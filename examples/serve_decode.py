"""Batched serving demo: prefill a batch of prompts, decode with KV/state
caches (attention KV, Mamba conv+ssm, RWKV wkv state — whatever the arch
needs).

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-3b
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import ARCHS, get_config  # noqa: E402
from repro.launch.serve import serve_batch  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    out = serve_batch(cfg, batch=args.batch, prompt_len=args.prompt_len,
                      gen=args.gen)
    print("sampled token ids (first row):", out["tokens"][0][:16])


if __name__ == "__main__":
    main()
