"""Batched serving demo: prefill a batch of prompts, decode with KV/state
caches (attention KV, Mamba conv+ssm, RWKV wkv state — whatever the arch
needs).

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-3b

With ``--ranks N`` the demo runs the DISTRIBUTED serve tier instead: a
router rank admits synthetic sessions through persistent-request pools
and N-1 workers decode them with continuous batching over the
rank-sharded KV page cache (pages move one-sidedly — see
docs/serving.md).

    PYTHONPATH=src python examples/serve_decode.py --ranks 3
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--ranks", type=int, default=0,
                    help="> 1: distributed serve tier (router + workers)")
    ap.add_argument("--sessions", type=int, default=24)
    args = ap.parse_args()

    if args.ranks > 1:
        from repro.launch.serve import serve_distributed
        serve_distributed(ranks=args.ranks, sessions=args.sessions)
        return

    from repro.configs import ARCHS, get_config
    from repro.launch.serve import serve_batch
    if args.arch not in ARCHS:
        ap.error(f"unknown arch {args.arch!r} (choose from {list(ARCHS)})")
    cfg = get_config(args.arch).reduced()
    out = serve_batch(cfg, batch=args.batch, prompt_len=args.prompt_len,
                      gen=args.gen)
    print("sampled token ids (first row):", out["tokens"][0][:16])


if __name__ == "__main__":
    main()
