"""Tour of the one-sided (RMA v2) API over real processes: rput/rget
ping-pong with request overlap, the notified-put producer/consumer
fast path (zero receiver-side payload copies), and the get-based
window allgather — all on one shared-memory window, with every byte
accounted in the ``rma_*`` ProtocolStats buckets.

    PYTHONPATH=src python examples/rma_tour.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import run_processes  # noqa: E402

N = 4
MSG = 256 << 10              # 256 KiB rput/rget payload (chunked)
SHARD = 8 << 10              # 8 KiB per-rank allgather shard


def prog(env):
    comm = env.comm
    r, n = comm.rank, comm.size
    win = comm.win_allocate("tour", 1 << 20)
    report = {}
    st = env.arena.view.stats

    # ---- rput/rget ping-pong: local-completion requests --------------
    # Rank r rputs into its OWN segment (publish), fences, then rgets
    # its neighbour's segment. Both requests are pumped by the shared
    # progress engine one chunk per tick — the arithmetic between
    # issue and wait() runs while chunks move.
    src = (np.arange(MSG, dtype=np.uint8) + r).astype(np.uint8)
    win.fence()
    put_req = win.rput(r, 0, src, chunk_bytes="auto")
    overlap = float(np.sum(np.sqrt(np.arange(4096.0))))  # overlapped work
    put_req.wait()
    win.fence()
    peer = (r + 1) % n
    dst = np.zeros(MSG, np.uint8)
    win.rget(peer, 0, dst, chunk_bytes="auto").wait()
    assert np.array_equal(dst, (np.arange(MSG) + peer).astype(np.uint8))
    report["pingpong_ok"] = True
    report["overlap"] = overlap > 0
    win.fence()

    # ---- notified put: producer/consumer, zero receiver copies -------
    # Even rank 2k produces for odd rank 2k+1. The payload moves
    # origin -> window once (counted as rma_notify at the ORIGIN); the
    # consumer spins on one non-temporal counter word and then reads
    # the data in place — its own copied-byte counters never move.
    slot = 512 << 10                      # clear of the ping-pong region
    if r % 2 == 0 and r + 1 < n:
        win.put_notify(r + 1, slot, f"batch-from-{r}".encode())
        report["notify"] = "produced"
    elif r % 2 == 1:
        c0 = st.copied_bytes
        win.wait_notify(r - 1)
        payload = bytes(win.local_view(slot, 32)).split(b"\0", 1)[0]
        report["recv_copies"] = st.copied_bytes - c0   # stays 0
        report["notify"] = payload.decode()
    win.fence()

    # ---- get-based allgather: payloads never ride the wire -----------
    shard = np.full(SHARD // 8, float(r))
    gathered = win.allgather(shard)
    exp = np.repeat(np.arange(n, dtype=float), SHARD // 8)
    assert np.array_equal(gathered, exp)
    report["allgather_ok"] = True

    report["paths"] = {k: v for k, v in st.path_copied_bytes.items()
                       if k.startswith("rma_") and v}
    win.free()
    return report


def main() -> None:
    res = run_processes(N, prog, pool_bytes=128 << 20, timeout=300)
    print(f"== RMA v2 tour on {N} real processes ==")
    for r, rep in enumerate(res):
        print(f"rank {r}: {rep}")
    consumers = [rep for rep in res if "recv_copies" in rep]
    ok = all(rep["recv_copies"] == 0 for rep in consumers)
    print(f"\nnotified-put consumers copied 0 payload bytes on their "
          f"side: {ok}")


if __name__ == "__main__":
    main()
