"""Tour of the Comm API v2 over real processes: method collectives on
pool-resident round buffers, split()/dup() sub-communicators, the
hierarchical allreduce, persistent requests, and the auto-tuned eager
threshold.

    PYTHONPATH=src python examples/comm_v2_tour.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import run_processes  # noqa: E402

N = 4
VEC = 1 << 16                # 512 KB of float64 per collective


def prog(env):
    comm = env.comm
    report = {}
    report["threshold"] = (comm.eager_threshold, comm.probed_crossover)

    # ---- method collectives (bulk -> pool-resident round buffers) ----
    x = (np.arange(VEC, dtype=np.float64) + 1) * (comm.rank + 1)
    st = env.arena.view.stats
    c0 = st.copied_bytes
    total = comm.allreduce(x, algo="ring")
    report["allreduce_copied"] = st.copied_bytes - c0
    assert np.allclose(total, (np.arange(VEC, dtype=np.float64) + 1) * 10)

    # ---- split: two rows of two ranks, remapped ranks ----------------
    row = comm.split(color=comm.rank // 2, key=comm.rank)
    row_sum = row.allreduce(np.array([float(comm.rank)]))
    report["row"] = (row.rank, row.parent_ranks, float(row_sum[0]))

    # ---- dup: congruent comm with isolated traffic -------------------
    clone = comm.dup()
    clone.send((clone.rank + 1) % N, f"r{clone.rank}".encode(), tag=1)
    msg, _ = clone.recv((clone.rank - 1) % N, tag=1)
    report["dup_msg"] = msg.decode()

    # ---- hierarchical allreduce over split() groups ------------------
    h = comm.allreduce(x, algo="hier")
    assert np.allclose(h, total)

    # ---- persistent requests: stable arena footprint -----------------
    peer = (comm.rank + 1) % N
    src = (comm.rank - 1) % N
    sbuf = np.zeros(VEC, np.float64)
    rbuf = np.zeros(VEC, np.float64)
    psend = comm.send_init(peer, sbuf, tag=7)
    precv = comm.recv_init(src, rbuf, tag=7)
    comm.barrier()
    slots0 = None
    for i in range(8):
        sbuf[:] = comm.rank * 100 + i
        psend.start(); precv.start()
        precv.wait(); psend.wait()
        if i == 0:
            slots0 = env.arena.stats()["slots_used"]
    comm.barrier()
    report["slots_stable"] = env.arena.stats()["slots_used"] == slots0
    assert rbuf[0] == src * 100 + 7
    return report


def main() -> None:
    res = run_processes(N, prog, pool_bytes=128 << 20,
                        eager_threshold="auto", timeout=300)
    print(f"== Comm API v2 on {N} real processes ==")
    for r, rep in enumerate(res):
        thr, cross = rep["threshold"]
        print(f"rank {r}: auto eager_threshold={thr}B "
              f"(probe crossover: {cross or 'beyond range'}); "
              f"allreduce copied {rep['allreduce_copied']}B; "
              f"row={rep['row']}; dup got '{rep['dup_msg']}'; "
              f"persistent-req slots stable: {rep['slots_stable']}")
    ok = all(rep["slots_stable"] for rep in res)
    print(f"\nhierarchical == ring result on every rank; "
          f"persistent requests left the arena footprint flat: {ok}")


if __name__ == "__main__":
    main()
