"""Quickstart: end-to-end training of a reduced smollm-135m on synthetic
Markov data — real optimizer, checkpointing, restart, straggler monitor.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]

Loss drops well below the uniform-entropy floor (log V ~= 4.85) because the
synthetic stream is an order-2 Markov chain with learnable structure.
"""
import argparse
import dataclasses
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.train import run_training  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64,
                                global_batch=8)
    with tempfile.TemporaryDirectory() as d:
        out = run_training(cfg, shape, args.steps, ckpt_dir=d,
                           ckpt_every=100, log_every=25)
        print(f"\nfinal loss {out['final_loss']:.3f} "
              f"(uniform floor {np.log(cfg.vocab_size):.2f}); "
              f"{out['tokens_per_s']:.0f} tok/s; "
              f"health={out['health']}")
        # resume from the final checkpoint to show restartability
        out2 = run_training(cfg, shape, args.steps, ckpt_dir=d, quiet=True)
        print(f"restart check: resumed at trained step, loss "
              f"{out2['final_loss']:.3f}")


if __name__ == "__main__":
    main()
