"""Fig-10-style strong-scaling study on the event simulator: CG and
miniAMR over CXL SHM vs TCP fabrics, 8 procs/node.

    PYTHONPATH=src python examples/scaling_study.py --nodes 2 4 8 16
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.perfmodel.apps import cg_program, miniamr_program  # noqa: E402
from repro.perfmodel.interconnects import (CXL_SHM, ETHERNET_TCP,  # noqa: E402
                                           MELLANOX_TCP)
from repro.perfmodel.simulator import Engine  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, nargs="*", default=[2, 4, 8, 16])
    args = ap.parse_args()

    for app, maker, kw in (("CG", cg_program, {"iters": 20}),
                           ("miniAMR", miniamr_program, {"steps": 20})):
        print(f"\n== {app} (8 procs/node) ==")
        print(f"{'nodes':>6s} {'cxl_shm':>10s} {'tcp_cx6':>10s} "
              f"{'tcp_eth':>10s} {'cxl comm%':>10s}")
        for nodes in args.nodes:
            n = nodes * 8
            res = {}
            for ic in (CXL_SHM, MELLANOX_TCP, ETHERNET_TCP):
                res[ic.name] = Engine(n, ic, procs_per_node=8).run(
                    lambda r: maker(r, n, **kw))
            c = res["cxl_shm"]
            print(f"{nodes:6d} {c['total_s']:9.3f}s "
                  f"{res['tcp_cx6dx']['total_s']:9.3f}s "
                  f"{res['tcp_ethernet']['total_s']:9.3f}s "
                  f"{c['comm_fraction'] * 100:9.1f}%")


if __name__ == "__main__":
    main()
