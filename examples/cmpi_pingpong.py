"""cMPI ping-pong: the paper's core mechanism live — two REAL processes
exchanging messages through shared memory (the CXL SHM stand-in), with the
arena, SPSC queues, MPI-4 persistent requests (Comm API v2), one-sided
RMA windows and PSCW synchronization, vs. a localhost TCP baseline.

    PYTHONPATH=src python examples/cmpi_pingpong.py
"""
import sys
import time
from pathlib import Path

_root = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_root))            # benchmarks package
sys.path.insert(0, str(_root / "src"))    # repro package

from benchmarks.common import tcp_pingpong  # noqa: E402
from repro.core import run_processes  # noqa: E402

SIZES = [8, 512, 4096, 65536]
ITERS = 100


def prog(env):
    out = {}
    # two-sided over the SPSC queue matrix
    for s in SIZES:
        payload = bytes(s)
        env.comm.barrier()
        t0 = time.perf_counter()
        for _ in range(ITERS):
            if env.rank == 0:
                env.comm.send(1, payload, tag=1)
                env.comm.recv(1, tag=2)
            else:
                env.comm.recv(0, tag=1)
                env.comm.send(0, payload, tag=2)
        out[("two", s)] = (time.perf_counter() - t0) / ITERS / 2
    # two-sided again through MPI-4 persistent requests (Comm API v2):
    # the wire plan is fixed once, start()/wait() reuse it every iter
    peer = 1 - env.rank
    for s in SIZES:
        sbuf = bytearray(s)
        rbuf = bytearray(s)
        psend = env.comm.send_init(peer, sbuf, tag=3)
        precv = env.comm.recv_init(peer, rbuf, tag=3)
        env.comm.barrier()
        t0 = time.perf_counter()
        for _ in range(ITERS):
            if env.rank == 0:
                psend.start().wait()
                precv.start(); precv.wait()
            else:
                precv.start(); precv.wait()
                psend.start().wait()
        out[("pers", s)] = (time.perf_counter() - t0) / ITERS / 2
        psend.free()
    # one-sided put/get through an RMA window + PSCW epochs
    win = env.comm.win_allocate("demo", max(SIZES) + 64)
    for s in SIZES:
        payload = bytes(s)
        win.fence()
        t0 = time.perf_counter()
        for _ in range(ITERS):
            if env.rank == 0:
                win.put(1, 0, payload)
                win.get(1, 0, 1)
            else:
                pass
        out[("one", s)] = (time.perf_counter() - t0) / ITERS / 2
        win.fence()
    return out


def main() -> None:
    shm = run_processes(2, prog, pool_bytes=64 << 20, cell_size=65536)[0]
    tcp = tcp_pingpong(SIZES, iters=ITERS)
    print(f"{'size':>8s} {'cMPI two-sided':>16s} {'cMPI persistent':>16s} "
          f"{'cMPI one-sided':>16s} {'localhost TCP':>15s}")
    for s in SIZES:
        print(f"{s:8d} {shm[('two', s)] * 1e6:13.1f} us "
              f"{shm[('pers', s)] * 1e6:13.1f} us "
              f"{shm[('one', s)] * 1e6:13.1f} us "
              f"{tcp[s] * 1e6:12.1f} us")
    print("\n(CPython per-op cost dominates the absolute numbers on this "
          "host; the calibrated\n model in repro.perfmodel carries the "
          "paper's hardware-level ratios.)")


if __name__ == "__main__":
    main()
