"""Training driver.

CPU preset runs a REDUCED config end-to-end (real training, synthetic
Markov data, checkpoint/restart, straggler monitor); on a TPU pod the same
driver takes the full config + production mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 60 --preset cpu-smoke
  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --steps 30 --preset cpu-smoke --cmpi-sync int8
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import InputShape
from repro.models import lm
from repro.train import data as D
from repro.train import optimizer as opt
from repro.train import steps as ST
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import FailureInjector, HeartbeatBoard


def run_training(cfg, shape: InputShape, steps: int, *,
                 ckpt_dir: str | Path | None = None,
                 ckpt_every: int = 20,
                 seed: int = 0,
                 injector: FailureInjector | None = None,
                 log_every: int = 10,
                 grad_accum: int = 1,
                 n_shards: int = 1,
                 quiet: bool = False) -> dict:
    """Single-process training loop (mesh-free CPU path). Returns final
    metrics + loss history. Restartable via ckpt_dir."""
    oc = opt.for_model(cfg)
    params = lm.init(cfg, jax.random.key(seed))
    opt_state = opt.init(oc, params)
    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr is not None:
        got = mgr.restore((params, opt_state))
        if got[0] is not None:
            start_step, (params, opt_state) = got
            if not quiet:
                print(f"[train] resumed from step {start_step}")

    ds = D.SyntheticLM(D.for_model(cfg, shape, seed))
    board = HeartbeatBoard(n_shards)

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            return lm.loss_fn(p, cfg, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_o, om = opt.apply_updates(oc, params, grads, opt_state)
        return new_p, new_o, dict(metrics, **om)

    history = []
    t0 = time.perf_counter()
    for step in range(start_step, steps):
        if injector is not None:
            injector.check(step)
        batch = {k: jax.numpy.asarray(v)
                 for k, v in ds.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        history.append(loss)
        board.beat(0, step)
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save_async(step + 1, (params, opt_state))
        if not quiet and (step % log_every == 0 or step == steps - 1):
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e}")
    if mgr is not None:
        mgr.save(steps, (params, opt_state))
        mgr.wait()
    dt = time.perf_counter() - t0
    tokens = (steps - start_step) * shape.global_batch * shape.seq_len
    return {
        "history": history,
        "final_loss": history[-1] if history else float("nan"),
        "tokens_per_s": tokens / max(dt, 1e-9),
        "params": params,
        "opt_state": opt_state,
        "health": board.health(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--preset", default="cpu-smoke",
                    choices=["cpu-smoke", "full"])
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.preset == "cpu-smoke":
        cfg = cfg.reduced()
        shape = dataclasses.replace(shape, seq_len=args.seq_len,
                                    global_batch=args.global_batch)
    out = run_training(cfg, shape, args.steps, ckpt_dir=args.ckpt_dir,
                       seed=args.seed)
    uniform = float(np.log(cfg.vocab_size))
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"(uniform {uniform:.2f}) | {out['tokens_per_s']:.0f} tok/s")


if __name__ == "__main__":
    main()
