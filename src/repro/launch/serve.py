"""Serving driver: batched prefill + decode with a KV/state cache.

CPU preset serves a REDUCED config; the same driver lowers the full config
on a TPU mesh (the decode shapes of the dry-run are exactly this step).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --batch 4 --prompt-len 32 --gen 32

``--ranks N`` switches to the DISTRIBUTED serve tier instead
(``repro.serve``): a router rank admits an open-loop Poisson session
population and N-1 workers run continuous-batching decode over the
rank-sharded dynamic-window page cache — the comm-core data plane the
single-process path above feeds in a real deployment.

  PYTHONPATH=src python -m repro.launch.serve --ranks 3 --sessions 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm


def serve_batch(cfg, *, batch: int, prompt_len: int, gen: int,
                seed: int = 0, greedy: bool = True, quiet: bool = False
                ) -> dict:
    """Prefill a batch of prompts, then decode `gen` tokens each."""
    params = lm.init(cfg, jax.random.key(seed))
    rng = np.random.default_rng(seed)
    cache_len = prompt_len + gen

    prompts = rng.integers(0, cfg.vocab_size, size=(batch, prompt_len),
                           dtype=np.int32)

    state = lm.decode_state_init(cfg, batch, cache_len)

    @jax.jit
    def decode_fn(params, state, tok, pos):
        b = {"tokens": tok}
        if cfg.frontend == "frames":
            emb = params["embed"].astype(jnp.dtype(cfg.compute_dtype))
            b = {"frames": emb[tok[:, 0]][:, None, :]}
        return lm.decode_step(params, cfg, state, b, pos)

    # prefill via decode steps (teacher-forcing the prompt) — exercises the
    # cache write path end to end; a fused prefill kernel is the TPU path.
    t0 = time.perf_counter()
    logits = None
    for i in range(prompt_len):
        tok = jnp.asarray(prompts[:, i:i + 1])
        pos = jnp.full((batch,), i, jnp.int32)
        logits, state = decode_fn(params, state, tok, pos)
    t_prefill = time.perf_counter() - t0

    out_tokens = np.zeros((batch, gen), np.int32)
    t0 = time.perf_counter()
    for j in range(gen):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32) if greedy else \
            jax.random.categorical(jax.random.key(j), logits).astype(jnp.int32)
        out_tokens[:, j] = np.asarray(nxt)
        pos = jnp.full((batch,), prompt_len + j, jnp.int32)
        logits, state = decode_fn(params, state, nxt[:, None], pos)
    t_decode = time.perf_counter() - t0

    tput = batch * gen / max(t_decode, 1e-9)
    if not quiet:
        print(f"[serve] batch={batch} prefill {prompt_len} tok in "
              f"{t_prefill:.2f}s | decode {gen} tok in {t_decode:.2f}s "
              f"({tput:.1f} tok/s)")
    return {"tokens": out_tokens, "decode_tok_per_s": tput,
            "prefill_s": t_prefill, "decode_s": t_decode}


def serve_distributed(*, ranks: int = 3, sessions: int = 32,
                      rate: float = 400.0, seed: int = 0,
                      quiet: bool = False) -> dict:
    """Run the multi-rank serve tier (router + workers over one Comm)
    and return the router's report. Thin wrapper over
    ``repro.serve.run_serve`` so launch scripts and the jax path share
    one entry point."""
    from repro.serve import ServeConfig, run_serve
    cfg = ServeConfig(sessions=sessions, rate=rate, seed=seed)
    reports = run_serve(cfg, ranks=ranks)
    router = reports[0]
    if not quiet:
        print(f"[serve] {router['sessions']} sessions on {ranks} ranks "
              f"({ranks - 1} workers): qps {router['qps']:.1f}, "
              f"p50 {router['p50_us']:.0f} us, "
              f"p99 {router['p99_us']:.0f} us")
    return router


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--preset", default="cpu-smoke",
                    choices=["cpu-smoke", "full"])
    ap.add_argument("--ranks", type=int, default=0,
                    help="> 1: run the distributed serve tier instead "
                         "of the single-process jax driver")
    ap.add_argument("--sessions", type=int, default=32)
    ap.add_argument("--rate", type=float, default=400.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.ranks > 1:
        serve_distributed(ranks=args.ranks, sessions=args.sessions,
                          rate=args.rate, seed=args.seed)
        return
    if args.arch is None:
        ap.error("--arch is required for the single-process driver")
    cfg = get_config(args.arch)
    if args.preset == "cpu-smoke":
        cfg = cfg.reduced()
    serve_batch(cfg, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen)


if __name__ == "__main__":
    main()
