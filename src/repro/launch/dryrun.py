import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), record memory analysis, cost
analysis and collective traffic — the §Roofline source of truth.

The two lines above MUST run before any jax import: jax locks the device
count at first init. Do not set that flag anywhere global (tests and benches
must see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both            # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --variant ga1 --grad-accum 1
"""
import argparse
import dataclasses
import gzip
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis import hlo as H
from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch import specs as SP
from repro.launch.mesh import MESHES, make_production_mesh
from repro.train import optimizer as opt
from repro.train import steps as ST

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def lower_cell(cfg, shape, mesh, *, grad_accum=None, unroll=False):
    """Returns (lowered, meta) for the cell's step function."""
    if shape.kind == "train":
        ts = ST.make_train_step(cfg, shape, mesh, grad_accum=grad_accum,
                                unroll=unroll)
        args = (SP.param_specs(cfg), SP.opt_state_specs(cfg),
                SP.batch_specs(cfg, shape))
        lowered = jax.jit(ts.fn, in_shardings=ts.in_shardings,
                          out_shardings=ts.out_shardings).lower(*args)
        return lowered, {"step": "train_step", "grad_accum": ts.grad_accum}
    if shape.kind == "prefill":
        ss = ST.make_serve_prefill(cfg, shape, mesh)
        args = (SP.param_specs(cfg), SP.batch_specs(cfg, shape))
        lowered = jax.jit(ss.fn, in_shardings=ss.in_shardings,
                          out_shardings=ss.out_shardings).lower(*args)
        return lowered, {"step": "serve_prefill"}
    # decode
    ss = ST.make_serve_decode(cfg, shape, mesh)
    state, pos = SP.decode_specs(cfg, shape)
    args = (SP.param_specs(cfg), state, SP.batch_specs(cfg, shape), pos)
    lowered = jax.jit(ss.fn, in_shardings=ss.in_shardings,
                      out_shardings=ss.out_shardings).lower(*args)
    return lowered, {"step": "serve_decode"}


def run_cell(arch: str, shape_name: str, mesh_key: str, *,
             variant: str = "baseline", grad_accum=None, save_hlo=False,
             overrides=None, preset: str = "baseline") -> dict:
    cfg = get_config(arch)
    if preset == "optimized":
        from repro.configs import optimized
        cfg = optimized(cfg)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    minfo = MESHES[mesh_key]
    chips = minfo["chips"]

    ok, why = shape_applicable(cfg, shape)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": minfo["tag"],
        "chips": chips, "variant": variant,
    }
    if not ok:
        rec["status"] = "skip"
        rec["why"] = why
        return rec

    mesh = make_production_mesh(multi_pod=minfo["multi_pod"])
    t0 = time.perf_counter()
    lowered, meta = lower_cell(cfg, shape, mesh, grad_accum=grad_accum)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    rec.update(meta)
    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)

    mem = compiled.memory_analysis()
    if mem is not None:
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
        live = (rec["memory"].get("argument_size_in_bytes", 0)
                + rec["memory"].get("temp_size_in_bytes", 0)
                + rec["memory"].get("output_size_in_bytes", 0)
                - rec["memory"].get("alias_size_in_bytes", 0))
        rec["memory"]["live_bytes_per_device"] = int(live)

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    # NOTE: cost_analysis counts while bodies once — recorded for reference
    # only; the roofline uses the trip-count-scaled HLO walk below.
    rec["cost_analysis_unscaled"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
    }

    hlo_text = compiled.as_text()
    st = H.analyze_module(hlo_text)
    rec["collectives"] = {
        "counts": st.coll_counts,
        "wire_bytes": st.wire_bytes,
        "top_ops": st.top_ops,
        "total_wire_bytes_per_device": st.total_wire_bytes,
        "unparsed_while": st.unparsed_while,
    }
    rec["top_bytes_ops"] = st.top_bytes_ops

    roof = H.Roofline(
        flops_per_device=st.flops,
        bytes_per_device=st.bytes_,
        wire_bytes_per_device=st.total_wire_bytes,
        model_flops_per_device=H.model_flops(cfg, shape, chips),
    )
    rec["roofline"] = roof.as_dict()
    rec["status"] = "ok"

    if save_hlo:
        p = ART / variant / minfo["tag"]
        p.mkdir(parents=True, exist_ok=True)
        with gzip.open(p / f"{arch}__{shape_name}.hlo.txt.gz", "wt") as f:
            f.write(hlo_text)
    return rec


def cell_path(variant: str, mesh_tag: str, arch: str, shape: str) -> Path:
    return ART / variant / mesh_tag / f"{arch}__{shape}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=list(ARCHS))
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--preset", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--override", nargs="*", default=[],
                    help="cfg overrides key=value (e.g. remat=none)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "true"):
            v = True
        if v in ("False", "false"):
            v = False
        overrides[k] = v

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    total = ok = skip = fail = 0
    for mesh_key in meshes:
        for arch in args.arch:
            for shape in args.shape:
                total += 1
                out = cell_path(args.variant, MESHES[mesh_key]["tag"], arch,
                                shape)
                if args.skip_existing and out.exists():
                    prev = json.loads(out.read_text())
                    if prev.get("status") in ("ok", "skip"):
                        print(f"[cached] {mesh_key:6s} {arch:24s} {shape}")
                        ok += prev["status"] == "ok"
                        skip += prev["status"] == "skip"
                        continue
                t0 = time.perf_counter()
                try:
                    rec = run_cell(arch, shape, mesh_key,
                                   variant=args.variant,
                                   grad_accum=args.grad_accum,
                                   save_hlo=args.save_hlo,
                                   overrides=overrides or None,
                                   preset=args.preset)
                except Exception as e:  # a failing cell is a bug — record it
                    rec = {"arch": arch, "shape": shape,
                           "mesh": MESHES[mesh_key]["tag"],
                           "variant": args.variant, "status": "fail",
                           "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                out.parent.mkdir(parents=True, exist_ok=True)
                out.write_text(json.dumps(rec, indent=1))
                dt = time.perf_counter() - t0
                if rec["status"] == "ok":
                    ok += 1
                    r = rec["roofline"]
                    mem = rec.get("memory", {}).get("live_bytes_per_device", 0)
                    print(f"[ok {dt:6.1f}s] {mesh_key:6s} {arch:24s} "
                          f"{shape:12s} mem/dev={mem/2**30:6.2f}GiB "
                          f"c={r['compute_s']:.2e}s m={r['memory_s']:.2e}s "
                          f"coll={r['collective_s']:.2e}s "
                          f"dom={r['bottleneck']:10s} "
                          f"frac={r['roofline_fraction']:.3f}", flush=True)
                elif rec["status"] == "skip":
                    skip += 1
                    print(f"[skip] {mesh_key:6s} {arch:24s} {shape:12s} "
                          f"{rec['why']}", flush=True)
                else:
                    fail += 1
                    print(f"[FAIL {dt:6.1f}s] {mesh_key:6s} {arch:24s} "
                          f"{shape:12s} {rec['error'][:200]}", flush=True)
    print(f"\ndryrun: {ok} ok, {skip} skip, {fail} fail / {total} cells")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
