"""Production meshes. Functions only — importing this module never touches
jax device state.

Single pod: (16, 16) ("data", "model")    = 256 chips (one v5e pod)
Multi-pod:  (2, 16, 16) ("pod", "data", "model") = 512 chips

The ``pod`` axis is the expensive fabric (DCN / cross-pod): the cMPI-derived
rule is that it must carry thin traffic only (hierarchical collectives,
optionally compressed) — see distributed/schedules.py.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            f"=512 before any jax import (launch/dryrun.py does)")
    return jax.make_mesh(shape, axes, devices=devices[:n],
                         axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n],
                         axis_types=(AxisType.Auto,) * len(axes))


MESHES = {
    "single": dict(multi_pod=False, chips=256, tag="pod16x16"),
    "multi": dict(multi_pod=True, chips=512, tag="pod2x16x16"),
}
