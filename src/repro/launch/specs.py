"""ShapeDtypeStruct stand-ins for every model input — the dry-run never
allocates. ``input_specs`` covers the batch; params / optimizer / decode-state
specs come from the respective eval_shape helpers.

Modality frontends are STUBS per the assignment: ``[audio]`` archs receive
precomputed frame embeddings (B, S, d_model); ``[vlm]`` archs receive
precomputed patch embeddings (B, n_ctx_tokens, d_model) as cross-attention
context.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import lm
from repro.train import optimizer as opt


def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, jax.ShapeDtypeStruct]:
    B = shape.global_batch
    L = 1 if shape.kind == "decode" else shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    d: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "frames":
        d["frames"] = jax.ShapeDtypeStruct((B, L, cfg.d_model), cdt)
    else:
        d["tokens"] = jax.ShapeDtypeStruct((B, L), jnp.int32)
    if shape.kind == "train":
        d["labels"] = jax.ShapeDtypeStruct((B, L), jnp.int32)
    if cfg.n_ctx_tokens and shape.kind != "decode":
        d["ctx"] = jax.ShapeDtypeStruct((B, cfg.n_ctx_tokens, cfg.d_model), cdt)
    return d


def param_specs(cfg: ModelConfig):
    return lm.param_specs(cfg)


def opt_state_specs(cfg: ModelConfig, oc: opt.OptConfig | None = None):
    oc = oc or opt.for_model(cfg)
    return opt.state_specs(oc, lm.param_specs(cfg))


def decode_specs(cfg: ModelConfig, shape: InputShape):
    state = lm.decode_state_specs(cfg, shape.global_batch, shape.seq_len)
    pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    return state, pos
