"""``python -m repro.trace`` — stitch and summarize flight-recorder dumps.

Each rank of a traced run writes its own dump via
``comm.trace_dump(path)`` (or ``Tracer.dump``). This CLI turns those
per-rank files into something a human can read:

    python -m repro.trace merge rank0.json rank1.json -o timeline.json
    python -m repro.trace summarize rank0.json rank1.json --top 15

``merge`` emits Chrome trace-event JSON — open it in Perfetto
(https://ui.perfetto.dev) or chrome://tracing: one process lane per
rank, engine ticks and schedule executions as duration slices (one
sub-lane per schedule node, so chunked collectives render per-chunk),
pt2pt/matchbox instants, RMA epochs as nested slices. ``summarize``
prints a text top-N event table + latency-histogram percentiles.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.core.trace import load_dump, merge_dumps, summarize_dumps


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description="merge/summarize per-rank flight-recorder dumps")
    sub = p.add_subparsers(dest="cmd", required=True)
    pm = sub.add_parser("merge", help="stitch per-rank dumps into one "
                                      "Perfetto-loadable Chrome trace")
    pm.add_argument("files", nargs="+", type=Path)
    pm.add_argument("-o", "--out", type=Path,
                    default=Path("timeline.json"))
    ps = sub.add_parser("summarize", help="text top-N event summary")
    ps.add_argument("files", nargs="+", type=Path)
    ps.add_argument("--top", type=int, default=10)
    args = p.parse_args(argv)

    dumps = []
    for f in args.files:
        if not f.exists():
            print(f"missing dump: {f}", file=sys.stderr)
            return 1
        dumps.append(load_dump(f))
    if args.cmd == "merge":
        trace = merge_dumps(dumps)
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(json.dumps(trace) + "\n")
        print(f"merged {len(dumps)} rank dump(s), "
              f"{len(trace['traceEvents'])} trace events -> {args.out}")
    else:
        print(summarize_dumps(dumps, top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
