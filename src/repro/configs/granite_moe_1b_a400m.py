"""granite-moe-1b-a400m — 24L d_model=1024 16H (GQA kv=8) d_ff=512,
vocab=49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import BlockSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,       # padded to 49168 for 16-way vocab parallelism
    pattern=(BlockSpec(mixer="attn", ffn="moe"),),
    moe=MoEConfig(n_experts=32, top_k=8),
    rope_theta=10_000.0,
    optimizer="adamw",
)
