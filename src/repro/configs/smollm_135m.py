"""smollm-135m — 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
[hf:HuggingFaceTB/SmolLM-135M; hf]

9 query heads are not divisible by the 16-way model axis; attention head
sharding is uneven (GSPMD pads) while FFN / vocab TP stays exact.
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    pattern=(BlockSpec(mixer="attn"),),
    rope_theta=10_000.0,
    tie_embeddings=True,
    optimizer="adamw",
)
