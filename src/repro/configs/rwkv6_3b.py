"""rwkv6-3b (Finch) — 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
Data-dependent decay linear recurrence. [arXiv:2404.05892; hf]"""
from repro.configs.base import BlockSpec, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    pattern=(BlockSpec(mixer="rwkv6", ffn="cmix"),),
    rwkv=RWKVConfig(head_size=64, decay_lora=64),
    fsdp=True,
    optimizer="adamw",
)
