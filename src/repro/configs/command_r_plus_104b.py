"""command-r-plus-104b — 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000. GQA, no-bias, Cohere-style parallel attn+FFN blocks.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    pattern=(BlockSpec(mixer="attn", parallel=True),),
    rope_theta=75_000.0,
    use_bias=False,
    tie_embeddings=True,
    fsdp=True,
    optimizer="adamw",
)
