"""musicgen-large — 48L d_model=2048 32H (GQA kv=32 == MHA) d_ff=8192
vocab=2048 (EnCodec codebook). Decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (batch, seq, d_model); the backbone predicts
codebook tokens over the 2048-entry vocab.
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    pattern=(BlockSpec(mixer="attn"),),
    frontend="frames",
    rope_theta=10_000.0,
    fsdp=True,
    optimizer="adamw",
)
