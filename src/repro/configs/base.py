"""Model / run configuration for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. A config is a
pure dataclass — no jax imports, no device state — so importing a config never
touches the runtime. Layer stacking is expressed as a repeating ``pattern`` of
``BlockSpec``s scanned ``n_groups`` times (``pattern * n_groups`` == the full
layer stack). Homogeneous models use a length-1 pattern; interleaved models
(Jamba 1:7 mamba:attn, Llama-vision self/cross) use longer patterns.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional


# --------------------------------------------------------------------------
# Block specs
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class BlockSpec:
    """One layer position inside the repeating pattern."""

    mixer: str = "attn"          # attn | cross_attn | mamba | rwkv6
    ffn: str = "dense"           # dense | moe | none
    parallel: bool = False       # Cohere-style parallel attn+ffn off one norm


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 16
    top_k: int = 2
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64         # lora rank for the data-dependent decay
    gate_lora: int = 0           # 0 -> d_model // 2 is NOT used; plain gate proj


# --------------------------------------------------------------------------
# Model config
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int                  # query heads; 0 for attention-free archs
    n_kv_heads: int
    d_ff: int                     # per-expert width for MoE archs
    vocab_size: int
    pattern: tuple[BlockSpec, ...] = (BlockSpec(),)
    d_head: int = 0               # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # vlm / audio frontend stubs
    n_ctx_tokens: int = 0         # cross-attn context length (image patches)
    frontend: str = "tokens"      # tokens | frames (precomputed embeddings)
    # misc
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    use_bias: bool = False
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 16  # pad vocab so the parallel head divides TP
    # numerics / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "block"          # none | block | full
    optimizer: str = "adamw"      # adamw | adafactor (memory-lean for >90B)
    attn_chunk: int = 0           # 0 -> auto: chunked attention when S > 8192
    kv_update: str = "onehot"     # onehot | dus (vmap dynamic_update_slice)
    decode_return: str = "logits"  # logits | token (vocab-parallel argmax)
    serve_fsdp: bool = True       # False: serve steps drop FSDP (TP-only
    #                               params; kills per-step weight gathers)
    moe_shard: str = "expert"     # expert (EP over model) | ffn (per-expert
    #                               TP over d_ff; dispatch stays device-local)
    attn_seq_shard: bool = False  # shard attention scores over q-sequence
    #                               on the model axis (context parallelism)
    kv_shard: str = "seq"         # decode KV-cache layout: seq (flash-
    #                               decoding over model) | batch (per-example
    #                               local attention; no model-axis gathers)
    fsdp_dim: str = "contract"    # contract: shard weights on contraction
    #                               dims (partial sums -> activation-sized
    #                               all-reduces — the measured pathology) |
    #                               output: ZeRO-3 style — weights sharded on
    #                               output dims, gathered just-in-time
    decode_attn: str = "auto"     # auto (XLA decides; reshards the cache) |
    #                               flashdecode (q replicated, scores stay
    #                               seq-sharded, LSE-merge over 'model')
    # distribution hints
    fsdp: bool = False            # additionally shard params over the data axis
    vocab_parallel: bool = True   # shard_map vocab-parallel embed + CE
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (quantized KV feature)

    # ---------------- derived ----------------
    def __post_init__(self):
        if self.d_head == 0 and self.n_heads > 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.arch_id}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}"
        )

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_attention_free(self) -> bool:
        return all(b.mixer in ("mamba", "rwkv6") for b in self.pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts (SSM / hybrid)."""
        return any(b.mixer in ("mamba", "rwkv6") for b in self.pattern)

    # ---------------- parameter counting (for rooflines) ----------------
    def param_counts(self) -> dict[str, float]:
        """Analytic parameter counts: total and active-per-token."""
        D, H, KV, Dh, F = (self.d_model, self.n_heads, self.n_kv_heads,
                           self.d_head, self.d_ff)
        embed = self.padded_vocab * D
        head = 0 if self.tie_embeddings else self.padded_vocab * D
        total = embed + head + 2 * D  # final norm (scale) + small slack
        active = float(embed // max(self.padded_vocab, 1)) * 0  # embed gather is O(D)
        per_layer_total = 0.0
        per_layer_active = 0.0
        counts = {"attn": 0, "cross_attn": 0, "mamba": 0, "rwkv6": 0}
        for blk in self.pattern:
            counts[blk.mixer] += 1
            if blk.mixer in ("attn", "cross_attn"):
                p = D * H * Dh + 2 * D * KV * Dh + H * Dh * D
            elif blk.mixer == "mamba":
                mc = self.mamba or MambaConfig()
                d_in = mc.expand * D
                dt_rank = mc.dt_rank or -(-D // 16)
                p = (D * 2 * d_in               # in_proj (x and z)
                     + d_in * mc.d_conv         # depthwise conv
                     + d_in * (dt_rank + 2 * mc.d_state)  # x_proj
                     + dt_rank * d_in           # dt_proj
                     + d_in * mc.d_state        # A
                     + d_in                     # D skip
                     + d_in * D)                # out_proj
            elif blk.mixer == "rwkv6":
                rc = self.rwkv or RWKVConfig()
                p = 5 * D * D + D * rc.decay_lora * 2 + D * D  # r,k,v,g,o + w lora + out
            else:
                raise ValueError(blk.mixer)
            per_layer_total += p
            per_layer_active += p
            # norms
            per_layer_total += 2 * D
            per_layer_active += 2 * D
            if blk.ffn == "dense":
                f = 3 * D * F  # swiglu
                per_layer_total += f
                per_layer_active += f
            elif blk.ffn == "cmix":
                f = D * D + 2 * D * F  # rwkv channel mix: r gate + k/v
                per_layer_total += f
                per_layer_active += f
            elif blk.ffn == "moe":
                moe = self.moe or MoEConfig()
                f = 3 * D * F
                per_layer_total += moe.n_experts * f + D * moe.n_experts
                per_layer_active += moe.top_k * f + D * moe.n_experts
        total += per_layer_total * self.n_groups
        active_total = (embed // max(self.padded_vocab, 1)) + head / max(self.padded_vocab, 1)
        active = per_layer_active * self.n_groups + D  # + head row cost is per-token
        # head matmul is always dense over vocab:
        active += head if head else embed  # logits matmul touches V*D
        return {"total": float(total), "active": float(active)}

    def reduced(self, **over) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(moe, n_experts=min(moe.n_experts, 4),
                                      top_k=min(moe.top_k, 2))
        mamba = self.mamba
        if mamba is not None:
            mamba = dataclasses.replace(mamba, d_state=4, d_conv=4, expand=2)
        rwkv = self.rwkv
        if rwkv is not None:
            rwkv = dataclasses.replace(rwkv, head_size=8, decay_lora=4)
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, max(1, n_heads // 2)) if self.n_kv_heads else 0
        d_head = 8
        d_model = max(n_heads, 1) * d_head if n_heads else 32
        if self.rwkv is not None:
            d_model = 4 * rwkv.head_size  # 4 rwkv heads
        small = dict(
            n_layers=2 * len(self.pattern),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=d_head if n_heads else 0,
            d_ff=64,
            vocab_size=128,
            moe=moe,
            mamba=mamba,
            rwkv=rwkv,
            n_ctx_tokens=16 if self.n_ctx_tokens else 0,
            vocab_pad_multiple=1,
            remat="none",
            fsdp=False,
            vocab_parallel=False,
        )
        small.update(over)
        return dataclasses.replace(self, **small)


# --------------------------------------------------------------------------
# Input shapes (assigned shape set for the LM family)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs. long_500k needs sub-quadratic attn."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "SKIP(full-attention): 500k decode needs sub-quadratic mixing"
    return True, ""
