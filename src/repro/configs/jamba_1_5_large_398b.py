"""jamba-1.5-large-398b — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave. [arXiv:2403.19887; hf]

Pattern: 8-layer block with attention at position 3 (1 attn : 7 mamba),
repeated 9 times. All FFNs are MoE (16 experts, top-2). Adafactor keeps
optimizer state within v5e HBM at 398B parameters.
"""
from repro.configs.base import BlockSpec, MambaConfig, ModelConfig, MoEConfig

_MAMBA = BlockSpec(mixer="mamba", ffn="moe")
_ATTN = BlockSpec(mixer="attn", ffn="moe")

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    pattern=(_MAMBA, _MAMBA, _MAMBA, _ATTN, _MAMBA, _MAMBA, _MAMBA, _MAMBA),
    moe=MoEConfig(n_experts=16, top_k=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    rope_theta=10_000.0,
    fsdp=True,
    optimizer="adafactor",
)
