"""llama-3.2-vision-90b — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attn image layers. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (n_ctx_tokens, d_model); every 5th layer
cross-attends to them (20 cross + 80 self = 100 layers).
"""
from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    pattern=(
        BlockSpec(mixer="attn"),
        BlockSpec(mixer="attn"),
        BlockSpec(mixer="attn"),
        BlockSpec(mixer="attn"),
        BlockSpec(mixer="cross_attn"),
    ),
    n_ctx_tokens=1024,       # precomputed image patch embeddings (stub frontend)
    rope_theta=500_000.0,
    fsdp=True,
    optimizer="adamw",
)
