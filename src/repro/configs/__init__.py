"""Config registry: ``get_config(arch_id)`` and the assigned-architecture list."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    BlockSpec,
    InputShape,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    SHAPES,
    shape_applicable,
)

_MODULES = {
    "dbrx-132b": "repro.configs.dbrx_132b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "llama3-8b": "repro.configs.llama3_8b",
    "smollm-135m": "repro.configs.smollm_135m",
    "glm4-9b": "repro.configs.glm4_9b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "musicgen-large": "repro.configs.musicgen_large",
}

ARCHS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def optimized(cfg: ModelConfig) -> ModelConfig:
    """The §Perf-proven beyond-paper flags, per family (EXPERIMENTS.md):
    ZeRO-3 weight sharding, scatter KV updates, TP-resident serve params,
    vocab-parallel greedy decode, flash-decoding; expert-parallel
    shard_map MoE for MoE archs; context-parallel attention when heads
    cannot split the 16-way model axis."""
    import dataclasses as _dc
    over: dict = dict(fsdp_dim="output", kv_update="dus",
                      serve_fsdp=False, decode_return="token",
                      decode_attn="flashdecode")
    if cfg.moe is not None:
        over["moe_shard"] = "ep_a2a"
    if cfg.n_heads and cfg.n_heads % 16 != 0:
        over["attn_seq_shard"] = True
    return _dc.replace(cfg, **over)
