"""jit-able step functions: train_step, serve_prefill, serve_decode.

Each ``make_*`` builder closes over (cfg, mesh) and returns the function plus
its in/out sharding trees, so launch/dryrun.py and launch/train.py share one
code path. Gradient accumulation bounds activation memory: microbatch count
is chosen so one microbatch holds ~TOKENS_PER_MICRO tokens per data shard
(scan-carry activations for the backward scale with the microbatch, not the
global batch).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.distributed.context import DistContext
from repro.distributed import sharding as shd
from repro.models import lm
from repro.train import optimizer as opt

TOKENS_PER_MICRO = 8_192   # per data shard, per microbatch


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def dp_total(mesh) -> int:
    t = 1
    for a in shd.dp_axes(mesh):
        t *= shd.axis_size(mesh, a)
    return t


def pick_grad_accum(shape: InputShape, mesh) -> int:
    """Microbatch count: divide the local batch until one microbatch is
    ~TOKENS_PER_MICRO tokens (>=1 sequence)."""
    local_seqs = max(1, shape.global_batch // dp_total(mesh))
    target = max(1, TOKENS_PER_MICRO // shape.seq_len)
    ga = max(1, local_seqs // max(target, 1))
    while local_seqs % ga:
        ga -= 1
    return ga


def named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def make_dist(cfg: ModelConfig, shape: InputShape, mesh) -> DistContext:
    shardable = shape.global_batch % dp_total(mesh) == 0 and dp_total(mesh) > 1
    return DistContext(mesh, batch_shardable=shardable)


# --------------------------------------------------------------------------
# train
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainStep:
    fn: Any                      # (params, opt_state, batch) -> (p, o, metrics)
    in_shardings: tuple          # (params, opt_state, batch)
    out_shardings: tuple
    grad_accum: int


def make_train_step(cfg: ModelConfig, shape: InputShape, mesh, *,
                    oc: Optional[opt.OptConfig] = None,
                    grad_accum: Optional[int] = None,
                    unroll: bool = False) -> TrainStep:
    oc = oc or opt.for_model(cfg)
    ga = pick_grad_accum(shape, mesh) if grad_accum is None else grad_accum
    dist = make_dist(cfg, shape, mesh)

    def loss_for(params, mb):
        total, metrics = lm.loss_fn(params, cfg, mb, dist=dist, unroll=unroll)
        return total, metrics

    def train_step(params, opt_state, batch):
        if ga == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((ga, x.shape[0] // ga) + x.shape[1:]),
                batch)

            def micro(gacc, mslice):
                (l, m), g = jax.value_and_grad(
                    loss_for, has_aux=True)(params, mslice)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return gacc, (l, m)

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            gsum, (ls, ms) = lax.scan(micro, g0, mb)
            grads = jax.tree.map(lambda g: g / ga, gsum)
            loss = ls.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        new_params, new_opt, om = opt.apply_updates(oc, params, grads,
                                                    opt_state)
        metrics = dict(metrics, loss=loss, **om)
        return new_params, new_opt, metrics

    pspec = shd.param_pspecs(cfg, mesh)
    ospec = opt_specs(cfg, mesh, oc, pspec)
    bspec = shd.batch_pspecs(cfg, shape, mesh)
    mspec = {k: P() for k in
             ("loss", "aux", "tokens", "grad_norm", "lr")}
    return TrainStep(
        fn=train_step,
        in_shardings=(named(mesh, pspec), named(mesh, ospec),
                      named(mesh, bspec)),
        out_shardings=(named(mesh, pspec), named(mesh, ospec),
                       named(mesh, mspec)),
        grad_accum=ga,
    )


def opt_specs(cfg: ModelConfig, mesh, oc: opt.OptConfig, pspec):
    """PartitionSpecs for the optimizer state (ZeRO-1 over ``data``)."""
    pshapes = lm.param_specs(cfg)
    sshapes = opt.state_specs(oc, pshapes)

    if oc.name == "adamw":
        mom = shd.opt_state_pspecs(cfg, mesh, pspec, pshapes)
        return {"mu": mom, "nu": mom, "count": P()}
    if oc.name == "adafactor":
        def drop_last(spec: P, leaf, full) -> P:
            parts = (list(spec) + [None] * len(full.shape))[: len(full.shape)]
            return P(*parts[: len(leaf.shape)])

        vr = jax.tree.map(lambda l, f, s: drop_last(s, l, f),
                          sshapes["vr"], pshapes, pspec)
        # vc drops the second-to-last dim: take spec minus that axis
        def vc_spec(spec: P, leaf, full) -> P:
            parts = list(spec) + [None] * (len(full.shape) - len(spec))
            if len(leaf.shape) == len(full.shape):       # unfactored
                return P(*parts)
            parts = parts[:-2] + [parts[-1]]
            return P(*parts[: len(leaf.shape)])

        vc = jax.tree.map(lambda l, f, s: vc_spec(s, l, f),
                          sshapes["vc"], pshapes, pspec)
        return {"vr": vr, "vc": vc, "count": P()}
    return {"count": P()}


# --------------------------------------------------------------------------
# serve
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ServeStep:
    fn: Any
    in_shardings: tuple
    out_shardings: tuple


def make_serve_prefill(cfg: ModelConfig, shape: InputShape, mesh) -> ServeStep:
    dist = make_dist(cfg, shape, mesh)

    def serve_prefill(params, batch):
        return lm.prefill(params, cfg, batch, dist=dist)

    pspec = shd.param_pspecs(cfg, mesh, serve=True)
    bspec = dict(shd.batch_pspecs(cfg, dataclasses.replace(shape, kind="prefill"),
                                  mesh))
    bspec.pop("labels", None)
    bdim = bspec[next(iter(bspec))][0]
    return ServeStep(
        fn=serve_prefill,
        in_shardings=(named(mesh, pspec), named(mesh, bspec)),
        out_shardings=NamedSharding(mesh, P(bdim, None)),
    )


def make_serve_decode(cfg: ModelConfig, shape: InputShape, mesh) -> ServeStep:
    dist = make_dist(cfg, shape, mesh)

    def serve_decode(params, state, batch, pos):
        logits, new_state = lm.decode_step(params, cfg, state, batch, pos,
                                           dist=dist)
        return logits, new_state

    pspec = shd.param_pspecs(cfg, mesh, serve=True)
    sspec = shd.decode_state_pspecs(cfg, shape, mesh)
    one = dataclasses.replace(shape, seq_len=1)
    bspec = dict(shd.batch_pspecs(cfg, dataclasses.replace(one, kind="decode"),
                                  mesh))
    bspec.pop("labels", None)
    bspec.pop("ctx", None)     # cross-attn context lives in the static cache
    bdim = bspec[next(iter(bspec))][0]
    token_mode = (cfg.decode_return == "token"
                  and dist.vocab_parallel(cfg))
    out0 = NamedSharding(mesh, P(bdim)) if token_mode \
        else NamedSharding(mesh, P(bdim, None))
    return ServeStep(
        fn=serve_decode,
        in_shardings=(named(mesh, pspec), named(mesh, sspec),
                      named(mesh, bspec), NamedSharding(mesh, P(bdim))),
        out_shardings=(out0, named(mesh, sspec)),
    )
