"""Deterministic synthetic LM data pipeline.

Documents are generated from a seeded order-2 Markov chain over the vocab
(so there IS learnable structure — the integration test asserts loss drops
well below uniform entropy), tokenized into fixed-length sequences with
next-token labels. Batches are addressed by (step, shard) so any rank can
materialize exactly its shard without coordination — the data-parallel
contract a real cluster loader needs (and what makes elastic restarts
reproducible: the schedule is a pure function of the step).

A background prefetch thread keeps ``depth`` batches ready.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64          # markov states (<= vocab)
    frontend: str = "tokens"    # tokens | frames
    d_model: int = 0            # for frames
    n_ctx_tokens: int = 0       # cross-attn context stub


class SyntheticLM:
    """Markov-chain token stream; batch(step, shard, n_shards) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        s = min(cfg.n_states, cfg.vocab_size)
        # sparse-ish row-stochastic transition matrix with strong modes
        logits = rng.normal(size=(s, s)) * 2.0
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        self.trans = p / p.sum(axis=1, keepdims=True)
        self.s = s

    def _gen_tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n, np.int64)
        x = int(rng.integers(self.s))
        for i in range(n):
            x = int(rng.choice(self.s, p=self.trans[x]))
            out[i] = x
        return out % self.cfg.vocab_size

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0
        b = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (cfg.seed, step, shard, n_shards))
        toks = np.stack([self._gen_tokens(rng, cfg.seq_len + 1)
                         for _ in range(b)])
        batch = {"labels": toks[:, 1:].astype(np.int32)}
        if cfg.frontend == "frames":
            emb_rng = np.random.default_rng((cfg.seed, 7))
            table = emb_rng.normal(size=(cfg.vocab_size, cfg.d_model)) \
                .astype(np.float32) * 0.1
            batch["frames"] = table[toks[:, :-1]]
        else:
            batch["tokens"] = toks[:, :-1].astype(np.int32)
        if cfg.n_ctx_tokens:
            batch["ctx"] = rng.normal(
                size=(b, cfg.n_ctx_tokens, cfg.d_model)).astype(np.float32)
        return batch


class Prefetcher:
    """Background thread keeping `depth` batches ready."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0, *,
                 shard: int = 0, n_shards: int = 1, depth: int = 2):
        self.ds = ds
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._shard = shard
        self._n_shards = n_shards
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = self.ds.batch(step, self._shard, self._n_shards)
            while not self._stop.is_set():
                try:
                    self.q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self.q.get(timeout=30)

    def stop(self):
        self._stop.set()


def for_model(model_cfg, shape, seed: int = 0) -> DataConfig:
    return DataConfig(
        vocab_size=model_cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        frontend=model_cfg.frontend,
        d_model=model_cfg.d_model,
        n_ctx_tokens=model_cfg.n_ctx_tokens,
    )
