"""Fault tolerance for the training loop.

Designed for thousands of nodes, exercised on CPU by simulation:

* step fencing       — checkpoints publish atomically (checkpoint.py);
                       restart resumes from LATEST and replays the data
                       schedule (a pure function of step), so an
                       interrupted run is BITWISE identical to an
                       uninterrupted one (tested).
* heartbeats         — every rank appends (step, wall_time) to a heartbeat
                       board; the monitor flags ranks whose last beat is
                       older than `deadline` (dead) or whose step lags the
                       median by > `lag_steps` (STRAGGLER).
* straggler policy   — 'warn' (log), 'skip' (continue without the
                       straggler's contribution — valid for DP replicas
                       when grads are averaged over contributing shards),
                       or 'restart' (fence + reload at last checkpoint).
* elastic re-mesh    — restore is layout-agnostic (full arrays per leaf),
                       so resuming on a different data-parallel width only
                       changes the batch sharding; tested by training on
                       n_shards=4, resuming on 2.
* failure injection  — FailureInjector raises at a chosen step to drive
                       the restart path in tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    fail_at_step: int | None = None
    fail_rank: int = 0
    fired: bool = False

    def check(self, step: int, rank: int = 0) -> None:
        if (not self.fired and self.fail_at_step is not None
                and step == self.fail_at_step and rank == self.fail_rank):
            self.fired = True
            raise InjectedFailure(f"injected failure at step {step}")


@dataclass
class Heartbeat:
    step: int
    t: float


@dataclass
class HeartbeatBoard:
    """In-memory stand-in for the heartbeat KV store (on a real cluster
    this is the coordination service; over cMPI it is an arena object that
    every rank writes at its own slot — single-writer, no atomics)."""
    n_ranks: int
    beats: dict[int, Heartbeat] = field(default_factory=dict)

    def beat(self, rank: int, step: int, t: float | None = None) -> None:
        self.beats[rank] = Heartbeat(step, time.monotonic() if t is None
                                     else t)

    def health(self, *, now: float | None = None, deadline: float = 10.0,
               lag_steps: int = 3) -> dict:
        now = time.monotonic() if now is None else now
        dead, stragglers = [], []
        steps = sorted(hb.step for hb in self.beats.values())
        median = steps[len(steps) // 2] if steps else 0
        for r in range(self.n_ranks):
            hb = self.beats.get(r)
            if hb is None or now - hb.t > deadline:
                dead.append(r)
            elif median - hb.step > lag_steps:
                stragglers.append(r)
        return {"dead": dead, "stragglers": stragglers, "median": median}


@dataclass
class ElasticPlan:
    """Decides the next world configuration after failures."""
    n_shards: int

    def after_failures(self, dead: list[int]) -> "ElasticPlan":
        healthy = self.n_shards - len(set(d % self.n_shards for d in dead))
        # keep a divisor-friendly width (batch divisibility)
        width = max(1, healthy)
        while self.n_shards % width:
            width -= 1
        return ElasticPlan(width)
