"""Optimizers: AdamW and Adafactor, with ZeRO-1-friendly state layout.

State tensors mirror the parameter pytree so ``opt_state_pspecs`` can assign
each moment the parameter's sharding plus an extra ``data`` shard (ZeRO-1).
Adafactor keeps the factored second moment for >=2D tensors — the
memory-lean choice for the >300B archs (Jamba) whose full Adam state would
not fit v5e HBM at 256 chips.

All update math is fp32; parameters stay in ``cfg.param_dtype``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    factored_dims_min: int = 128   # factor 2nd moment only if both dims >= this


def lr_at(oc: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(oc.warmup_steps, 1))
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(oc.decay_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos)


# --------------------------------------------------------------------------
# state init
# --------------------------------------------------------------------------

def _factored(shape, oc: OptConfig) -> bool:
    return (len(shape) >= 2
            and shape[-1] >= oc.factored_dims_min
            and shape[-2] >= oc.factored_dims_min)


def init(oc: OptConfig, params: Params) -> Params:
    if oc.name == "adamw":
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }
    if oc.name == "adafactor":
        def vr(p):
            if _factored(p.shape, oc):
                return jnp.zeros(p.shape[:-1], jnp.float32)       # row stats
            return jnp.zeros((), jnp.float32)

        def vc(p):
            if _factored(p.shape, oc):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)                # full 2nd mom

        return {
            "vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "count": jnp.zeros((), jnp.int32),
        }
    if oc.name == "sgd":
        return {"count": jnp.zeros((), jnp.int32)}
    raise ValueError(oc.name)


def state_specs(oc: OptConfig, param_shapes: Params) -> Params:
    return jax.eval_shape(lambda: init(oc, param_shapes))


# --------------------------------------------------------------------------
# update
# --------------------------------------------------------------------------

def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def apply_updates(oc: OptConfig, params: Params, grads: Params,
                  state: Params) -> tuple[Params, Params, dict[str, jax.Array]]:
    """One optimizer step. Returns (params, state, metrics)."""
    grads, gn = clip_by_global_norm(grads, oc.grad_clip)
    count = state["count"] + 1
    lr = lr_at(oc, state["count"])

    if oc.name == "adamw":
        b1, b2 = oc.b1, oc.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state["nu"], grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step = step + oc.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        new_state = {"mu": mu, "nu": nu, "count": count}

    elif oc.name == "adafactor":
        c = count.astype(jnp.float32)
        beta2 = 1.0 - c ** -0.8           # Adafactor's schedule
        eps = 1e-30

        def upd(p, g, vr, vc):
            g2 = jnp.square(g) + eps
            if _factored(p.shape, oc):
                nvr = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
                nvc = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
                denom = (nvr / jnp.maximum(nvr.mean(axis=-1, keepdims=True), eps)
                         )[..., None] * nvc[..., None, :]
                step = g / jnp.sqrt(jnp.maximum(denom, eps))
            else:
                nvr = beta2 * vr + (1 - beta2) * g2.mean()
                nvc = beta2 * vc + (1 - beta2) * g2
                step = g / jnp.sqrt(jnp.maximum(nvc, eps))
            # RMS update clipping (Adafactor d=1.0)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + eps)
            step = step / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                step = step + oc.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                    nvr, nvc)

        # flatten/unflatten (params trees contain real tuples — an
        # is_leaf=tuple tree.map would swallow them)
        p_leaves, treedef = jax.tree.flatten(params)
        g_leaves = jax.tree.leaves(grads)
        vr_leaves = jax.tree.leaves(state["vr"])
        vc_leaves = jax.tree.leaves(state["vc"])
        outs = [upd(p, g, vr, vc) for p, g, vr, vc in
                zip(p_leaves, g_leaves, vr_leaves, vc_leaves)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_state = {"vr": treedef.unflatten([o[1] for o in outs]),
                     "vc": treedef.unflatten([o[2] for o in outs]),
                     "count": count}

    elif oc.name == "sgd":
        new_params = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype),
            params, grads)
        new_state = {"count": count}
    else:
        raise ValueError(oc.name)

    return new_params, new_state, {"grad_norm": gn, "lr": lr}


def for_model(cfg) -> OptConfig:
    return OptConfig(name=cfg.optimizer)
