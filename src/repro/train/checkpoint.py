"""Checkpointing: sharded, async, restart-bitwise-identical; filesystem or
cMPI-arena backed.

Filesystem layout:
    <dir>/step_<N>/manifest.json       (step, leaf paths/shapes/dtypes)
    <dir>/step_<N>/leaf_<i>.npy
    <dir>/LATEST                       (atomic pointer, written LAST)

The LATEST pointer is renamed into place only after every shard fsyncs, so
a crash mid-save can never corrupt the restore point (step fencing).
``save_async`` runs serialization on a background thread (double-buffered:
the arrays are device_get'd synchronously — cheap — and written
asynchronously, so the train loop overlaps I/O with compute).

The ARENA backend checkpoints into cMPI shared-memory objects — the CXL
use case the paper cites for HPC (checkpointing into the pooled memory
[21, 22]): peers (or a restarted job on another node of the pod) restore
via cxl_shm_open without touching a filesystem.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path

import jax
import numpy as np

from repro.core.arena import Arena


# --------------------------------------------------------------------------
# filesystem backend
# --------------------------------------------------------------------------

class CheckpointManager:
    def __init__(self, directory: str | Path):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._sweep_stale_tmps()

    def _sweep_stale_tmps(self) -> None:
        """Remove .LATEST.<pid>.<tid>.tmp leftovers from writers that
        died between write and rename. Only files from DEAD processes
        are swept — a live writer (this process's own async thread, or
        a concurrent run) must keep its tmp until its atomic rename."""
        for p in self.dir.glob(".LATEST.*.tmp"):
            try:
                pid = int(p.name.split(".")[2])
                os.kill(pid, 0)                 # raises if pid is gone
            except (IndexError, ValueError, ProcessLookupError):
                try:
                    p.unlink()
                except FileNotFoundError:
                    pass
            except PermissionError:
                pass                            # pid alive, not ours

    # ---------------- save ----------------
    def save(self, step: int, tree) -> None:
        leaves, treedef = jax.tree.flatten(tree)
        arrs = [np.asarray(x) for x in leaves]
        self._write(step, arrs, treedef)

    def save_async(self, step: int, tree) -> None:
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        arrs = [np.asarray(x) for x in leaves]     # device_get now
        self._thread = threading.Thread(
            target=self._write, args=(step, arrs, treedef), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, arrs, treedef) -> None:
        d = self.dir / f"step_{step}"
        d.mkdir(parents=True, exist_ok=True)
        manifest = {"step": step, "treedef": str(treedef),
                    "leaves": []}
        for i, a in enumerate(arrs):
            np.save(d / f"leaf_{i}.npy", a)
            manifest["leaves"].append(
                {"i": i, "shape": list(a.shape), "dtype": str(a.dtype)})
        (d / "manifest.json").write_text(json.dumps(manifest))
        # unique tmp per writer: an abandoned async writer (e.g. a run
        # killed mid-save) and a resumed run's writer must never race on
        # one tmp path — the rename itself stays the atomic publish
        tmp = self.dir / f".LATEST.{os.getpid()}.{threading.get_ident()}.tmp"
        try:
            tmp.write_text(str(step))
            os.replace(tmp, self.dir / "LATEST")   # atomic publish
        except BaseException:
            tmp.unlink(missing_ok=True)
            raise

    # ---------------- restore ----------------
    def latest_step(self) -> int | None:
        p = self.dir / "LATEST"
        if not p.exists():
            return None
        return int(p.read_text().strip())

    def restore(self, tree_like, step: int | None = None):
        if step is None:
            step = self.latest_step()
            if step is None:
                return None, None
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = jax.tree.flatten(tree_like)
        assert len(leaves) == len(manifest["leaves"]), \
            "checkpoint/model structure mismatch"
        out = [np.load(d / f"leaf_{i}.npy")
               for i in range(len(leaves))]
        restored = treedef.unflatten([
            jax.numpy.asarray(a, dtype=l.dtype)
            for a, l in zip(out, leaves)])
        return step, restored


# --------------------------------------------------------------------------
# cMPI arena backend — checkpoint into the shared pool
# --------------------------------------------------------------------------

class ArenaCheckpoint:
    """Checkpoints as named arena objects: ``<tag>:manifest`` (JSON) and
    ``<tag>:leaf<i>`` (raw bytes). A restarted rank (or a peer node sharing
    the pool) restores via open() — no filesystem, no network."""

    def __init__(self, arena: Arena, tag: str = "ckpt"):
        self.arena = arena
        self.tag = tag

    def _destroy_if_exists(self, name: str) -> None:
        try:
            self.arena.destroy(self.arena.open(name))
        except FileNotFoundError:
            pass

    def save(self, step: int, tree) -> None:
        leaves, _ = jax.tree.flatten(tree)
        manifest = {"step": step, "leaves": []}
        for i, x in enumerate(leaves):
            a = np.asarray(x)
            name = f"{self.tag}:leaf{i}"
            self._destroy_if_exists(name)
            h = self.arena.create(name, max(a.nbytes, 1))
            self.arena.write(h, 0, a.tobytes())
            manifest["leaves"].append(
                {"shape": list(a.shape), "dtype": str(a.dtype)})
        mb = json.dumps(manifest).encode()
        self._destroy_if_exists(f"{self.tag}:manifest")
        h = self.arena.create(f"{self.tag}:manifest", len(mb))
        self.arena.write(h, 0, mb)       # manifest LAST: publication order

    def restore(self, tree_like):
        h = self.arena.open(f"{self.tag}:manifest")
        manifest = json.loads(self.arena.read(h, 0, h.size))
        leaves, treedef = jax.tree.flatten(tree_like)
        out = []
        for i, (meta, leaf) in enumerate(zip(manifest["leaves"], leaves)):
            lh = self.arena.open(f"{self.tag}:leaf{i}")
            raw = self.arena.read(lh, 0, lh.size)
            a = np.frombuffer(raw, dtype=np.dtype(meta["dtype"]))
            a = a[: int(np.prod(meta["shape"]))].reshape(meta["shape"])
            out.append(jax.numpy.asarray(a, dtype=leaf.dtype))
        return manifest["step"], treedef.unflatten(out)
