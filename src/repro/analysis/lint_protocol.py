"""AST linter for the shared-memory protocol discipline in ``core/``.

The communication core's correctness rests on conventions no type
checker sees: every store to the shared pool must go through the
coherence protocol, every user-facing tag must stay out of the
reserved internal window, the progress engine must never block inside
a tick, and each matchbox entry field has exactly ONE writing side.
This module enforces those conventions mechanically, as five rules
over the ASTs of ``src/repro/core``:

``LP001`` raw shared-region access
    Calls to the protocol-bypassing primitives (``raw_write`` /
    ``raw_read`` and direct ``.pool.write`` / ``.pool.read`` /
    ``.backing.write`` / ``.backing.read`` chains) are only legal
    inside the coherence layer itself (``coherence.py``, ``pool.py``).
    Elsewhere they need an explicit ``# lint: raw-ok (<why>)`` waiver
    on the line — today only the arena's pre-publication init and its
    advisory stats snapshot qualify.

``LP002`` reserved-tag validation
    Every PUBLIC send/recv surface that accepts a ``tag`` must
    (transitively) validate it against ``TAG_RESERVED_BASE`` — a
    surface that forwards user tags unchecked lets user traffic forge
    collective-round matches. The rule builds a call graph across all
    linted files (calls resolve by bare name; instantiating a class
    counts as reaching its methods, which is how ``send_init`` ->
    ``PersistentRequest.start`` -> ``isend`` validates) and runs a
    reachability fixpoint to the validation sites.

``LP003`` no blocking sleeps in tick paths
    ``progress.py`` runs cooperatively: every wait loop must tick the
    engine and may only yield (``time.sleep(0)``). Any sleep with a
    nonzero or non-literal argument would stall EVERY outstanding
    request on the rank.

``LP004`` matchbox single-writer discipline
    The 64-byte matchbox entry is split receiver-owned
    (``post_id``/``_MB_TAG``/``_MB_DEST``/``_MB_CAP``) and
    sender-owned (``_MB_CLAIM``/``_MB_FILL``) — the Dekker-style
    claim/retract handshake is only correct if each side stores only
    to its own fields. Every function that nt-stores a matchbox field
    must carry a ``# mb-writer: sender`` or ``# mb-writer: receiver``
    annotation on (or just above) its ``def`` line, and the stored
    fields must belong to the annotated side.

``LP005`` guarded, allocation-free trace emission
    The flight recorder (``core/trace.py``) is always compiled in;
    its disabled-mode cost budget is ONE predicate check per site. In
    the tick-path files (``progress.py``, ``pt2pt.py``) every
    ``emit(...)`` call must therefore sit lexically inside an ``if``
    whose test checks the ``.enabled`` predicate, and its arguments
    must be plain names/ints — no f-strings, dict/list/set displays,
    comprehensions or ``dict()`` calls, which would allocate eagerly
    on every pass even while tracing is off.

CLI: ``python -m repro.analysis.lint_protocol [paths...]`` (defaults
to ``src/repro/core``); prints ``path:line: LPxxx message`` per
finding and exits nonzero if any were found.
"""
from __future__ import annotations

import argparse
import ast
import re
from dataclasses import dataclass
from pathlib import Path

__all__ = ["LintFinding", "lint_paths", "lint_sources"]

_RAW_FUNCS = {"raw_write", "raw_read"}
_RAW_CHAINS = {"pool", "backing"}          # .pool.write(...) etc.
_RAW_ALLOWED_FILES = {"coherence.py", "pool.py"}
_RAW_WAIVER = re.compile(r"#\s*lint:\s*raw-ok")

_SURFACE_RE = re.compile(r"^i?(send|recv)(_[a-z0-9_]+)?$")
_RESERVED_NAME = "TAG_RESERVED_BASE"

_TICK_FILES = {"progress.py"}

_MB_SENDER_FIELDS = {"_MB_CLAIM", "_MB_FILL"}
_MB_RECEIVER_FIELDS = {"_MB_TAG", "_MB_DEST", "_MB_CAP"}
_MB_WRITER = re.compile(r"#\s*mb-writer:\s*(sender|receiver)")

_TRACE_FILES = {"progress.py", "pt2pt.py"}
_EMIT_ARG_BANNED = (ast.JoinedStr, ast.Dict, ast.DictComp, ast.ListComp,
                    ast.SetComp, ast.GeneratorExp)


@dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


# --------------------------------------------------------------------------
# per-function facts for the cross-file call graph (LP002)
# --------------------------------------------------------------------------

@dataclass(eq=False)        # identity hash: distinct defs stay distinct
class _FuncInfo:
    name: str
    cls: str | None
    path: str
    line: int
    params: set
    calls: set            # bare names of everything this function calls
    validates: bool       # references TAG_RESERVED_BASE anywhere


def _called_names(tree: ast.AST) -> set:
    out = set()
    for nd in ast.walk(tree):
        if isinstance(nd, ast.Call):
            f = nd.func
            if isinstance(f, ast.Name):
                out.add(f.id)
            elif isinstance(f, ast.Attribute):
                out.add(f.attr)
    return out


def _collect_funcs(path: str, tree: ast.Module, funcs: list,
                   classes: dict) -> None:
    def visit(node, cls):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                classes.setdefault(child.name, set())
                visit(child, child.name)
            elif isinstance(child, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                a = child.args
                params = {p.arg for p in (a.posonlyargs + a.args
                                          + a.kwonlyargs)}
                validates = any(
                    (isinstance(nd, ast.Name) and nd.id == _RESERVED_NAME)
                    or (isinstance(nd, ast.Attribute)
                        and nd.attr == _RESERVED_NAME)
                    for nd in ast.walk(child))
                funcs.append(_FuncInfo(child.name, cls, path,
                                       child.lineno, params,
                                       _called_names(child), validates))
                if cls is not None:
                    classes[cls].add(child.name)
                visit(child, cls)   # nested defs can also be surfaces

    visit(tree, None)


def _check_reserved_tags(funcs: list, classes: dict, out: list) -> None:
    by_name: dict = {}
    for f in funcs:
        by_name.setdefault(f.name, []).append(f)

    ok = {id(f) for f in funcs if f.validates}
    changed = True
    while changed:
        changed = False
        for f in funcs:
            if id(f) in ok:
                continue
            reach = set()
            for callee in f.calls:
                reach.update(by_name.get(callee, ()))
                # instantiating a class reaches its methods (the
                # request object the surface returns does the send)
                for m in classes.get(callee, ()):
                    reach.update(by_name.get(m, ()))
            if any(id(g) in ok for g in reach):
                ok.add(id(f))
                changed = True

    for f in funcs:
        if (not f.name.startswith("_") and _SURFACE_RE.match(f.name)
                and "tag" in f.params and id(f) not in ok):
            out.append(LintFinding(
                "LP002", f.path, f.line,
                f"user-facing surface {f.name}() accepts a tag but "
                f"never validates it against {_RESERVED_NAME} (nor "
                f"delegates to a surface that does)"))


# --------------------------------------------------------------------------
# single-file rules
# --------------------------------------------------------------------------

def _check_raw_access(path: str, fname: str, tree: ast.Module,
                      lines: list, out: list) -> None:
    if fname in _RAW_ALLOWED_FILES:
        return
    for nd in ast.walk(tree):
        if not (isinstance(nd, ast.Call)
                and isinstance(nd.func, ast.Attribute)):
            continue
        f = nd.func
        bad = f.attr in _RAW_FUNCS or (
            f.attr in ("write", "read")
            and isinstance(f.value, ast.Attribute)
            and f.value.attr in _RAW_CHAINS)
        if not bad:
            continue
        span = lines[nd.lineno - 1:(nd.end_lineno or nd.lineno)]
        if any(_RAW_WAIVER.search(ln) for ln in span):
            continue
        chain = (f.attr if f.attr in _RAW_FUNCS
                 else f"{f.value.attr}.{f.attr}")
        out.append(LintFinding(
            "LP001", path, nd.lineno,
            f"shared-region access bypasses the coherence protocol "
            f"({chain}); use CoherentView write_release/read_acquire/"
            f"nt-store helpers or add '# lint: raw-ok (<why>)'"))


def _check_tick_sleeps(path: str, fname: str, tree: ast.Module,
                       out: list) -> None:
    if fname not in _TICK_FILES:
        return
    for nd in ast.walk(tree):
        if not isinstance(nd, ast.Call):
            continue
        f = nd.func
        is_sleep = (isinstance(f, ast.Attribute) and f.attr == "sleep") \
            or (isinstance(f, ast.Name) and f.id == "sleep")
        if not is_sleep:
            continue
        arg = nd.args[0] if nd.args else None
        if isinstance(arg, ast.Constant) and arg.value == 0:
            continue                      # bare yield — legal
        out.append(LintFinding(
            "LP003", path, nd.lineno,
            "blocking sleep in a progress tick path — wait loops must "
            "tick cooperatively and only yield via time.sleep(0)"))


def _mb_store_side(nd: ast.Call, fn_calls_entry_off: bool) -> str | None:
    """Classify an ``nt_store_*`` call as targeting a sender- or
    receiver-owned matchbox field, or None when it does not store to a
    matchbox entry at all."""
    if not (isinstance(nd.func, ast.Attribute)
            and nd.func.attr.startswith("nt_store") and nd.args):
        return None
    off = nd.args[0]
    names = {n.id for n in ast.walk(off) if isinstance(n, ast.Name)}
    if names & _MB_SENDER_FIELDS:
        return "sender"
    if names & _MB_RECEIVER_FIELDS:
        return "receiver"
    # a bare offset in an entry_off-computing function is the post_id
    # word at entry offset 0 — receiver-owned (the publish/retract word)
    if isinstance(off, ast.Name) and fn_calls_entry_off:
        return "receiver"
    return None


def _check_mb_single_writer(path: str, tree: ast.Module, lines: list,
                            out: list) -> None:
    def annotation(fn) -> str | None:
        for ln in range(fn.lineno, max(fn.lineno - 3, 0), -1):
            m = _MB_WRITER.search(lines[ln - 1])
            if m:
                return m.group(1)
        return None

    def own_nodes(fn):
        # this function's own statements — nested defs are annotated
        # (and checked) separately
        stack = list(ast.iter_child_nodes(fn))
        while stack:
            nd = stack.pop()
            if isinstance(nd, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            yield nd
            stack.extend(ast.iter_child_nodes(nd))

    def visit(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                own = list(own_nodes(child))
                calls_entry_off = any(
                    isinstance(nd, ast.Call)
                    and ((isinstance(nd.func, ast.Attribute)
                          and nd.func.attr == "entry_off")
                         or (isinstance(nd.func, ast.Name)
                             and nd.func.id == "entry_off"))
                    for nd in own)
                role = annotation(child)
                for nd in own:
                    if not isinstance(nd, ast.Call):
                        continue
                    side = _mb_store_side(nd, calls_entry_off)
                    if side is None:
                        continue
                    if role is None:
                        out.append(LintFinding(
                            "LP004", path, nd.lineno,
                            f"matchbox field store in unannotated "
                            f"function {child.name}() — declare the "
                            f"owning side with '# mb-writer: {side}' "
                            f"on the def line"))
                    elif role != side:
                        out.append(LintFinding(
                            "LP004", path, nd.lineno,
                            f"{child.name}() is annotated mb-writer: "
                            f"{role} but stores a {side}-owned "
                            f"matchbox field — single-writer "
                            f"discipline violated"))
            visit(child)

    visit(tree)


def _mentions_enabled(test: ast.AST) -> bool:
    for nd in ast.walk(test):
        if isinstance(nd, ast.Attribute) and nd.attr == "enabled":
            return True
        if isinstance(nd, ast.Name) and nd.id == "enabled":
            return True
    return False


def _check_trace_guards(path: str, fname: str, tree: ast.Module,
                        out: list) -> None:
    if fname not in _TRACE_FILES:
        return

    def check_emit(nd: ast.Call, guarded: bool) -> None:
        if not guarded:
            out.append(LintFinding(
                "LP005", path, nd.lineno,
                "trace emit() in a tick path outside an '.enabled' "
                "guard — disabled-mode cost must be one predicate "
                "check (tr = self.tracer; if tr.enabled: tr.emit(...))"))
        for a in list(nd.args) + [kw.value for kw in nd.keywords]:
            if any(isinstance(sub, _EMIT_ARG_BANNED)
                   or (isinstance(sub, ast.Call)
                       and isinstance(sub.func, ast.Name)
                       and sub.func.id == "dict")
                   for sub in ast.walk(a)):
                out.append(LintFinding(
                    "LP005", path, a.lineno,
                    "trace emit() argument builds an f-string/dict/"
                    "comprehension — arguments must be plain names or "
                    "ints (records are five int64 words; formatting "
                    "belongs in the exporter)"))
                break

    def visit(node: ast.AST, guarded: bool) -> None:
        if isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr == "emit") or \
                    (isinstance(f, ast.Name) and f.id == "emit"):
                check_emit(node, guarded)
        if isinstance(node, ast.If) and _mentions_enabled(node.test):
            for b in node.body:
                visit(b, True)
            for b in node.orelse:
                visit(b, guarded)
            return
        for child in ast.iter_child_nodes(node):
            visit(child, guarded)

    visit(tree, False)


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------

def lint_sources(sources: dict) -> list:
    """Lint ``{path: source_text}``; returns sorted findings. Split
    from ``lint_paths`` so tests can feed synthetic bad code."""
    out: list = []
    funcs: list = []
    classes: dict = {}
    for path, text in sorted(sources.items()):
        fname = Path(path).name
        try:
            tree = ast.parse(text, filename=path)
        except SyntaxError as e:
            out.append(LintFinding("LP000", path, e.lineno or 0,
                                   f"syntax error: {e.msg}"))
            continue
        lines = text.splitlines()
        _collect_funcs(path, tree, funcs, classes)
        _check_raw_access(path, fname, tree, lines, out)
        _check_tick_sleeps(path, fname, tree, out)
        _check_mb_single_writer(path, tree, lines, out)
        _check_trace_guards(path, fname, tree, out)
    _check_reserved_tags(funcs, classes, out)
    return sorted(out, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths) -> list:
    sources = {}
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            sources[str(f)] = f.read_text()
    return lint_sources(sources)


def _default_target() -> Path:
    return Path(__file__).resolve().parent.parent / "core"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="shared-memory protocol discipline linter")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src/repro/core)")
    args = ap.parse_args(argv)
    paths = args.paths or [_default_target()]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    print(f"lint_protocol: {len(findings)} finding(s) in "
          f"{', '.join(str(p) for p in paths)}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
