"""HLO-level analysis: call-graph walker + roofline terms.

This is the dry-run 'profiler': there is no TPU wall clock, so the three
roofline terms are derived from the compiled (SPMD-partitioned, per-device)
HLO module —

  compute term    = HLO_FLOPs_per_device / peak_FLOPs        [s]
  memory term     = HLO_bytes_per_device / HBM_bw            [s]
  collective term = wire_bytes_per_device / link_bw          [s]

CRITICAL: XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
so anything under ``lax.scan`` (layer stacks, grad-accum microbatches,
chunked attention) is undercounted by the trip count. We therefore parse the
HLO text into its computation call graph, derive trip counts from while
conditions, and scale every nested computation's FLOPs / bytes / collective
traffic by the product of enclosing trip counts.

Per-instruction accounting (post-fusion, per-device module):

  * FLOPs  — dot: 2 * prod(out dims) * prod(lhs contracting dims); operand
    shapes resolved through a per-computation symbol table (post-opt HLO
    omits operand shapes inline). conv: 2 * prod(out) * window.
  * bytes  — output + resolved operand buffer sizes for every top-level
    instruction, excluding view/plumbing ops (parameter, GTE, tuple,
    bitcast, constant). dynamic-update-slice counts the update slice, not
    the aliased full buffer (XLA updates in place inside scan bodies).
    This is an HBM-traffic proxy (no cache modeling) — consistent across
    variants, which is what hillclimbing needs.
  * wire   — ring-algorithm factors per collective kind (per device):
               all-reduce          2(S-1)/S * buffer
               all-gather          (S-1)/S  * result
               reduce-scatter      (S-1)    * result   (= (S-1)/S * input)
               all-to-all          (S-1)/S  * buffer
               collective-permute  1        * buffer
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\)|[a-z]\w*\[[\d,]*\](?:\{[\d,]*\})?))")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLED_RE = re.compile(
    r"(?:to_apply|calls|body|condition|branch_computations|"
    r"true_computation|false_computation)="
    r"(?:\{([^}]*)\}|%?([\w\.\-]+))")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*?)\}")
_GROUPS_ID_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_VIEW_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota",
             "opt-barrier", "optimization-barrier"}
_COLL_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute", "all-reduce-start", "all-gather-start",
             "collective-permute-start", "reduce-scatter-start",
             "all-to-all-start"}


def shape_bytes(shape_str: str) -> int:
    """Total bytes of 'bf16[2,3]{1,0}' or '(f32[4], s32[])' strings."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_ID_RE.search(line)
    if m:  # iota format [n_groups,group_size]
        return int(m.group(2))
    return 1


def _wire_bytes(kind: str, rb: int, s: int) -> float:
    if kind.startswith("collective-permute"):
        return float(rb)
    if s <= 1:
        return 0.0
    if kind.startswith("all-reduce"):
        return 2.0 * (s - 1) / s * rb
    if kind.startswith("all-gather"):
        return (s - 1) / s * rb
    if kind.startswith("reduce-scatter"):
        return float(s - 1) * rb
    if kind.startswith("all-to-all"):
        return (s - 1) / s * rb
    return float(rb)


# --------------------------------------------------------------------------
# module parsing
# --------------------------------------------------------------------------

@dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str
    operands: list[str]


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    instrs: list[Instr] = field(default_factory=list)
    symbols: dict[str, str] = field(default_factory=dict)   # name -> shape
    # if the root is a dynamic-update-slice (in-place scan-carry write),
    # callers must charge the UPDATE size, not the aliased full buffer
    root_dus_update: int | None = None
    # local (unscaled) stats, filled by _local_stats
    flops: float = 0.0
    bytes_: float = 0.0
    wire: dict[str, float] = field(default_factory=dict)
    coll_counts: dict[str, int] = field(default_factory=dict)
    coll_ops: list[tuple[str, int, int]] = field(default_factory=list)
    calls: list[tuple[str, str]] = field(default_factory=list)
    while_cond: dict[str, str] = field(default_factory=dict)


def _operand_names(line: str, op_end: int) -> list[str]:
    """Names referenced inside op( ... ) — up to the closing paren."""
    depth = 0
    i = op_end - 1            # index of '('
    end = len(line)
    for j in range(i, len(line)):
        ch = line[j]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = j
                break
    return _OPERAND_RE.findall(line[i:end])


def _parse_instr(s: str) -> Instr | None:
    """Parse '%name = SHAPE op(args...), attrs' with balanced-paren shape
    handling (tuple shapes contain '/*index=N*/' comments)."""
    m = _INSTR_HEAD_RE.match(s)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(s):
        return None
    if s[i] == "(":               # tuple shape
        depth = 0
        j = i
        while j < len(s):
            if s[j] == "(":
                depth += 1
            elif s[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        shape = s[i:j + 1]
        rest = s[j + 1:]
    else:
        sp = s.find(" ", i)
        if sp < 0:
            return None
        shape = s[i:sp]
        rest = s[sp:]
    mo = re.match(r"\s*([\w\-]+)\(", rest)
    if not mo:
        return None
    op = mo.group(1)
    op_paren = len(s) - len(rest) + mo.end()
    return Instr(name, shape, op, s, _operand_names(s, op_paren))


def _split_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    depth = 0
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
                is_entry = s.startswith("ENTRY")
                body = s[len("ENTRY"):].strip() if is_entry else s
                name = body.split()[0].lstrip("%").split("(")[0]
                cur = Computation(name=name, is_entry=is_entry)
                depth = 1
                # parameters into the symbol table
                for pname, pshape in _PARAM_RE.findall(s):
                    cur.symbols[pname] = pshape
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            comps[cur.name] = cur
            cur = None
            continue
        ins = _parse_instr(s)
        if ins is not None:
            cur.symbols[ins.name] = ins.shape
            cur.instrs.append(ins)
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _find_root_dus(c: Computation) -> None:
    """Detect fusions whose root writes a slice in place (scan carries)."""
    for ins in c.instrs:
        if "ROOT" in ins.line.split("=", 1)[0] or ins is c.instrs[-1]:
            if ins.op == "dynamic-update-slice" and len(ins.operands) >= 2:
                c.root_dus_update = shape_bytes(
                    c.symbols.get(ins.operands[1], ""))
            return


def _local_stats(c: Computation, comps: dict[str, "Computation"]
                 | None = None) -> None:
    comps = comps or {}
    for ins in c.instrs:
        op = ins.op
        # ---- flops
        if op == "dot":
            out_n = 1
            for d in _shape_dims(ins.shape):
                out_n *= d
            k = 1
            mc = _CONTRACT_RE.search(ins.line)
            if mc and ins.operands:
                lhs_shape = c.symbols.get(ins.operands[0], "")
                lhs_dims = _shape_dims(lhs_shape)
                for ci in (int(x) for x in mc.group(1).split(",") if x):
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
            c.flops += 2.0 * out_n * k
        elif op == "convolution":
            out_n = 1
            for d in _shape_dims(ins.shape):
                out_n *= d
            kn = 1
            if len(ins.operands) >= 2:
                kd = _shape_dims(c.symbols.get(ins.operands[1], ""))
                for d in kd[:-1]:
                    kn *= d
            c.flops += 2.0 * out_n * kn

        # ---- collectives
        if op in _COLL_OPS:
            rb = shape_bytes(ins.shape)
            s = _group_size(ins.line)
            kind = op.replace("-start", "")
            c.wire[kind] = c.wire.get(kind, 0.0) + _wire_bytes(kind, rb, s)
            c.coll_counts[kind] = c.coll_counts.get(kind, 0) + 1
            c.coll_ops.append((kind, rb, s))

        # ---- call edges
        for grp, single in _CALLED_RE.findall(ins.line):
            names = ([single.lstrip("%")] if single else
                     [x.strip().lstrip("%") for x in grp.split(",")
                      if x.strip()])
            if op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mc2 = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                body = mb.group(1) if mb else None
                cond = mc2.group(1) if mc2 else None
                if body and (body, "while") not in c.calls:
                    c.calls.append((body, "while"))
                    if cond:
                        c.while_cond[body] = cond
            else:
                kind = "fusion" if op == "fusion" else "call"
                for n in names:
                    if (n, kind) not in c.calls:
                        c.calls.append((n, kind))

        # ---- memory traffic
        if op in _VIEW_OPS or op == "while":
            continue   # while carry traffic is accounted inside the body
        if op == "dynamic-update-slice" and len(ins.operands) >= 2:
            upd = shape_bytes(c.symbols.get(ins.operands[1], ""))
            c.bytes_ += 2.0 * upd          # read update + write slice
            continue
        if op == "fusion":
            # a fusion whose root is a DUS aliases its big operand in
            # place: charge the update slice, not the full buffer
            callee = None
            mcall = re.search(r"calls=%?([\w\.\-]+)", ins.line)
            if mcall:
                callee = comps.get(mcall.group(1))
            if callee is not None and callee.root_dus_update is not None:
                big = shape_bytes(ins.shape)
                in_b = sum(shape_bytes(c.symbols.get(o, ""))
                           for o in ins.operands)
                # drop the aliased buffer from both sides
                c.bytes_ += max(in_b - big, 0) + 2.0 * callee.root_dus_update
                continue
        out_b = shape_bytes(ins.shape)
        in_b = sum(shape_bytes(c.symbols.get(o, "")) for o in ins.operands)
        c.bytes_ += out_b + in_b


def _trip_count(cond: Computation) -> int | None:
    consts = []
    for ins in cond.instrs:
        consts += [int(x) for x in _CONST_RE.findall(ins.line)]
    if consts:
        return max(consts)
    return None


@dataclass
class ModuleStats:
    flops: float = 0.0
    bytes_: float = 0.0
    wire_bytes: dict[str, float] = field(default_factory=dict)
    coll_counts: dict[str, float] = field(default_factory=dict)
    top_ops: list[tuple[str, int, int, float]] = field(default_factory=list)
    top_bytes_ops: list[tuple[str, float, float]] = field(
        default_factory=list)       # (op, scaled bytes, mult)
    unparsed_while: int = 0

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def analyze_module(text: str) -> ModuleStats:
    comps = _split_computations(text)
    for c in comps.values():
        _find_root_dus(c)
    for c in comps.values():
        _local_stats(c, comps)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None and comps:
        entry = next(iter(comps.values()))

    stats = ModuleStats()
    top_ops: list[tuple[str, int, int, float]] = []
    top_bytes: list[tuple[str, float, float]] = []
    stack: set[str] = set()

    def walk(name: str, mult: float, in_fusion: bool):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        stack.add(name)
        stats.flops += comp.flops * mult
        if not in_fusion:
            stats.bytes_ += comp.bytes_ * mult
            for ins in comp.instrs:
                if ins.op in _VIEW_OPS or ins.op == "while":
                    continue
                ob = shape_bytes(ins.shape)
                if ins.op == "fusion":           # DUS-root fusions alias
                    mc = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                    callee = comps.get(mc.group(1)) if mc else None
                    if callee is not None and \
                            callee.root_dus_update is not None:
                        ob = 2 * callee.root_dus_update
                if ob * mult > 1 << 28:          # track >256MiB-equivalents
                    top_bytes.append(
                        (f"{ins.op}:{ins.shape[:48]}", ob * mult, mult))
        for k, v in comp.wire.items():
            stats.wire_bytes[k] = stats.wire_bytes.get(k, 0.0) + v * mult
        for k, v in comp.coll_counts.items():
            stats.coll_counts[k] = stats.coll_counts.get(k, 0.0) + v * mult
        for kind, rb, s in comp.coll_ops:
            top_ops.append((kind, rb, s, mult))
        for callee, kind in comp.calls:
            m2 = mult
            f2 = in_fusion or kind == "fusion"
            if kind == "while":
                cond_name = comp.while_cond.get(callee)
                trip = None
                if cond_name and cond_name in comps:
                    trip = _trip_count(comps[cond_name])
                if trip is None:
                    stats.unparsed_while += 1
                    trip = 1
                m2 = mult * trip
            walk(callee, m2, f2)
        stack.discard(name)

    if entry is not None:
        walk(entry.name, 1.0, False)
    top_ops.sort(key=lambda t: -(t[1] * t[3]))
    stats.top_ops = top_ops[:12]
    top_bytes.sort(key=lambda t: -t[1])
    stats.top_bytes_ops = top_bytes[:12]
    return stats


# --------------------------------------------------------------------------
# roofline
# --------------------------------------------------------------------------

@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_per_device: float

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundancy waste."""
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.flops_per_device

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute seconds / max(all terms): what fraction of the
        compute roofline the step achieves if the dominant term is the
        critical path."""
        dom = max(self.compute_s, self.memory_s, self.collective_s)
        if dom <= 0:
            return 0.0
        return (self.model_flops_per_device / PEAK_FLOPS) / dom

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops_per_device": self.model_flops_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, chips: int) -> float:
    """Analytic MODEL_FLOPS for the step, per device.

    train: 6 * N_active * tokens      (fwd 2N + bwd 4N)
    prefill: 2 * N_active * tokens
    decode: 2 * N_active * batch      (one token per sequence)
    """
    n_active = cfg.param_counts()["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:
        total = 2.0 * n_active * shape.global_batch
    return total / chips
