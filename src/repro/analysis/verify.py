"""Cross-rank static verification of compiled collective schedules.

``repro.core.sched`` compiles each collective into a per-rank DAG of
Send/Recv/Reduce/Copy nodes, and the protocol's correctness rests on
invariants that span RANKS: every send must meet exactly one matching
receive, the union of all ranks' dependency edges plus the wire edges
must stay acyclic, no two unordered node executions may touch the same
bytes with a write, and no execution may demand more matchbox depth or
tag space than the runtime provisions. PR 5's fuzz suite exercises
those properties at runtime; this module PROVES them per config by
compiling the schedule for all ranks and model-checking the result —
cheap enough to sweep the whole compiler matrix in CI.

The checks (one ``Finding.code`` per failure class):

``invariant``
    a rank's schedule fails ``Schedule.validate()`` (forward/self dep,
    round outside span) — the per-rank structural floor, reused from
    ``core.sched.ScheduleInvariantError``.
``rounds-mismatch``
    ranks disagree on the tag span or chunk size. Wire tags are
    ``tag_base + round``; a span disagreement silently cross-matches
    adjacent collectives.
``tag-window``
    the (sub-)round count exceeds ``MAX_ROUNDS`` — the per-launch tag
    window — so two in-flight launches could collide.
``orphan-send`` / ``orphan-recv`` / ``duplicate-match`` / ``size-mismatch``
    send/recv matching is not a size-preserving bijection on
    ``(src, dst, round)`` keys.
``deadlock``
    the global happens-before graph has a cycle. Every node is split
    into an ISSUE and a COMPLETE event: deps order ``complete(dep) ->
    issue(node)``; a matched pair adds ``issue(send) -> complete(recv)``
    (data cannot land before the sender starts) and ``issue(recv) ->
    complete(send)`` (rendezvous: a pool-resident send drains only once
    the receive is posted — the synchronous-mode conservative model).
    Dependency-free receives therefore pre-post correctly: their issue
    event has no prerequisites, which is exactly how the progress
    engine primes the matchbox.
``buffer-hazard``
    two accesses on one rank overlap in a slot, at least one writes,
    and neither is an ancestor of the other — an unordered WAR/WAW/RAW
    pair the engine could execute in either order.
``unchained-send``
    two payload-carrying sends source the same slot without a
    dependency path between them. A ``PoolBuffer`` has ONE drain-ack
    word, so at most one send per underlying buffer may be in flight;
    zero-byte sends (the dissemination barrier) are exempt — they never
    take the pool path.
``depth-overflow``
    a peer needs more concurrent receive postings than the declared
    matchbox demand (``Schedule.required_matchbox_depth`` is the single
    source of truth; ``comm.py`` derives persistent demand from it).

One-sided schedules (``rput``/``rget``/``raccumulate``/
``allgather_get``/``bcast_put``) verify under the SAME checks: their Put/Get nodes are engine-local
(the shared-memory store IS the transfer, so they never enter the
send/recv bijection), while all cross-rank ordering they need rides on
zero-byte Send/Recv token pairs — which the matching, deadlock and
depth checks see as ordinary wire traffic. Put reads its staging
region, Get writes it, so the hazard check orders one-sided data
movement exactly like Reduce/Copy.

What this does NOT prove: value correctness (reduce order, padding),
liveness of the runtime engine, or races in the matchbox claim
protocol itself — those stay with the runtime fuzz suite and the
``lint_protocol`` discipline linter. For one-sided schedules it also
does not model WINDOW-segment overlap across collectives (epoch
discipline — fence/PSCW/lock — owns that, as in MPI).

Entry points: ``verify_config`` for one config, ``sweep`` /
``iter_matrix`` for the full compiler matrix, ``compile_group`` +
``verify_schedules`` when the schedules are built by hand (mutation
tests). CLI: ``python -m repro.analysis.verify [--max-n N]``.
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass, field

from repro.core.sched import (MAX_ROUNDS, GetOp, PutOp, RecvOp, Schedule,
                              ScheduleInvariantError, SendOp,
                              compile_schedule)

__all__ = ["Finding", "VerificationReport", "compile_group",
           "verify_schedules", "verify_config", "iter_matrix", "sweep"]


# --------------------------------------------------------------------------
# findings
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Finding:
    """One verification failure. ``code`` is the machine-checkable
    failure class (see module docstring); ``rank``/``node`` locate the
    offending node when the failure is attributable to one."""
    code: str
    message: str
    rank: int | None = None
    node: int | None = None

    def __str__(self) -> str:
        where = ""
        if self.rank is not None:
            where += f" rank={self.rank}"
        if self.node is not None:
            where += f" node={self.node}"
        return f"[{self.code}]{where}: {self.message}"


@dataclass
class VerificationReport:
    """All findings for one verified config."""
    config: str
    findings: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings

    def codes(self) -> set:
        return {f.code for f in self.findings}

    def raise_if_failed(self) -> None:
        if self.findings:
            lines = "\n  ".join(str(f) for f in self.findings)
            raise ScheduleInvariantError(
                f"schedule verification failed for {self.config}:"
                f"\n  {lines}")

    def __str__(self) -> str:
        if self.ok:
            return f"{self.config}: OK"
        lines = "\n  ".join(str(f) for f in self.findings)
        return f"{self.config}: {len(self.findings)} finding(s)\n  {lines}"


# --------------------------------------------------------------------------
# compiling every rank of a config
# --------------------------------------------------------------------------

class _CompileView:
    """Minimal communicator stand-in: ``compile_schedule`` reads only
    ``size``, ``rank`` and the ``_sched_cache`` dict, so verifying rank
    r never needs a live runtime — chunk widening included, because it
    is a pure function of the (rank-uniform) sub-round count."""

    def __init__(self, n: int, rank: int):
        self.size = n
        self.rank = rank
        self._sched_cache: dict = {}


def compile_group(kind: str, n: int, *, nbytes: int = 0,
                  itemsize: int = 1, root: int = 0, group: int = 0,
                  chunk_bytes: int | None = None) -> list[Schedule]:
    """Compile ``kind`` for ALL ranks of an n-rank communicator."""
    return [compile_schedule(_CompileView(n, r), kind, nbytes, itemsize,
                             root, group=group, chunk_bytes=chunk_bytes)
            for r in range(n)]


# --------------------------------------------------------------------------
# the checks
# --------------------------------------------------------------------------

def _check_structure(scheds, out) -> None:
    for sched in scheds:
        try:
            sched.validate()
        except ScheduleInvariantError as e:
            out.append(Finding("invariant", str(e), rank=sched.rank,
                               node=e.node))


def _check_uniformity(scheds, out) -> None:
    rounds = {s.rounds for s in scheds}
    if len(rounds) > 1:
        out.append(Finding("rounds-mismatch",
                           f"ranks disagree on tag span: {sorted(rounds)}"))
    cbs = {s.chunk_bytes for s in scheds}
    if len(cbs) > 1:
        out.append(Finding("rounds-mismatch",
                           f"ranks disagree on chunk size: {sorted(map(str, cbs))}"))
    for s in scheds:
        if s.rounds > MAX_ROUNDS:
            out.append(Finding(
                "tag-window",
                f"{s.rounds} sub-rounds exceed the per-launch tag "
                f"window MAX_ROUNDS={MAX_ROUNDS}", rank=s.rank))


def _check_matching(scheds, out):
    """Send/recv matching must be a size-preserving bijection on
    ``(src, dst, round)`` — the wire key after the executor adds the
    per-launch tag base. Returns the matched pairs for the deadlock
    check: list of ``(src_rank, send_idx, dst_rank, recv_idx)``."""
    sends: dict = {}
    recvs: dict = {}
    for sched in scheds:
        for nd in sched.nodes:
            if isinstance(nd, SendOp):
                key = (sched.rank, nd.peer, nd.round)
                if key in sends:
                    out.append(Finding(
                        "duplicate-match",
                        f"two sends {sends[key].idx} and {nd.idx} from "
                        f"rank {sched.rank} to rank {nd.peer} share "
                        f"round {nd.round}", rank=sched.rank,
                        node=nd.idx))
                sends[key] = nd
            elif isinstance(nd, RecvOp):
                key = (nd.peer, sched.rank, nd.round)
                if key in recvs:
                    out.append(Finding(
                        "duplicate-match",
                        f"two receives {recvs[key].idx} and {nd.idx} on "
                        f"rank {sched.rank} from rank {nd.peer} share "
                        f"round {nd.round}", rank=sched.rank,
                        node=nd.idx))
                recvs[key] = nd
    pairs = []
    for key, snd in sends.items():
        src, dst, rnd = key
        rcv = recvs.get(key)
        if rcv is None:
            out.append(Finding(
                "orphan-send",
                f"send to rank {dst} at round {rnd} has no matching "
                f"receive on the peer", rank=src, node=snd.idx))
            continue
        if rcv.buf.nbytes != snd.buf.nbytes:
            out.append(Finding(
                "size-mismatch",
                f"send of {snd.buf.nbytes} B to rank {dst} at round "
                f"{rnd} meets a receive of {rcv.buf.nbytes} B",
                rank=src, node=snd.idx))
        pairs.append((src, snd.idx, dst, rcv.idx))
    for key, rcv in recvs.items():
        src, dst, rnd = key
        if key not in sends:
            out.append(Finding(
                "orphan-recv",
                f"receive from rank {src} at round {rnd} has no "
                f"matching send on the peer", rank=dst, node=rcv.idx))
    return pairs


def _check_deadlock(scheds, pairs, out) -> None:
    """Kahn's algorithm over the global happens-before event graph;
    any cycle is a deadlock the engine cannot make progress through.
    Events: node X -> issue(X)=2*gid(X), complete(X)=2*gid(X)+1."""
    offset = []
    total = 0
    for sched in scheds:
        offset.append(total)
        total += len(sched.nodes)
    n_ev = 2 * total
    succ: list[list[int]] = [[] for _ in range(n_ev)]
    indeg = [0] * n_ev

    def add(a: int, b: int) -> None:
        succ[a].append(b)
        indeg[b] += 1

    for sched in scheds:
        off = offset[sched.rank]
        for nd in sched.nodes:
            gid = off + nd.idx
            add(2 * gid, 2 * gid + 1)            # issue -> complete
            for d in nd.deps:
                add(2 * (off + d) + 1, 2 * gid)  # complete(dep) -> issue
    for src, sidx, dst, ridx in pairs:
        sg, rg = offset[src] + sidx, offset[dst] + ridx
        add(2 * sg, 2 * rg + 1)   # issue(send) -> complete(recv)
        add(2 * rg, 2 * sg + 1)   # issue(recv) -> complete(send)

    stack = [e for e in range(n_ev) if indeg[e] == 0]
    done = 0
    while stack:
        e = stack.pop()
        done += 1
        for t in succ[e]:
            indeg[t] -= 1
            if indeg[t] == 0:
                stack.append(t)
    if done == n_ev:
        return

    # extract one concrete cycle from the residual graph for the report
    def name(ev: int) -> str:
        gid, phase = divmod(ev, 2)
        for sched in scheds:
            if gid - offset[sched.rank] < len(sched.nodes) \
                    and gid >= offset[sched.rank]:
                nd = sched.nodes[gid - offset[sched.rank]]
                kind = type(nd).__name__
                tag = "issue" if phase == 0 else "complete"
                return f"rank{sched.rank}.{kind}[{nd.idx}].{tag}"
        return f"event{ev}"

    # walk BACKWARD through unprocessed predecessors: indeg[e] > 0
    # means some predecessor never completed Kahn's, so the walk stays
    # inside the residual set and must revisit a node — the cycle
    pred: list[list[int]] = [[] for _ in range(n_ev)]
    residual = {e for e in range(n_ev) if indeg[e] > 0}
    for e in residual:
        for t in succ[e]:
            if t in residual:
                pred[t].append(e)
    cur = next(iter(residual))
    path: list[int] = []
    seen: dict[int, int] = {}
    while cur not in seen:
        seen[cur] = len(path)
        path.append(cur)
        cur = pred[cur][0]
    cycle = [cur] + list(reversed(path[seen[cur]:]))
    out.append(Finding(
        "deadlock",
        "happens-before cycle: " + " -> ".join(name(e) for e in cycle)))


def _accesses(nd):
    """Yield ``(buf, is_write)`` for every LOCAL region a node touches.
    Put reads its staging region (window store), Get writes it (window
    load) — the window segment itself is cross-collective state that
    epoch discipline orders, not the schedule DAG."""
    if isinstance(nd, (SendOp, PutOp)):
        yield nd.buf, False
    elif isinstance(nd, (RecvOp, GetOp)):
        yield nd.buf, True
    else:                                   # ReduceOp / CopyOp
        yield nd.src, False
        yield nd.dst, True


def _ancestors(sched) -> list[int]:
    """Per-node ancestor sets as bitmasks. Construction order is a
    topological order (validate() enforces strictly-backward deps), so
    one forward pass computes the transitive closure."""
    anc = [0] * len(sched.nodes)
    for nd in sched.nodes:
        a = 0
        for d in nd.deps:
            a |= anc[d] | (1 << d)
        anc[nd.idx] = a
    return anc


def _check_hazards(scheds, out) -> None:
    """Unordered overlapping accesses with a write (WAR/WAW/RAW), and
    the same-slot send chain (one drain-ack word per PoolBuffer)."""
    for sched in scheds:
        anc = _ancestors(sched)
        by_slot: dict[int, list] = {}
        sends_in_slot: dict[int, list] = {}
        for nd in sched.nodes:
            for buf, wr in _accesses(nd):
                if buf.nbytes:
                    by_slot.setdefault(buf.slot, []).append(
                        (nd.idx, wr, buf.off, buf.off + buf.nbytes))
            if isinstance(nd, SendOp) and nd.buf.nbytes:
                sends_in_slot.setdefault(nd.buf.slot, []).append(nd.idx)

        for slot, accs in by_slot.items():
            for i in range(len(accs)):
                ai, awr, alo, ahi = accs[i]
                for j in range(i + 1, len(accs)):
                    bi, bwr, blo, bhi = accs[j]
                    if ai == bi or not (awr or bwr):
                        continue
                    if ahi <= blo or bhi <= alo:
                        continue
                    lo, hi = (ai, bi) if ai < bi else (bi, ai)
                    if not (anc[hi] >> lo) & 1:
                        out.append(Finding(
                            "buffer-hazard",
                            f"nodes {lo} and {hi} touch slot {slot} "
                            f"bytes [{max(alo, blo)}, {min(ahi, bhi)}) "
                            f"with a write but no dependency path "
                            f"orders them", rank=sched.rank, node=hi))

        for slot, idxs in sends_in_slot.items():
            for prev, cur in zip(idxs, idxs[1:]):
                if not (anc[cur] >> prev) & 1:
                    out.append(Finding(
                        "unchained-send",
                        f"sends {prev} and {cur} both source slot "
                        f"{slot} but are not ordered — a PoolBuffer "
                        f"has one drain-ack word, so same-slot sends "
                        f"must chain", rank=sched.rank, node=cur))


def _check_depth(scheds, matchbox_capacity, out) -> None:
    """``Schedule.required_matchbox_depth`` must equal the recount from
    the nodes (it is the declared bound ``comm.py`` provisions from),
    and — when a capacity is declared — no peer may need more."""
    for sched in scheds:
        per: dict[int, int] = {}
        for nd in sched.nodes:
            if isinstance(nd, RecvOp):
                per[nd.peer] = per.get(nd.peer, 0) + 1
        worst = max(per.values(), default=0)
        declared = sched.required_matchbox_depth()
        if worst != declared:
            out.append(Finding(
                "depth-overflow",
                f"declared matchbox depth {declared} != recounted "
                f"per-peer maximum {worst}", rank=sched.rank))
        for peer, depth in per.items():
            if sched.required_matchbox_depth(peer) != depth:
                out.append(Finding(
                    "depth-overflow",
                    f"declared depth toward peer {peer} is "
                    f"{sched.required_matchbox_depth(peer)}, schedule "
                    f"posts {depth}", rank=sched.rank))
            if matchbox_capacity is not None \
                    and depth > matchbox_capacity:
                out.append(Finding(
                    "depth-overflow",
                    f"peer {peer} needs {depth} concurrent postings "
                    f"but declared matchbox capacity is "
                    f"{matchbox_capacity}", rank=sched.rank))


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------

def verify_schedules(scheds: list[Schedule], *, config: str = "?",
                     matchbox_capacity: int | None = None
                     ) -> VerificationReport:
    """Run every check over one per-rank schedule list (``scheds[r]``
    is rank r's schedule). ``matchbox_capacity``, when given, is the
    provisioned per-peer posting depth to check ``depth-overflow``
    against (callers normally pass the persistent declaration
    ``2 * required_matchbox_depth()``)."""
    out: list[Finding] = []
    rep = VerificationReport(config, out)
    _check_structure(scheds, out)
    _check_uniformity(scheds, out)
    if any(f.code == "invariant" for f in out):
        return rep        # deps may be unusable; later checks assume not
    pairs = _check_matching(scheds, out)
    _check_deadlock(scheds, pairs, out)
    _check_hazards(scheds, out)
    _check_depth(scheds, matchbox_capacity, out)
    return rep


def verify_config(kind: str, n: int, *, nbytes: int = 0,
                  itemsize: int = 1, root: int = 0, group: int = 0,
                  chunk_bytes: int | None = None) -> VerificationReport:
    """Compile ``kind`` for all ``n`` ranks and verify the group. The
    matchbox capacity checked is the persistent-mode declaration
    (twice the schedule's own depth — two iterations coexist)."""
    config = (f"{kind}(n={n}, nbytes={nbytes}, itemsize={itemsize}, "
              f"root={root}, group={group}, chunk_bytes={chunk_bytes})")
    try:
        scheds = compile_group(kind, n, nbytes=nbytes, itemsize=itemsize,
                               root=root, group=group,
                               chunk_bytes=chunk_bytes)
    except ValueError as e:
        # ScheduleInvariantError and compiler preconditions (e.g. rd on
        # a non-pow2 size) both mean "this config cannot compile" — a
        # report the caller can inspect, not a crash.
        return VerificationReport(config, [Finding("invariant", str(e))])
    cap = max(2 * s.required_matchbox_depth() for s in scheds)
    return verify_schedules(scheds, config=config,
                            matchbox_capacity=max(cap, 1))


def iter_matrix(max_n: int = 16):
    """Yield every config the compilers currently support: all algos x
    rank counts 2..max_n x {unchunked, chunked, finely-chunked} x all
    valid hier group sizes, plus a chunk-widening boundary case. Pure
    and deterministic — the CI sweep and the pytest sweep share it."""
    nbytes, itemsize, per_b = 4096, 8, 1024
    for n in range(2, max_n + 1):
        pow2 = (n & (n - 1)) == 0
        for chunk in (None, 512, 128):
            cfgs = [dict(kind="allreduce_ring", n=n, nbytes=nbytes,
                         itemsize=itemsize),
                    dict(kind="reduce_scatter_ring", n=n, nbytes=nbytes,
                         itemsize=itemsize),
                    dict(kind="allgather_ring", n=n, nbytes=per_b),
                    dict(kind="allgather_bruck", n=n, nbytes=per_b),
                    # one-sided: Put/Get nodes + zero-byte token pairs
                    dict(kind="allgather_get", n=n, nbytes=per_b),
                    dict(kind="rput", n=n, nbytes=nbytes, root=n - 1),
                    dict(kind="rget", n=n, nbytes=nbytes, root=n - 1),
                    # read-modify-write chain: Get -> Reduce -> Put
                    dict(kind="raccumulate", n=n, nbytes=nbytes,
                         itemsize=itemsize, root=n - 1)]
            if pow2:
                cfgs.append(dict(kind="allreduce_rd", n=n, nbytes=nbytes,
                                 itemsize=itemsize))
            for root in (0, n - 1):
                cfgs.append(dict(kind="bcast", n=n, nbytes=nbytes,
                                 root=root))
                cfgs.append(dict(kind="bcast_put", n=n, nbytes=nbytes,
                                 root=root))
                cfgs.append(dict(kind="reduce", n=n, nbytes=nbytes,
                                 itemsize=itemsize, root=root))
            for g in range(1, n + 1):
                if n % g == 0 and ((n // g) & (n // g - 1)) == 0:
                    cfgs.append(dict(kind="allreduce_hier", n=n,
                                     nbytes=nbytes, itemsize=itemsize,
                                     group=g))
            for cfg in cfgs:
                cfg["chunk_bytes"] = chunk
                yield cfg
        yield dict(kind="barrier", n=n)
    # widening boundary: sub-rounds would blow past MAX_ROUNDS, so the
    # compiler must widen the chunk until the tag window fits — and the
    # widened schedule must still verify on every rank
    yield dict(kind="allreduce_rd", n=min(16, 1 << (max_n.bit_length() - 1)),
               nbytes=65536, itemsize=8, chunk_bytes=64)


def sweep(max_n: int = 16, *, fail_fast: bool = False):
    """Verify the full matrix; returns ``(n_configs, bad_reports)``."""
    count = 0
    bad = []
    for cfg in iter_matrix(max_n):
        kind = cfg.pop("kind")
        n = cfg.pop("n")
        rep = verify_config(kind, n, **cfg)
        count += 1
        if not rep.ok:
            bad.append(rep)
            if fail_fast:
                break
    return count, bad


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="cross-rank static verification of every compiled "
                    "collective schedule shape")
    p.add_argument("--max-n", type=int, default=16,
                   help="largest communicator size to sweep (default 16)")
    p.add_argument("--fail-fast", action="store_true",
                   help="stop at the first failing config")
    args = p.parse_args(argv)
    count, bad = sweep(args.max_n, fail_fast=args.fail_fast)
    for rep in bad:
        print(rep)
    print(f"verified {count} configs across sizes 2..{args.max_n}: "
          f"{len(bad)} failing")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
