"""DistContext — the model's view of the mesh.

Carries the mesh + axis names and provides:
  * ``constrain_act``      — canonical activation sharding constraint
  * ``vp_embed``           — vocab-parallel embedding lookup (shard_map):
                             address arithmetic over the local vocab shard +
                             psum; the gathered table never materializes.
  * ``vp_cross_entropy``   — vocab-parallel softmax CE (shard_map): local
                             logits shard + pmax/psum reductions.

This is the Arena lesson from the paper applied to TPU: replace data motion
(all-gather of a 256k-row table) with address arithmetic on a shared layout.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import axis_size, dp_axes


@dataclass(frozen=True)
class DistContext:
    mesh: Any
    batch_shardable: bool = True   # False when global_batch % dp != 0

    # ------------------------------------------------------------------
    @property
    def dp(self) -> tuple[str, ...]:
        return dp_axes(self.mesh)

    @property
    def model_size(self) -> int:
        return axis_size(self.mesh, "model")

    @property
    def bspec(self):
        return self.dp if (self.dp and self.batch_shardable) else None

    def constrain_act(self, x):
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(self.bspec, *([None] * (x.ndim - 1)))))

    def constrain_seq(self, x):
        """Context parallelism: dim 1 (sequence) sharded over 'model'.
        Used to shard attention scores when heads cannot split the TP axis
        (e.g. smollm's 9 heads over 16-way model)."""
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh,
                             P(self.bspec, "model",
                               *([None] * (x.ndim - 2)))))

    def constrain_kv(self, x):
        """Decode KV cache (B, KV, S, Dh): S sharded over 'model'."""
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(self.bspec, None, "model", None)))

    def constrain_scores(self, x):
        """Decode scores (B, H, 1, S): S sharded over 'model'."""
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(self.bspec, None, None, "model")))

    def vocab_parallel(self, cfg: ModelConfig) -> bool:
        return (cfg.vocab_parallel and self.model_size > 1
                and cfg.padded_vocab % self.model_size == 0)

    # ------------------------------------------------------------------
    def vp_embed(self, table, tokens, cfg: ModelConfig):
        V = cfg.padded_vocab
        shard = V // self.model_size
        cdt = jnp.dtype(cfg.compute_dtype)

        def f(tab, tok):
            idx = lax.axis_index("model")
            local = tok - idx * shard
            ok = (local >= 0) & (local < shard)
            x = jnp.take(tab, jnp.clip(local, 0, shard - 1), axis=0)
            x = jnp.where(ok[..., None], x.astype(cdt), 0)
            return lax.psum(x, "model")

        return jax.shard_map(
            f, mesh=self.mesh,
            in_specs=(P("model", None), P(self.bspec, None)),
            out_specs=P(self.bspec, None, None))(table, tokens)

    # ------------------------------------------------------------------
    def vp_cross_entropy(self, head, x, labels, cfg: ModelConfig):
        """Returns per-token CE (B, S) without materializing full logits."""
        V = cfg.padded_vocab
        shard = V // self.model_size
        vocab = cfg.vocab_size

        def f(hd, xx, lab):
            idx = lax.axis_index("model")
            logits = jnp.einsum("bsd,vd->bsv", xx, hd.astype(xx.dtype),
                                preferred_element_type=jnp.float32)
            gidx = idx * shard + jnp.arange(shard)
            logits = jnp.where(gidx[None, None] < vocab, logits, -1e30)
            # m is a constant shift (lse identity holds for any constant);
            # stop_gradient BEFORE pmax: zero tangent in -> pmax's missing
            # JVP rule is never invoked.
            m = lax.pmax(lax.stop_gradient(logits.max(axis=-1)), "model")
            s = lax.psum(jnp.exp(logits - m[..., None]).sum(axis=-1), "model")
            local = lab - idx * shard
            ok = (local >= 0) & (local < shard)
            ll = jnp.take_along_axis(
                logits, jnp.clip(local, 0, shard - 1)[..., None], axis=-1)[..., 0]
            ll = lax.psum(jnp.where(ok, ll, 0.0), "model")
            return jnp.log(s) + m - ll

        return jax.shard_map(
            f, mesh=self.mesh,
            in_specs=(P("model", None), P(self.bspec, None, None),
                      P(self.bspec, None)),
            out_specs=P(self.bspec, None))(head, x, labels)

    # ------------------------------------------------------------------
    def vp_greedy_token(self, head, x, cfg: ModelConfig):
        """Greedy next token WITHOUT materializing (B, V) logits on any
        device: local argmax per vocab shard + tiny cross-shard reductions
        (2 scalars/row of wire, vs V floats for a gathered-logits decode)."""
        V = cfg.padded_vocab
        shard = V // self.model_size
        vocab = cfg.vocab_size

        def f(hd, xx):
            idx = lax.axis_index("model")
            logits = jnp.einsum("bd,vd->bv", xx, hd.astype(xx.dtype),
                                preferred_element_type=jnp.float32)
            gidx = idx * shard + jnp.arange(shard)
            logits = jnp.where(gidx[None] < vocab, logits, -jnp.inf)
            lmax = logits.max(axis=-1)
            larg = jnp.argmax(logits, axis=-1).astype(jnp.int32) \
                + idx * shard
            gmax = lax.pmax(lmax, "model")
            cand = jnp.where(lmax >= gmax, larg, jnp.int32(V))
            return lax.pmin(cand, "model")

        return jax.shard_map(
            f, mesh=self.mesh,
            in_specs=(P("model", None), P(self.bspec, None)),
            out_specs=P(self.bspec))(head, x)
