"""Distributed layer: device-side (jax) sharding/context/schedules and
host-side (jax-free) coordination.

Exports are LAZY (PEP 562): importing this package must not pull in jax,
so host-side ranks (data loaders, checkpoint writers) can use
``repro.distributed.host_coord`` — or import its names from here —
without a device runtime. jax loads only when a jax-backed name
(DistContext, *_pspecs) is first touched.
"""
_CONTEXT = ("DistContext",)
_SHARDING = ("batch_pspecs", "decode_state_pspecs", "opt_state_pspecs",
             "param_pspecs")
_HOST_COORD = ("agree_max_step", "allreduce_metrics", "bcast_manifest",
               "sync_epoch")

__all__ = [*_CONTEXT, *_SHARDING, *_HOST_COORD]


def __getattr__(name):
    if name in _CONTEXT:
        from repro.distributed import context
        return getattr(context, name)
    if name in _SHARDING:
        from repro.distributed import sharding
        return getattr(sharding, name)
    if name in _HOST_COORD:
        from repro.distributed import host_coord
        return getattr(host_coord, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
