from repro.distributed.context import DistContext  # noqa: F401
from repro.distributed.sharding import (  # noqa: F401
    batch_pspecs,
    decode_state_pspecs,
    opt_state_pspecs,
    param_pspecs,
)
