"""cMPI-adapted cross-pod collective schedules.

The paper's core systems lesson — route traffic over the cheapest
memory-like fabric and keep the expensive hop THIN — maps onto a multi-pod
TPU mesh as hierarchical gradient synchronization:

    in-pod reduce-scatter (fast ICI, full bytes)
      -> cross-pod all-reduce on 1/|data| of the bytes (thin hop),
         optionally int8-compressed (compression.py)
      -> in-pod all-gather (fast ICI)

vs. the flat all-reduce over all (pod x data) devices that a naive mesh
spec produces. ``sync_grads`` is called INSIDE shard_map (axis names in
scope). ``make_cmpi_train_step`` builds a demonstration train step that
computes per-shard grads under shard_map over the dp axes and synchronizes
them explicitly — the device-level mirror of core/collectives.py. Params
are replicated across dp inside this step, so it targets the <=1.5B-class
archs (smollm, granite); the >8B archs keep GSPMD sharding where XLA's
hierarchical decomposition applies.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import compression as C
from repro.models import lm
from repro.train import optimizer as opt


def sync_grads(grads, *, data_axis: str = "data",
               pod_axis: str | None = "pod",
               compression: str = "none"):
    """Hierarchical gradient all-reduce; call inside shard_map.

    Every leaf: reduce-scatter in-pod over ``data_axis`` (leaf flattened,
    padded to the axis size), cross-pod (all-)reduce on the shard —
    optionally int8 — then all-gather in-pod and reshape back.
    """
    dsize = lax.axis_size(data_axis)

    def leaf(g):
        gf = g.astype(jnp.float32).reshape(-1)
        pad = (-gf.size) % dsize
        if pad:
            gf = jnp.concatenate([gf, jnp.zeros(pad, jnp.float32)])
        shard = lax.psum_scatter(gf.reshape(dsize, -1), data_axis,
                                 scatter_dimension=0, tiled=False)
        if pod_axis is not None:
            if compression == "int8":
                shard = C.psum_int8(shard, pod_axis)
            else:
                shard = lax.psum(shard, pod_axis)
        full = lax.all_gather(shard, data_axis, axis=0, tiled=False)
        return full.reshape(-1)[:g.size].reshape(g.shape)

    return jax.tree.map(leaf, grads)


def make_cmpi_train_step(cfg, shape, mesh, *, oc=None,
                         compression: str = "none"):
    """shard_map train step with EXPLICIT cMPI-style gradient sync.

    Batch is sharded over the dp axes; params/opt-state replicated (this
    demonstration targets small archs). Loss is the LOCAL mean; grads are
    synchronized by ``sync_grads`` (mean over shards folded into the psum)
    — no GSPMD-inserted gradient collectives.
    """
    oc = oc or opt.for_model(cfg)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    pod_axis = "pod" if "pod" in mesh.shape else None
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    assert shape.global_batch % dp_total == 0

    def local_step(params, opt_state, batch):
        def loss_fn(p):
            total, metrics = lm.loss_fn(p, cfg, batch, dist=None)
            return total, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        # hierarchical sync: data-axis RS/AG + thin (compressed) pod hop.
        # in-pod mean: psum_scatter sums over data; divide by dp_total.
        grads = sync_grads(grads, data_axis=dp[-1], pod_axis=pod_axis,
                           compression=compression)
        grads = jax.tree.map(lambda g: g / dp_total, grads)
        loss = lax.pmean(loss, dp)
        metrics = jax.tree.map(lambda m: lax.pmean(m, dp), metrics)
        new_params, new_opt, om = opt.apply_updates(oc, params, grads,
                                                    opt_state)
        return new_params, new_opt, dict(metrics, loss=loss, **om)

    bspec = {k: P(dp, *([None] * extra))
             for k, extra in (("tokens", 1), ("labels", 1))}
    if cfg.frontend == "frames":
        bspec = {"frames": P(dp, None, None), "labels": P(dp, None)}
    if cfg.n_ctx_tokens:
        bspec["ctx"] = P(dp, None, None)

    rep = P()
    pspec = jax.tree.map(lambda _: rep, lm.param_specs(cfg))
    osspec = jax.tree.map(lambda _: rep,
                          opt.state_specs(oc, lm.param_specs(cfg)))
    mspec = {k: rep for k in ("loss", "aux", "tokens", "grad_norm", "lr")}

    fn = jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(pspec, osspec, bspec),
        out_specs=(pspec, osspec, mspec),
        check_vma=False)
    shardings = tuple(
        jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                     is_leaf=lambda x: isinstance(x, P))
        for t in ((pspec, osspec, bspec), (pspec, osspec, mspec)))
    return fn, shardings[0], shardings[1]
