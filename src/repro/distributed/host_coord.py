"""Host-side coordination over cMPI — the control-plane callers of
the ``Comm`` method collectives.

The device mesh (jax side, ``schedules.py``) synchronizes gradients; the
HOSTS still have to coordinate: agree on checkpoint manifests, reduce
scalar training metrics across ranks, and advance data-pipeline epochs in
lockstep. These helpers run those flows over the cMPI ``Comm`` (API v2) with
ndarray views end to end — metric vectors travel as buffer-protocol sends
and land via ``recv_into`` (inside the collectives), never through
``tobytes()`` / ``frombuffer().copy()`` round trips. Large manifests
automatically ride the communicator's rendezvous path.

No jax import here: host coordination must work on ranks that never
initialize a device runtime (e.g. a data-loader or checkpoint-writer
process).
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.comm import Comm


def allreduce_metrics(comm: Comm, metrics: dict[str, float],
                      op=np.add) -> dict[str, float]:
    """Reduce a {name: scalar} dict across all ranks (sum by default).
    Keys must match on every rank; values travel as one float64 vector."""
    keys = sorted(metrics)
    vec = np.array([float(metrics[k]) for k in keys], np.float64)
    out = comm.allreduce(vec, op=op)
    return dict(zip(keys, out.tolist()))


def bcast_manifest(comm: Comm, manifest: dict | None,
                   root: int = 0) -> dict:
    """Broadcast a JSON-serializable manifest (checkpoint index, data
    epoch plan, elastic membership) from ``root`` to every rank.

    The JSON bytes are wrapped as a uint8 ndarray view — zero-copy into
    the broadcast tree; decoding happens once at the consumer boundary."""
    if comm.rank == root:
        blob = json.dumps(manifest, sort_keys=True).encode()
        arr = np.frombuffer(blob, np.uint8)
    else:
        arr = None
    out = comm.bcast(arr, root=root)
    return json.loads(out.tobytes().decode())


def sync_epoch(comm: Comm, epoch: int, root: int = 0) -> int:
    """Advance the data-pipeline epoch in lockstep: every rank adopts
    the root's epoch counter (a barrier + 8-byte broadcast)."""
    comm.barrier()
    out = comm.bcast(np.array([epoch], np.int64), root=root)
    return int(out[0])


def agree_max_step(comm: Comm, step: int) -> int:
    """Elastic-restart helper: the cluster resumes from the HIGHEST step
    any surviving rank holds a complete checkpoint for."""
    out = comm.allreduce(np.array([step], np.int64), op=np.maximum)
    return int(out[0])
