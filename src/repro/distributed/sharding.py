"""Divisibility-aware sharding rules.

The mesh is 2D ``("data","model")`` or 3D ``("pod","data","model")``.
Weights are tensor-parallel over ``model`` on flattened projection dims (so
TP never depends on head-count divisibility), optionally FSDP-sharded over
``data`` (HSDP: parameters are replicated across pods and FSDP-sharded
*within* a pod — the cMPI lesson that the expensive inter-pod fabric should
carry thin traffic, not weight gathers). Any rule whose dim is not divisible
by the axis size falls back to replication for that dim — GSPMD tolerates
uneven shardings on constraints, but we keep *parameter* shardings exact so
checkpointing shards stay rectangular.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import lm


def axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _maybe(mesh, axis: Optional[str], dim: int) -> Optional[str]:
    """axis if dim is divisible by its size (and axis exists) else None."""
    if axis is None:
        return None
    sz = axis_size(mesh, axis)
    if sz > 1 and dim % sz == 0:
        return axis
    return None


def param_pspecs(cfg: ModelConfig, mesh, *, serve: bool = False) -> Any:
    """PartitionSpec pytree matching lm.init(cfg).

    ``serve=True`` drops FSDP unless cfg.serve_fsdp: a serving step reads
    every weight every step, so data-axis sharding of params turns into a
    per-step all-gather of the full model (measured: the dominant decode
    collective, see EXPERIMENTS.md §Perf cell B). TP-only layouts keep
    weights resident."""
    specs = lm.param_specs(cfg)
    use_fsdp = cfg.fsdp and (cfg.serve_fsdp or not serve)
    fsdp = "data" if (use_fsdp and "data" in mesh.shape) else None
    m = "model" if "model" in mesh.shape else None

    def block_rule(path: str, shape) -> P:
        d = dict  # noqa: E731 (readability only)
        dims = shape.shape
        # all block leaves have leading n_groups dim
        if "norm" in path or path.endswith(("mix_k", "mix_r", "mix_x", "w0",
                                            "dt_bias", "conv_b", "D", "u")):
            if path.endswith(("w0", "dt_bias", "conv_b", "D")):
                return P(None, _maybe(mesh, m, dims[1]))
            return P(*([None] * len(dims)))
        if path.endswith(("wq", "w_gate", "w_up", "in_proj", "cm_k")) \
                and len(dims) == 3:
            if cfg.fsdp_dim == "output" and fsdp:
                # ZeRO-3: stack (model, data) on the OUTPUT dim — XLA
                # gathers the (small) weight shards just-in-time instead of
                # all-reducing activation-sized partial sums over data
                both = _maybe(mesh, m, dims[2])
                if both and dims[2] % (axis_size(mesh, m)
                                       * axis_size(mesh, fsdp)) == 0:
                    return P(None, None, (m, fsdp))
                return P(None, None, both)
            return P(None, _maybe(mesh, fsdp, dims[1]), _maybe(mesh, m, dims[2]))
        if path.endswith(("wk", "wv")):
            if cfg.fsdp_dim == "output" and fsdp:
                both = _maybe(mesh, m, dims[2])
                if both and dims[2] % (axis_size(mesh, m)
                                       * axis_size(mesh, fsdp)) == 0:
                    return P(None, None, (m, fsdp))
                return P(None, _maybe(mesh, fsdp, dims[1]) if not both
                         else None, both)
            return P(None, _maybe(mesh, fsdp, dims[1]), _maybe(mesh, m, dims[2]))
        if path.endswith(("wo", "w_down", "out_proj", "cm_v")) and len(dims) == 3:
            return P(None, _maybe(mesh, m, dims[1]), _maybe(mesh, fsdp, dims[2]))
        if path.endswith(("wr", "wg", "cm_r")):
            return P(None, _maybe(mesh, fsdp, dims[1]), _maybe(mesh, m, dims[2]))
        if path.endswith("router"):
            return P(None, _maybe(mesh, fsdp, dims[1]), None)
        if path.endswith(("w_gate", "w_up")) and len(dims) == 4:  # moe (G,E,D,F)
            if cfg.moe_shard == "ffn":
                # per-expert TP over d_ff: dispatch stays device-local;
                # comm collapses to the dense-FFN all-reduce pattern
                return P(None, None, _maybe(mesh, fsdp, dims[2]),
                         _maybe(mesh, m, dims[3]))
            if cfg.fsdp_dim == "output":
                # fsdp on the OUTPUT dim F (not the contraction dim D)
                return P(None, _maybe(mesh, m, dims[1]), None,
                         _maybe(mesh, fsdp, dims[3]))
            return P(None, _maybe(mesh, m, dims[1]), _maybe(mesh, fsdp, dims[2]),
                     None)
        if path.endswith("w_down") and len(dims) == 4:            # moe (G,E,F,D)
            if cfg.moe_shard == "ffn":
                return P(None, None, _maybe(mesh, m, dims[2]),
                         _maybe(mesh, fsdp, dims[3]))
            if cfg.fsdp_dim == "output":
                return P(None, _maybe(mesh, m, dims[1]), None,
                         _maybe(mesh, fsdp, dims[3]))
            return P(None, _maybe(mesh, m, dims[1]), _maybe(mesh, fsdp, dims[2]),
                     None)
        if path.endswith("conv_w"):
            return P(None, None, _maybe(mesh, m, dims[2]))
        if path.endswith("x_proj"):
            return P(None, _maybe(mesh, m, dims[1]), None)
        if path.endswith("dt_proj"):
            return P(None, None, _maybe(mesh, m, dims[2]))
        if path.endswith("A_log"):
            return P(None, _maybe(mesh, m, dims[1]), None)
        if path.endswith(("w_a",)):
            return P(None, _maybe(mesh, fsdp, dims[1]), None)
        if path.endswith(("w_b",)):
            return P(None, None, _maybe(mesh, m, dims[2]))
        # default: replicate
        return P(*([None] * len(dims)))

    def rule(path_tuple, leaf) -> P:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path_tuple)
        if path.startswith(("embed", "head")):
            return P(_maybe(mesh, m, leaf.shape[0]), None)
        if path.startswith("final_norm"):
            return P(None)
        return block_rule(path, leaf)

    return jax.tree_util.tree_map_with_path(rule, specs)


def batch_pspecs(cfg: ModelConfig, shape: InputShape, mesh) -> dict[str, P]:
    dp = dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= axis_size(mesh, a)
    bdim: Any = dp if (dp and shape.global_batch % dp_total == 0) else None
    out: dict[str, P] = {}
    if cfg.frontend == "frames":
        out["frames"] = P(bdim, None, None)
    else:
        out["tokens"] = P(bdim, None)
    if shape.kind == "train":
        out["labels"] = P(bdim, None)
    if cfg.n_ctx_tokens:
        out["ctx"] = P(bdim, None, None)
    return out


def decode_state_pspecs(cfg: ModelConfig, shape: InputShape, mesh) -> Any:
    """Specs for lm.decode_state_init output. KV caches are sharded over the
    batch (data axes) and over sequence (model axis) — the flash-decoding
    layout; when batch is unshardable (long_500k, B=1) the sequence dim takes
    every axis."""
    dp = dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= axis_size(mesh, a)
    batch_ok = dp and shape.global_batch % dp_total == 0
    bdim: Any = dp if batch_ok else None
    seq_axes: Any = "model" if batch_ok else (dp + ("model",) if dp else "model")

    state_specs = lm.decode_state_specs(cfg, shape.global_batch, shape.seq_len)

    def rule(path_tuple, leaf) -> P:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path_tuple)
        dims = leaf.shape
        if "/kv/" in path or path.endswith(("/k", "/v")):
            # (G, B, KV, S, Dh) — shard seq; cross-attn ctx cache too
            if cfg.kv_shard == "batch" and batch_ok:
                # per-example local attention: every device holds the FULL
                # sequence for its batch shard — no model-axis traffic in
                # the decode inner loop (EXPERIMENTS.md §Perf cell B)
                return P(None, bdim, None, None, None)
            seq = dims[3]
            ax = seq_axes
            if isinstance(ax, tuple):
                tot = 1
                for a in ax:
                    tot *= axis_size(mesh, a)
                ax = ax if seq % tot == 0 else "model"
            return P(None, bdim, None, _maybe(mesh, ax, seq)
                     if isinstance(ax, str) else ax, None)
        if path.endswith("k_scale") or path.endswith("v_scale"):
            return P(None, bdim, None, None)
        if path.endswith("/conv"):
            return P(None, bdim, None, _maybe(mesh, "model", dims[3]))
        if path.endswith("/h"):
            return P(None, bdim, _maybe(mesh, "model", dims[2]), None)
        if path.endswith("/S"):
            return P(None, bdim, None, None, None)
        if path.endswith(("x_prev", "cm_x_prev")):
            return P(None, bdim, _maybe(mesh, "model", dims[2]))
        return P(*([None] * len(dims)))

    return jax.tree_util.tree_map_with_path(rule, state_specs)


def opt_state_pspecs(cfg: ModelConfig, mesh, param_specs_tree, params_shape) -> Any:
    """ZeRO-1: moment tensors take the param spec plus a ``data`` shard on the
    first free divisible dim (optimizer state is never replicated over data)."""
    del cfg

    def zero1(spec: P, leaf) -> P:
        if "data" not in mesh.shape:
            return spec
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        if any(p == "data" or (isinstance(p, tuple) and "data" in p)
               for p in parts):
            return spec
        dsz = axis_size(mesh, "data")
        for i, (p, dim) in enumerate(zip(parts, leaf.shape)):
            if p is None and dim % dsz == 0 and dim >= dsz:
                parts[i] = "data"
                return P(*parts)
        return spec

    return jax.tree_util.tree_map(zero1, param_specs_tree, params_shape)
