"""Gradient compression for the expensive cross-pod hop.

cMPI's lesson is that the thin fabric (CXL link there, DCN/pod axis here)
must carry as few bytes as possible. After the in-pod reduce-scatter, each
device owns 1/|data| of the gradient; the cross-pod exchange of that shard
is further compressed bf16 -> int8 with a per-block scale (block = last
axis), cutting cross-pod wire bytes ~2x vs bf16 (4x vs f32).

Summation of int8 across pods happens in int32 (psum of the quantized
values), then one rescale — this keeps the collective itself integer and
exact; the only error is the quantization, bounded by scale/2 per element.
Error feedback (residual carry) is provided for training-quality use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def int8_encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (q int8, scale f32 per last-axis block)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_error(x: jax.Array) -> jax.Array:
    q, s = int8_encode(x)
    return x.astype(jnp.float32) - int8_decode(q, s)


def psum_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """Compressed psum over `axis_name` (call inside shard_map):
    int8-quantize locally, sum quantized ints in int32 exactly, and apply
    the max scale — wire bytes are 1B/elem + one scale per block."""
    q, scale = int8_encode(x)
    qsum = lax.psum(q.astype(jnp.int32), axis_name)
    smax = lax.pmax(scale, axis_name)
    return (qsum.astype(jnp.float32) * smax).astype(x.dtype)


class ErrorFeedback:
    """Residual carry: feed quantization error into the next step's grads.
    state = pytree of residuals matching the grad tree."""

    @staticmethod
    def init(grads):
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    @staticmethod
    def apply(grads, residual):
        """-> (compensated grads, fn(compressed) -> new residual)."""
        comp = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual)

        def new_residual(compressed):
            return jax.tree.map(
                lambda c, dec: c - dec.astype(jnp.float32),
                comp, compressed)

        return comp, new_residual
