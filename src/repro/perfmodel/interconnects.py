"""Analytical interconnect models calibrated to the paper's Table 1 and
Figs 5-11.

We have neither the Niagara 2.0 CXL box nor the Mellanox NICs, so — exactly
like the paper does for >4 nodes via SimGrid — performance claims are
reproduced through calibrated alpha-beta models:

    T_raw(size)  = alpha + size / bandwidth          (fabric)
    T_mpi(size)  = t_proto + T_raw(size) + coherence (MPI layer)

Calibration anchors (paper Table 1 / §2 / §4):
  main memory        100 ns   132.8 GB/s
  TCP over Ethernet   16 us   117.8 MB/s
  TCP over CX-6 Dx    18 us    11.5 GB/s
  RoCEv2 CX-6 Dx     1.6 us    10.8 GB/s
  RoCEv2 CX-3        ~2 us      7.0 GB/s
  InfiniBand CX-6   ~600 ns    25.0 GB/s
  CXL SHM (cached)   790 ns     9.9 GB/s
  CXL SHM (flushed)  2.2 us     9.5 GB/s

MPI-level anchors (Figs 5-8, OMB on 2 nodes):
  one-sided  CXL ~12 us flat to 16 KB;  TCP-Eth ~630 us;  TCP-CX6 ~620 us
  two-sided  CXL ~12 us;  TCP-Eth ~160 us;  TCP-CX6 ~55 us
  one-sided bw saturates ~8,600 MB/s (16p);  two-sided ~6,050 MB/s (-30%,
  double copy);  TCP-CX6 climbs to ~10,150 MB/s at 32p for large messages.
  CXL bandwidth DECLINES beyond 16 KB messages (CPU-mediated copies contend
  in the memory hierarchy); NIC offload does not.

Coherence modes (Fig 11): clflush serial per line; clflushopt ~4x parallel;
uncacheable pays a PCIe transaction per word (MPS packetization) — >4,000 us
beyond 2 KB.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

CACHELINE = 64
KB = 1024
MB = 1024 * 1024
GB = 1024 ** 3


@dataclass(frozen=True)
class Interconnect:
    name: str
    alpha: float                 # fabric latency, seconds
    bandwidth: float             # fabric peak, bytes/s
    # MPI-layer protocol overheads (seconds)
    t_onesided: float            # OMB one-sided per-op overhead (win sync)
    t_twosided: float            # OMB two-sided per-op overhead
    # CPU-mediated transfer? (CXL: every byte moves via CPU `mov`)
    cpu_mediated: bool = False
    # aggregate fabric ceiling for multi-process bw tests, bytes/s
    fabric_peak: float = 0.0
    # two-sided aggregate ceiling if different (NIC duplex pipelines)
    fabric_peak_twosided: float = 0.0
    # per-process ceiling (NIC pipelines; CXL per-core copy throughput)
    proc_peak: float = 0.0
    # message size at which the NIC pipeline reaches half of peak
    half_size: float = 0.0

    # ------------------------------------------------------------------
    def raw_latency(self, size: int) -> float:
        return self.alpha + size / self.bandwidth

    def _contention(self, size: int, procs: int) -> float:
        """CPU-mediated fabrics lose bandwidth beyond 16 KB messages as
        concurrent copies contend in the memory hierarchy (paper §3.6)."""
        if not self.cpu_mediated or size <= 16 * KB:
            return 1.0
        return 1.0 + 0.25 * math.log2(size / (16 * KB)) \
            * (0.5 + procs / 16.0)

    def mpi_latency(self, size: int, *, onesided: bool,
                    procs: int = 2) -> float:
        t = self.t_onesided if onesided else self.t_twosided
        lat = t + self.raw_latency(size)
        if self.cpu_mediated and size > 16 * KB:
            # paper §4.2: CXL latency grows proportionally beyond 16 KB —
            # concurrent CPU copies contend in the memory hierarchy
            lat += (size / self.bandwidth) * (0.75 * size / (16 * KB) - 1.0)
        return lat

    def mpi_bandwidth(self, size: int, procs: int, *,
                      onesided: bool) -> float:
        """Aggregate OMB-style bandwidth (bytes/s) for `procs` concurrent
        pairs streaming `size`-byte messages (window of 64 in flight —
        per-message protocol overhead amortizes)."""
        t_proto = (self.t_onesided if onesided else self.t_twosided) / 64.0
        per_msg = t_proto + size / self.bandwidth
        if self.cpu_mediated:
            # every message pays its coherence epilogue (Fig 11):
            # flush-call base + clflushopt-parallel per-line cost
            lines = max(1, (size + CACHELINE - 1) // CACHELINE)
            per_msg += 2.2e-6 + (lines - 1) * 0.125e-6 / 4.0
        agg = procs * size / per_msg
        peak = self.fabric_peak or self.bandwidth
        if not onesided and self.fabric_peak_twosided:
            peak = self.fabric_peak_twosided
        if self.half_size:                    # NIC pipeline fill
            peak = peak * size / (size + self.half_size)
        if self.cpu_mediated:                 # paper §3.6: memory-hierarchy
            peak = peak / self._contention(size, procs)   # contention
        caps = [peak]
        if self.proc_peak:
            caps.append(self.proc_peak * procs)
        agg = min(agg, *caps)
        if self.cpu_mediated and not onesided:
            agg *= 0.70          # double copy through the queue (paper: -30%)
        return agg


# --------------------------------------------------------------------------
# Table-1 instances
# --------------------------------------------------------------------------

MAIN_MEMORY = Interconnect(
    "main_memory", 100e-9, 132.8 * GB, 0.4e-6, 0.4e-6,
    fabric_peak=132.8 * GB, proc_peak=20 * GB)

ETHERNET_TCP = Interconnect(
    "tcp_ethernet", 16e-6, 117.8 * MB, 614e-6, 144e-6,
    fabric_peak=120 * MB, proc_peak=117.8 * MB)

MELLANOX_TCP = Interconnect(
    "tcp_cx6dx", 18e-6, 11.5 * GB, 602e-6, 37e-6,
    fabric_peak=10.65 * GB, fabric_peak_twosided=13.1 * GB,
    proc_peak=0.45 * GB, half_size=12 * KB)

ROCE_CX6 = Interconnect(
    "rocev2_cx6dx", 1.6e-6, 10.8 * GB, 4e-6, 2e-6,
    fabric_peak=10.8 * GB, proc_peak=2 * GB)

ROCE_CX3 = Interconnect(
    "rocev2_cx3", 2e-6, 7.0 * GB, 5e-6, 3e-6,
    fabric_peak=7.0 * GB, proc_peak=1.5 * GB)

INFINIBAND_CX6 = Interconnect(
    "ib_cx6", 0.6e-6, 25.0 * GB, 2e-6, 1.2e-6,
    fabric_peak=25.0 * GB, proc_peak=5 * GB)

CXL_SHM_NOFLUSH = Interconnect(
    "cxl_shm_cached", 790e-9, 9.9 * GB, 10.6e-6, 10.6e-6,
    cpu_mediated=True, fabric_peak=9.4 * GB, proc_peak=0.9725 * GB)

CXL_SHM = Interconnect(
    "cxl_shm", 2.2e-6, 9.5 * GB, 10.6e-6, 10.6e-6,
    cpu_mediated=True, fabric_peak=9.02 * GB, proc_peak=0.9725 * GB)

INTERCONNECTS = {
    ic.name: ic for ic in (
        MAIN_MEMORY, ETHERNET_TCP, MELLANOX_TCP, ROCE_CX6, ROCE_CX3,
        INFINIBAND_CX6, CXL_SHM_NOFLUSH, CXL_SHM)
}


# --------------------------------------------------------------------------
# coherence-mode latency (Fig 11: memset of `size` bytes + coherence)
# --------------------------------------------------------------------------

_FLUSH_BASE = 2.2e-6          # single-line flush + fence
_FLUSH_PER_LINE = 0.50e-6     # clflush: serial per line
_FLUSHOPT_PAR = 4.0           # clflushopt flushes ~4 lines in parallel
_UC_PER_BYTE = 2.0e-6         # uncacheable: PCIe transaction per word


def coherence_latency(size: int, mode: str) -> float:
    """Seconds for a memset of `size` bytes under each coherence mode."""
    lines = max(1, (size + CACHELINE - 1) // CACHELINE)
    if mode == "clflush":
        return _FLUSH_BASE + (lines - 1) * _FLUSH_PER_LINE
    if mode == "clflushopt":
        return _FLUSH_BASE + (lines - 1) * _FLUSH_PER_LINE / _FLUSHOPT_PAR
    if mode == "uncacheable":
        return 1.0e-6 + size * _UC_PER_BYTE
    if mode == "cached":          # no coherence (single-host only)
        return 100e-9 + size / (132.8 * GB)
    raise ValueError(mode)


def protocol_time(stats, interconnect: Interconnect = CXL_SHM,
                  mode: str = "clflushopt") -> float:
    """Attach time to a CoherentView.ProtocolStats counter set: data motion
    at fabric bandwidth + per-line coherence + fences. This converts the
    executable protocol's event counts into modeled seconds."""
    t = (stats.written_bytes + stats.read_bytes) / interconnect.bandwidth
    per_line = (_FLUSH_PER_LINE / _FLUSHOPT_PAR if mode == "clflushopt"
                else _FLUSH_PER_LINE)
    t += stats.flush_lines * per_line
    t += stats.fences * 50e-9
    t += stats.nt_ops * interconnect.alpha
    t += stats.uncached_ops * (CACHELINE * _UC_PER_BYTE)
    return t
