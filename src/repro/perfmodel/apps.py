"""Communication skeletons of the paper's Fig-10 applications.

* CG (NPB class D): per iteration, dot-product allreduces (8 B) plus
  row/column vector exchanges with ~log2(p) partners. Communication is a
  SMALL fraction of runtime (<15%, paper §4.4); compute dominates and
  strong-scales ~1/p.

* miniAMR (proxy AMR, block size 4^3): per step, face halo exchanges with
  ~6 neighbors plus refinement consensus allreduces. Each rank keeps a
  CONSTANT number of blocks as p grows (the paper gives every process a
  fixed grid-block count), so compute per rank is flat and the
  communication fraction grows with scale (>62%, paper §4.4).

Both emit (compute, sendrecv, allreduce) action streams for
perfmodel.simulator.Engine. Calibration constants are chosen to land in
the paper's reported regimes at 8 procs/node.
"""
from __future__ import annotations

import math
from typing import Iterator

KB = 1024


# --------------------------------------------------------------------------
# CG — conjugate gradient (NPB class D: na=1,500,000, ~100 iterations)
# --------------------------------------------------------------------------

CG_NA = 1_500_000            # class D problem rows
CG_ITERS = 100
CG_FLOP_PER_ROW = 2_700.0    # calibrated: class D ~4e11 flop/iter total
CG_CORE_FLOPS = 6.0e9        # per-core effective flop/s


def cg_program(rank: int, n_ranks: int, *, iters: int = CG_ITERS
               ) -> Iterator:
    rows = CG_NA / n_ranks
    t_compute = rows * CG_FLOP_PER_ROW / CG_CORE_FLOPS
    # CG on a 2D process grid: exchanges with log2(p) partners per iter
    npart = max(1, int(math.log2(max(n_ranks, 2))))
    xfer = int(rows * 8 / max(npart, 1))     # vector segment bytes
    for _ in range(iters):
        yield ("compute", t_compute)
        for k in range(npart):
            peer = rank ^ (1 << k)
            if peer < n_ranks:
                yield ("sendrecv", peer, xfer, k)
        # two dot products per iteration
        yield ("allreduce", 8)
        yield ("allreduce", 8)


# --------------------------------------------------------------------------
# miniAMR — adaptive mesh refinement proxy (block size 4x4x4)
# --------------------------------------------------------------------------

AMR_BLOCKS_PER_RANK = 8        # constant per rank (paper's configuration)
AMR_BLOCK = 4                  # 4x4x4 cells
AMR_VARS = 4                   # variable groups exchanged separately
AMR_STEPS = 40
AMR_FLOP_PER_CELL = 60_000.0
AMR_CORE_FLOPS = 6.0e9
AMR_BLOCK_BYTES = AMR_BLOCK ** 3 * 40 * 4   # full block payload (40 fp32 vars)


def miniamr_program(rank: int, n_ranks: int, *, steps: int = AMR_STEPS
                    ) -> Iterator:
    """Halo exchange is MANY TINY messages (one per block-face-variable:
    a 4x4 face of 4-byte cells = 64 B) — latency-bound, which is where the
    16 us Ethernet vs 18 us CX-6 alpha decides small-scale performance.
    Every ~20 steps, refinement REDISTRIBUTES whole blocks across nodes —
    bandwidth-bound, which is what sinks Ethernet beyond ~8 nodes
    (paper §4.4: 'at small scales latency-dominated, at larger scales
    bandwidth becomes the limiting factor')."""
    cells = AMR_BLOCKS_PER_RANK * AMR_BLOCK ** 3
    t_compute = cells * AMR_FLOP_PER_CELL / AMR_CORE_FLOPS
    face = AMR_BLOCK * AMR_BLOCK * 2                  # 32 B: one face, one var
    nodes = max(1, n_ranks // 8)
    cross = 1.0 - 1.0 / nodes if nodes > 1 else 0.0
    redis = int(AMR_BLOCKS_PER_RANK * AMR_BLOCK_BYTES * cross)
    for step in range(steps):
        yield ("compute", t_compute)
        for axis in range(3):
            stride = max(1, round(n_ranks ** (axis / 3)))
            for s in (+stride, -stride):
                peer = (rank + s) % n_ranks
                if peer == rank:
                    continue
                for b in range(AMR_BLOCKS_PER_RANK):
                    for v in range(AMR_VARS):
                        yield ("sendrecv", peer, face, 64 + axis)
        # refinement: consensus + block redistribution (half the blocks
        # move, every 10 steps — the bandwidth-bound phase)
        if step % 10 == 5:
            yield ("allreduce", AMR_BLOCKS_PER_RANK * 8)
            if redis:
                yield ("sendrecv", (rank + n_ranks // 2) % n_ranks,
                       redis // 2, 99)
