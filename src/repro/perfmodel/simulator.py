"""Event-driven MPI simulator (the paper's SimGrid role, §4.4).

Rank programs are generator coroutines yielding actions; the engine
advances virtual time:

    yield ("compute", seconds)
    yield ("send", dst, nbytes, tag)      # non-blocking injection
    yield ("recv", src, nbytes, tag)      # blocks until matching arrival
    yield ("sendrecv", peer, nbytes, tag) # symmetric exchange
    yield ("allreduce", nbytes)           # collective (ring model)
    yield ("barrier",)

Network model — the paper's SimGrid configuration: links carry the RAW
fabric alpha-beta from Table 1 (16 us Ethernet vs 18 us CX-6 TCP is exactly
why Ethernet wins miniAMR at <=8 nodes), plus a fabric-independent
per-message MPI software cost. Inter-node messages share the node's single
port (NIC / CXL link) with the other ranks on the node: effective bytes =
size * sharers, sharers ~= ppn * (1 - 1/nodes) — this is what makes the
117.8 MB/s Ethernet NIC the limiting factor at scale while latency rules
small scales (paper §4.4's stated mechanism). Intra-node messages ride main
memory. Collectives use the ring decomposition:
  allreduce(n ranks, s bytes) = 2(n-1) steps of (t_sw + alpha + shard/bw).

This is deliberately a THIN simulator — enough to reproduce the paper's
Fig 10 strong-scaling study (CG, miniAMR) with configured lat/bw, not a
general platform simulator.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

from repro.perfmodel.interconnects import Interconnect, MAIN_MEMORY


@dataclass
class Proc:
    rank: int
    gen: Iterator
    time: float = 0.0
    blocked: Any = None          # ("recv", src, nbytes, tag) | barrier token


class Engine:
    def __init__(self, n_ranks: int, fabric: Interconnect,
                 procs_per_node: int = 8,
                 intra: Interconnect = MAIN_MEMORY, *,
                 onesided: bool = False):
        self.n = n_ranks
        self.fabric = fabric
        self.intra = intra
        self.ppn = procs_per_node
        self.onesided = onesided
        # (src, dst, tag) -> list of arrival times
        self.mailbox: dict[tuple[int, int, int], list[float]] = {}
        self.comm_time = [0.0] * n_ranks
        self.compute_time = [0.0] * n_ranks

    T_SW = 1.5e-6        # fabric-independent per-message MPI software cost

    def _node(self, r: int) -> int:
        return r // self.ppn

    @property
    def nodes(self) -> int:
        return max(1, self.n // self.ppn)

    def _sharers(self) -> float:
        """Ranks contending for the node's single inter-node port."""
        return max(1.0, self.ppn * (1.0 - 1.0 / self.nodes))

    def _msg_time(self, a: int, b: int, nbytes: int) -> float:
        if self._node(a) == self._node(b):
            return self.T_SW + self.intra.raw_latency(nbytes)
        ic = self.fabric
        return self.T_SW + ic.alpha + nbytes * self._sharers() / ic.bandwidth

    def _allreduce_time(self, nbytes: int) -> float:
        """Small payloads: recursive doubling (log2 n rounds, full size).
        Large payloads: ring reduce-scatter + all-gather (2(n-1) rounds of
        1/n size). The inter-node hop paces every round once the job spans
        nodes — MPICH's size-switched algorithm choice."""
        if self.n == 1:
            return 0.0
        import math as _m

        def hop(size: int) -> float:
            if self.nodes <= 1:
                return self.T_SW + self.intra.raw_latency(size)
            return (self.T_SW + self.fabric.alpha
                    + size * self._sharers() / self.fabric.bandwidth)

        rd = _m.ceil(_m.log2(self.n)) * hop(nbytes)
        ring = 2 * (self.n - 1) * hop(max(nbytes // self.n, 1))
        return min(rd, ring)

    # ------------------------------------------------------------------
    def run(self, make_prog: Callable[[int], Iterator]) -> dict:
        procs = [Proc(r, make_prog(r)) for r in range(self.n)]
        barrier_wait: list[Proc] = []
        # receivers blocked on a (src, dst, tag) with no message yet;
        # woken by the matching send (no polling)
        waiting: dict[tuple[int, int, int], Proc] = {}

        ready = [(0.0, r) for r in range(self.n)]
        heapq.heapify(ready)
        done = 0
        guard = 0
        while done < self.n:
            guard += 1
            if guard > 50_000_000:
                raise RuntimeError("simulator livelock")
            if not ready:
                raise RuntimeError("simulator deadlock: no runnable rank")
            t, r = heapq.heappop(ready)
            p = procs[r]
            p.time = max(p.time, t)
            try:
                action = next(p.gen)
            except StopIteration:
                done += 1
                continue
            kind = action[0]
            if kind == "compute":
                self.compute_time[r] += action[1]
                p.time += action[1]
                heapq.heappush(ready, (p.time, r))
            elif kind == "send":
                _, dst, nbytes, tag = action
                arrive = p.time + self._msg_time(r, dst, nbytes)
                key = (r, dst, tag)
                blocked = waiting.pop(key, None)
                if blocked is not None:
                    wait = max(arrive - blocked.time, 0.0)
                    self.comm_time[blocked.rank] += wait
                    blocked.time = max(blocked.time, arrive)
                    heapq.heappush(ready, (blocked.time, blocked.rank))
                else:
                    self.mailbox.setdefault(key, []).append(arrive)
                # eager injection: sender proceeds immediately
                heapq.heappush(ready, (p.time, r))
            elif kind == "recv":
                _, src, nbytes, tag = action
                box = self.mailbox.get((src, r, tag))
                if box:
                    arrive = box.pop(0)
                    wait = max(arrive - p.time, 0.0)
                    self.comm_time[r] += wait
                    p.time = max(p.time, arrive)
                    heapq.heappush(ready, (p.time, r))
                else:
                    waiting[(src, r, tag)] = p   # sleep until the send
            elif kind == "sendrecv":
                _, peer, nbytes, tag = action
                tmsg = self._msg_time(r, peer, nbytes)
                self.comm_time[r] += tmsg
                p.time += tmsg
                heapq.heappush(ready, (p.time, r))
            elif kind == "allreduce":
                tar = self._allreduce_time(action[1])
                self.comm_time[r] += tar
                p.time += tar
                barrier_wait.append(p)
                if len(barrier_wait) == self.n:
                    tmax = max(q.time for q in barrier_wait)
                    for q in barrier_wait:
                        self.comm_time[q.rank] += tmax - q.time
                        q.time = tmax
                        heapq.heappush(ready, (q.time, q.rank))
                    barrier_wait = []
            elif kind == "barrier":
                barrier_wait.append(p)
                if len(barrier_wait) == self.n:
                    tmax = max(q.time for q in barrier_wait)
                    for q in barrier_wait:
                        self.comm_time[q.rank] += tmax - q.time
                        q.time = tmax
                        heapq.heappush(ready, (q.time, q.rank))
                    barrier_wait = []
            else:
                raise ValueError(kind)
        if waiting:
            raise RuntimeError(
                f"simulator deadlock: receivers never matched: "
                f"{list(waiting)[:4]}")
        total = max(p.time for p in procs)
        return {
            "total_s": total,
            "comm_s": max(self.comm_time),
            "compute_s": max(self.compute_time),
            "comm_fraction": max(self.comm_time) / total if total else 0.0,
        }
