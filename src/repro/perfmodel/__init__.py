from repro.perfmodel.interconnects import (CXL_SHM, CXL_SHM_NOFLUSH,
                                           ETHERNET_TCP, INFINIBAND_CX6,
                                           INTERCONNECTS, MAIN_MEMORY,
                                           MELLANOX_TCP, ROCE_CX3, ROCE_CX6,
                                           Interconnect, coherence_latency)
from repro.perfmodel.simulator import Engine, Proc
