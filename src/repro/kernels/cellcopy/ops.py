"""jit'd public wrapper for the cellcopy kernel: message-buffer in/out with
padding to lane alignment, plus verification helper."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cellcopy.kernel import LANE, cellcopy


def copy_message(buf: np.ndarray | jax.Array, cell_bytes: int = 16384, *,
                 block_cells: int = 8, interpret: bool = True):
    """Copy a flat uint8 message through cell-granular kernel DMA.

    Returns (copied uint8 array of the original length, checksums)."""
    buf = jnp.asarray(buf, dtype=jnp.uint8)
    n = buf.shape[0]
    words_per_cell = cell_bytes // 4
    words_per_cell += (-words_per_cell) % LANE
    cell_bytes = words_per_cell * 4
    n_cells = -(-n // cell_bytes)
    n_cells += (-n_cells) % block_cells
    pad = n_cells * cell_bytes - n
    flat = jnp.pad(buf, (0, pad))
    cells = flat.view(jnp.int32).reshape(n_cells, words_per_cell)
    dst, sums = cellcopy(cells, block_cells=block_cells, interpret=interpret)
    out = dst.reshape(-1).view(jnp.uint8)[:n]
    return out, sums


def verify(cells: jax.Array, sums: jax.Array) -> jax.Array:
    """Consumer-side validity check (what the header word buys us)."""
    expect = jnp.sum(cells.astype(jnp.uint32), axis=1, dtype=jnp.uint32)
    return jnp.all(expect == sums)
