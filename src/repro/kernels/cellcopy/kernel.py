"""Pallas cell-copy kernel — the TPU reading of cMPI's data plane.

cMPI's hot loop is the CPU ``mov``-driven copy of message cells between a
local buffer and the CXL pool, with a coherence epilogue per cell
(paper §3.3, §4.3). On TPU the analogue of the 'message cell' is the VMEM
block: HBM -> VMEM -> HBM chunked copy, double-buffered by the Pallas
pipeline across grid steps, with a fused per-cell checksum standing in for
the header/validity epilogue (so the consumer can verify a cell without a
second pass over HBM).

The BlockSpec cell shape is the tunable that reproduces the paper's Fig-9
cell-size study as a TPU block-shape sweep (benchmarks/fig9_cellsize.py):
too-small cells waste pipeline latency per cell, too-large cells overflow
VMEM — same tradeoff, different memory hierarchy.

Layout: messages are (n_cells, cell_bytes/4) int32 words, cell rows 128-
word aligned (the MXU/VPU lane width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _cellcopy_body(src_ref, dst_ref, sum_ref):
    """One grid step: copy `block_cells` cells and emit their checksums."""
    data = src_ref[...]                       # (block_cells, words) int32
    dst_ref[...] = data
    # wrapping u32 sum per cell — the validity word the consumer checks
    s = jnp.sum(data.astype(jnp.uint32), axis=1, dtype=jnp.uint32)
    sum_ref[...] = s


@functools.partial(jax.jit, static_argnames=("block_cells", "interpret"))
def cellcopy(src: jax.Array, *, block_cells: int = 8,
             interpret: bool = True):
    """Copy (n_cells, words) int32 cells; returns (dst, checksums u32).

    ``block_cells`` cells ride one VMEM block per grid step; the Pallas
    pipeline double-buffers the HBM->VMEM->HBM stream across steps.
    """
    n_cells, words = src.shape
    assert n_cells % block_cells == 0, (n_cells, block_cells)
    assert words % LANE == 0, f"cell words {words} not {LANE}-aligned"
    grid = (n_cells // block_cells,)
    return pl.pallas_call(
        _cellcopy_body,
        grid=grid,
        in_specs=[pl.BlockSpec((block_cells, words), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_cells, words), lambda i: (i, 0)),
            pl.BlockSpec((block_cells,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_cells, words), src.dtype),
            jax.ShapeDtypeStruct((n_cells,), jnp.uint32),
        ],
        interpret=interpret,
    )(src)


def vmem_bytes(block_cells: int, words: int) -> int:
    """VMEM working set claimed by one grid step (src + dst blocks,
    double-buffered by the pipeline => x2)."""
    return 2 * 2 * block_cells * words * 4
