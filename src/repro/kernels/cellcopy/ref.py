"""Pure-jnp oracle for the cellcopy kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cellcopy_ref(src: jax.Array):
    dst = src
    sums = jnp.sum(src.astype(jnp.uint32), axis=1, dtype=jnp.uint32)
    return dst, sums
