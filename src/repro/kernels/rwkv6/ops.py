"""Public wrapper matching models/blocks layout: (B, S, H, n) tensors."""
from __future__ import annotations

import jax

from repro.kernels.rwkv6.kernel import wkv6


def wkv6_bshn(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, *, chunk: int = 32,
              interpret: bool | None = None) -> jax.Array:
    """r,k,v,w: (B, S, H, n); u: (H, n) -> (B, S, H, n) f32
    (the models/blocks._wkv6_scan layout)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    args = [a.transpose(0, 2, 1, 3) for a in (r, k, v, w)]
    out = wkv6(*args, u, chunk=chunk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
