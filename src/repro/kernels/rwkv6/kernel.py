"""Pallas chunked WKV6 (RWKV-6 'Finch') kernel.

Recurrence (per head, n = head size):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state n x n)
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

The sequential oracle (models/blocks._wkv6_scan) is O(S) steps of rank-1
updates — latency-bound on any hardware. The chunked-parallel form turns a
chunk of L tokens into dense L x n / n x n matmuls (MXU food):

  with P_t = prod_{j<=t} w_j (per-channel cumulative decay inside a chunk),
    o_t   = (r_t * P_{t-1}) S_0                       <- inter-chunk
          + sum_{i<t} [(r_t * P_{t-1}/P_i) . k_i] v_i <- intra-chunk
          + (r_t * u . k_t) v_t                       <- current token
    S_L   = diag(P_L) S_0 + sum_i (P_L / P_i * k_i) v_i^T

Grid (B, H, n_chunks): the chunk axis is innermost/sequential, so the f32
state S rides in VMEM scratch across chunk steps — the standard Pallas
carry pattern. L is kept small (32) so the decay ratios P/P_i stay in f32
range (w in (0,1); worst case w^-L).

All math f32; inputs (r, k, v, w) are pre-projected (B, H, S, n) tensors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_body(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, L: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)       # (L, n)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)       # decay in (0, 1)
    u = u_ref[0].astype(jnp.float32)          # (n,)
    S0 = s_ref[...]                           # (n, n)

    P = jnp.cumprod(w, axis=0)                # (L, n): prod_{j<=t} w_j
    Pprev = jnp.concatenate([jnp.ones((1, P.shape[1]), jnp.float32),
                             P[:-1]], axis=0)            # prod_{j<t}

    rP = r * Pprev                            # (L, n)
    # inter-chunk: (r_t * P_{t-1}) @ S0
    o = jax.lax.dot_general(rP, S0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk: att[t, i] = sum_c rP[t,c] * (k[i,c] / P[i,c]),  i < t
    kP = k / P
    att = jax.lax.dot_general(rP, kP, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (L, L)
    ti = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    ij = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    att = jnp.where(ij < ti, att, 0.0)        # strictly lower triangular
    o = o + jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # current token bonus: (r_t * u . k_t) v_t
    o = o + jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    o_ref[0, 0] = o.astype(o_ref.dtype)

    # state update: S_L = diag(P_L) S0 + sum_i ((P_L / P_i) * k_i) v_i^T
    kS = (P[-1][None, :] / P) * k             # (L, n)
    s_new = P[-1][:, None] * S0 + jax.lax.dot_general(
        kS, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (n, n)
    s_ref[...] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, chunk: int = 32,
         interpret: bool = True) -> jax.Array:
    """r,k,v,w: (B, H, S, n); u: (H, n). Returns (B, H, S, n) f32."""
    b, h, s, n = r.shape
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L
    body = functools.partial(_wkv6_body, L=L)
    return pl.pallas_call(
        body,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, n), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, L, n), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, L, n), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, L, n), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, n), lambda b_, h_, c: (h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, n), lambda b_, h_, c: (b_, h_, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
