"""Sequential oracle for WKV6 — numerically exact rank-1 recurrence.
(Same math as models/blocks._wkv6_scan, re-exported in kernel layout.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def wkv6_ref(r, k, v, w, u):
    """r,k,v,w: (B, H, S, n); u: (H, n) -> (B, H, S, n) f32."""
    b, h, s, n = r.shape

    def step(state, inp):
        rt, kt, vt, wt = inp                  # (b, h, n)
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        o = jnp.einsum("bhn,bhnm->bhm", rt,
                       state + u[None, :, :, None] * kv)
        state = state * wt[..., None] + kv
        return state, o

    xs = tuple(a.transpose(2, 0, 1, 3).astype(jnp.float32)
               for a in (r, k, v, w))
    state0 = jnp.zeros((b, h, n, n), jnp.float32)
    _, os_ = lax.scan(step, state0, xs)
    return os_.transpose(1, 2, 0, 3)
