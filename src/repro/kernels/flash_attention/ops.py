"""Public wrapper: (B, S, H, D)-layout attention entry point matching
models/blocks.py conventions, dispatching to the Pallas kernel.

On a real TPU ``interpret=False`` compiles the kernel; in this container
(CPU) interpret mode executes the same kernel body for validation.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention


def flash_attention_bshd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, block_q: int = 128,
                         block_k: int = 128,
                         interpret: bool | None = None) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, KV, D) — the blocks.py layout."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = flash_attention(qh, kh, vh, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
