"""Pure-jnp oracle for flash attention (exact softmax attention)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, KV, S, D). fp32 softmax, out in q.dtype."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    group = h // kv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
