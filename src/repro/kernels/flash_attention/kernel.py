"""Pallas flash attention (causal, GQA) — online-softmax tiling for VMEM.

Grid (B, H, nq, nk); the kv axis is the innermost (sequential) dimension so
the f32 accumulator + running (m, l) live in VMEM scratch across kv steps
and the output block is written once on the last kv step. Causal blocks
strictly above the diagonal are skipped with @pl.when (zero work, not just
masking). GQA is free: the k/v BlockSpec index_map sends query head h to
kv head h // (H // KV), so no repeated-KV materialization in HBM.

VMEM per step: q(bq,d) + k(bk,d) + v(bk,d) in compute dtype + f32
acc(bq,d) + m,l(bq) — with bq=bk=128, d=128, bf16: ~160 KB. MXU dims are
128-aligned by construction.

Target numerics match the jnp oracle: scores f32, exp in f32, accumulate
f32, final out cast to q.dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: kv block strictly above the q block's diagonal does no work
    run = (not causal) or (ki * bk <= qi * bq + (bq - 1))

    @pl.when(run)
    def _step():
        q = q_ref[0, 0]                        # (bq, d)
        k = k_ref[0, 0]                        # (bk, d)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, KV, S, D) with H % KV == 0.
    Returns (B, H, S, D) in q.dtype."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    assert h % kv == 0, (h, kv)
    group = h // kv
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / math.sqrt(d)

    body = functools.partial(_flash_body, scale=scale, causal=causal,
                             bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        body,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            # f32 accumulator + running max / normalizer across kv steps
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
