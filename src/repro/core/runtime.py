"""Multi-rank runtimes for the cMPI library.

* ``run_threads``  — N ranks as threads over ONE pool. With
  ``coherent=True`` the pool is a plain LocalPool (threads on one host are
  coherent, like processes on one x86 node). With ``coherent=False`` every
  rank gets a PRIVATE write-back cache over the shared backing pool — the
  executable model of the paper's non-coherent CXL platform; the
  software-coherence protocol in core/* is then load-bearing.

* ``run_processes`` — N ranks as real processes over a
  multiprocessing SharedMemoryPool. This is the measurement configuration
  for the OSU-style benchmarks (real memory fabric vs. real TCP sockets).

Both hand each rank a ``RankEnv`` whose ``comm`` is a v2 ``Comm``
(method collectives, split/dup, persistent requests); pass
``eager_threshold="auto"`` to have every rank micro-probe its
eager/rendezvous crossover at init. Both return per-rank results and
re-raise the first rank failure.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
import traceback
from dataclasses import dataclass
from typing import Any, Callable

from repro.core.arena import Arena
from repro.core.comm import Comm
from repro.core.pool import IncoherentPool, LocalPool, Pool, RankCache, \
    SharedMemoryPool


@dataclass
class RankEnv:
    rank: int
    size: int
    arena: Arena
    comm: Comm


def _make_arena(pool: Pool, rank: int, coherent: bool,
                arena_kw: dict) -> Arena:
    if coherent:
        return Arena(pool, rank, mode="coherent",
                     initialize=(rank == 0), **arena_kw)
    cache = RankCache(pool)
    inc = IncoherentPool(pool, cache)
    return Arena(inc, rank, mode="incoherent",
                 initialize=(rank == 0), **arena_kw)


def run_threads(size: int, fn: Callable[[RankEnv], Any], *,
                pool_bytes: int = 8 << 20, coherent: bool = True,
                cell_size: int = 4096, n_cells: int = 8,
                eager_threshold: int | str | None = None,
                arena_kw: dict | None = None,
                comm_kw: dict | None = None,
                timeout: float = 60.0) -> list[Any]:
    pool = LocalPool(pool_bytes)
    arena_kw = arena_kw or {}
    comm_kw = comm_kw or {}
    results: list[Any] = [None] * size
    errors: list[tuple[int, BaseException, str]] = []
    gate = threading.Barrier(size)

    # rank 0 must initialize the arena before others map it
    arenas: list[Arena | None] = [None] * size
    arenas[0] = _make_arena(pool, 0, coherent, arena_kw)
    for r in range(1, size):
        arenas[r] = _make_arena(pool, r, coherent, arena_kw)

    def worker(rank: int):
        try:
            comm = Comm(arenas[rank], rank, size,
                        cell_size=cell_size, n_cells=n_cells,
                        eager_threshold=eager_threshold, **comm_kw)
            gate.wait(timeout)
            results[rank] = fn(RankEnv(rank, size, arenas[rank], comm))
        except BaseException as e:  # noqa: BLE001 — reported to the caller
            errors.append((rank, e, traceback.format_exc()))

    threads = [threading.Thread(target=worker, args=(r,), daemon=True)
               for r in range(size)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout)
    alive = [t for t in threads if t.is_alive()]
    if alive:
        raise TimeoutError(f"{len(alive)} ranks still running "
                           f"(deadlock?); errors so far: {errors}")
    if errors:
        rank, e, tb = errors[0]
        raise RuntimeError(f"rank {rank} failed:\n{tb}") from e
    return results


# --------------------------------------------------------------------------
# real processes over real shared memory
# --------------------------------------------------------------------------

def _proc_entry(shm_name: str, rank: int, size: int, fn, cell_size: int,
                n_cells: int, eager_threshold: int | str | None,
                arena_kw: dict, comm_kw: dict, q: mp.Queue):
    try:
        pool = SharedMemoryPool(0, name=shm_name, create=False)
        arena = Arena(pool, rank, mode="coherent", initialize=False,
                      **arena_kw)
        comm = Comm(arena, rank, size, cell_size=cell_size,
                    n_cells=n_cells, eager_threshold=eager_threshold,
                    **comm_kw)
        out = fn(RankEnv(rank, size, arena, comm))
        q.put((rank, "ok", out))
        pool.close()
    except BaseException:  # noqa: BLE001
        q.put((rank, "err", traceback.format_exc()))


def run_processes(size: int, fn: Callable[[RankEnv], Any], *,
                  pool_bytes: int = 64 << 20,
                  cell_size: int = 16384, n_cells: int = 8,
                  eager_threshold: int | str | None = None,
                  arena_kw: dict | None = None,
                  comm_kw: dict | None = None,
                  timeout: float = 120.0) -> list[Any]:
    arena_kw = arena_kw or {}
    comm_kw = comm_kw or {}
    pool = SharedMemoryPool(pool_bytes, create=True)
    try:
        # rank 0's arena initialization happens in the parent so children
        # never race on the header
        Arena(pool, 0, mode="coherent", initialize=True, **arena_kw)
        ctx = mp.get_context("fork")
        q: mp.Queue = ctx.Queue()
        procs = [ctx.Process(target=_proc_entry,
                             args=(pool.name, r, size, fn, cell_size,
                                   n_cells, eager_threshold, arena_kw,
                                   comm_kw, q),
                             daemon=True)
                 for r in range(size)]
        for p in procs:
            p.start()
        results: list[Any] = [None] * size
        got = 0
        errs = []
        while got < size:
            rank, status, payload = q.get(timeout=timeout)
            got += 1
            if status == "ok":
                results[rank] = payload
            else:
                errs.append((rank, payload))
        for p in procs:
            p.join(timeout=10)
            if p.is_alive():
                p.terminate()
        if errs:
            raise RuntimeError(
                f"rank {errs[0][0]} failed:\n{errs[0][1]}")
        return results
    finally:
        pool.close()
        pool.unlink()
