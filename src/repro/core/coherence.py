"""Software cache coherence (paper §3.5) as an explicit, testable protocol.

The CXL pooled platform is NOT hardware-coherent across hosts. The paper's
protocol:

  after every write :  cache flush (clwb/clflushopt)  then  sfence
  before every read :  fence                          then  flush/invalidate

plus non-temporal load/store for control words (queue head/tail pointers,
sync flags) so they never linger in cache.

``CoherentView`` wraps a pool and applies that protocol. Three modes:

  * "coherent"    — backing pool is already coherent (LocalPool shared by
                    threads, SharedMemoryPool across processes on one x86
                    host). Protocol calls are COUNTED (for the timing model,
                    calibrated to Fig 11) but are memory no-ops.
  * "incoherent"  — backing pool is an IncoherentPool (per-rank write-back
                    cache). The protocol is REQUIRED for correctness; tests
                    prove omitting it produces stale reads.
  * "uncacheable" — every access bypasses the cache (the paper's MTRR
                    experiment). Correct, counted as uncached accesses, and
                    shown by the perf model to be catastrophically slow
                    beyond 2 KB (PCIe MPS packetization, Fig 11).

The latency model attached to these counters lives in
``repro.perfmodel.interconnects`` — this module only counts events.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.pool import CACHELINE, IncoherentPool, Pool

MODES = ("coherent", "incoherent", "uncacheable")


@dataclass
class ProtocolStats:
    writes: int = 0
    reads: int = 0
    written_bytes: int = 0
    read_bytes: int = 0
    flush_lines: int = 0
    fences: int = 0
    nt_ops: int = 0             # non-temporal control-word accesses
    uncached_ops: int = 0

    def lines(self, n: int) -> int:
        return (n + CACHELINE - 1) // CACHELINE


class CoherentView:
    """Protocol-applying accessor for one rank over one pool."""

    def __init__(self, pool: Pool, mode: str = "coherent"):
        assert mode in MODES, mode
        self.pool = pool
        self.mode = mode
        self.stats = ProtocolStats()
        self._inc = isinstance(pool, IncoherentPool)
        if mode == "incoherent" and not self._inc:
            raise ValueError("incoherent mode requires an IncoherentPool")

    # ------------------------------------------------------------------
    # raw (protocol-free) access — used by tests to demonstrate staleness
    # ------------------------------------------------------------------
    def raw_read(self, off: int, n: int) -> bytes:
        return self.pool.read(off, n)

    def raw_write(self, off: int, data: bytes) -> None:
        self.pool.write(off, data)

    # ------------------------------------------------------------------
    # protocol access
    # ------------------------------------------------------------------
    def write_release(self, off: int, data: bytes) -> None:
        """store; flush; sfence — makes the write globally visible."""
        n = len(data)
        self.stats.writes += 1
        self.stats.written_bytes += n
        if self.mode == "uncacheable":
            self.stats.uncached_ops += self.stats.lines(n)
            self.pool.write(off, data)
            return
        self.pool.write(off, data)
        if self._inc:
            self.pool.flush(off, n)       # write back + invalidate
            self.pool.fence()
        self.stats.flush_lines += self.stats.lines(n)
        self.stats.fences += 1

    def read_acquire(self, off: int, n: int) -> bytes:
        """lfence; invalidate; load — defeats stale cached/prefetched data."""
        self.stats.reads += 1
        self.stats.read_bytes += n
        if self.mode == "uncacheable":
            self.stats.uncached_ops += self.stats.lines(n)
            return self.pool.read(off, n)
        if self._inc:
            self.pool.fence()
            self.pool.invalidate(off, n)  # drop stale lines
        self.stats.flush_lines += self.stats.lines(n)
        self.stats.fences += 1
        return self.pool.read(off, n)

    # ------------------------------------------------------------------
    # non-temporal control words (u64 head/tail pointers, flags)
    # ------------------------------------------------------------------
    def nt_store_u64(self, off: int, value: int) -> None:
        self.stats.nt_ops += 1
        data = int(value).to_bytes(8, "little")
        if self._inc:
            # non-temporal: write straight to the pool, bypassing the cache,
            # and kill any stale private copy of that line.
            self.pool.backing.write(off, data)
            self.pool.invalidate(off, 8)
        else:
            self.pool.write(off, data)

    def nt_load_u64(self, off: int) -> int:
        self.stats.nt_ops += 1
        if self._inc:
            self.pool.invalidate(off, 8)
            data = self.pool.backing.read(off, 8)
        else:
            data = self.pool.read(off, 8)
        return int.from_bytes(data, "little")

    def nt_store_u8(self, off: int, value: int) -> None:
        self.stats.nt_ops += 1
        data = bytes([value & 0xFF])
        if self._inc:
            self.pool.backing.write(off, data)
            self.pool.invalidate(off, 1)
        else:
            self.pool.write(off, data)

    def nt_load_u8(self, off: int) -> int:
        self.stats.nt_ops += 1
        if self._inc:
            self.pool.invalidate(off, 1)
            return self.pool.backing.read(off, 1)[0]
        return self.pool.read(off, 1)[0]

    def nt_store_u32(self, off: int, value: int) -> None:
        self.stats.nt_ops += 1
        data = int(value).to_bytes(4, "little")
        if self._inc:
            self.pool.backing.write(off, data)
            self.pool.invalidate(off, 4)
        else:
            self.pool.write(off, data)

    def nt_load_u32(self, off: int) -> int:
        self.stats.nt_ops += 1
        if self._inc:
            self.pool.invalidate(off, 4)
            data = self.pool.backing.read(off, 4)
        else:
            data = self.pool.read(off, 4)
        return int.from_bytes(data, "little")
