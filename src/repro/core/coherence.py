"""Software cache coherence (paper §3.5) as an explicit, testable protocol.

The CXL pooled platform is NOT hardware-coherent across hosts. The paper's
protocol:

  after every write :  cache flush (clwb/clflushopt)  then  sfence
  before every read :  fence                          then  flush/invalidate

plus non-temporal load/store for control words (queue head/tail pointers,
sync flags) so they never linger in cache.

``CoherentView`` wraps a pool and applies that protocol. Three modes:

  * "coherent"    — backing pool is already coherent (LocalPool shared by
                    threads, SharedMemoryPool across processes on one x86
                    host). Protocol calls are COUNTED (for the timing model,
                    calibrated to Fig 11) but are memory no-ops.
  * "incoherent"  — backing pool is an IncoherentPool (per-rank write-back
                    cache). The protocol is REQUIRED for correctness; tests
                    prove omitting it produces stale reads.
  * "uncacheable" — every access bypasses the cache (the paper's MTRR
                    experiment). Correct, counted as uncached accesses, and
                    shown by the perf model to be catastrophically slow
                    beyond 2 KB (PCIe MPS packetization, Fig 11).

The latency model attached to these counters lives in
``repro.perfmodel.interconnects`` — this module only counts events.

``ProtocolStats`` additionally counts DATA COPIES: every byte that moves
through the protocol layer (user buffer -> pool, pool -> user buffer, or
an explicit staging memcpy reported via ``count_copy``). This includes
framing — cell/message headers, rendezvous descriptors — and any arena
metadata traffic issued through the same view; only non-temporal control
words (nt_ops) are excluded. Copies-per-message is the paper's
performance model for CXL messaging, and the eager-vs-rendezvous
benchmark (benchmarks/fig5_8_osu.py) reports the per-message delta.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.pool import CACHELINE, IncoherentPool, Pool, as_u8

MODES = ("coherent", "incoherent", "uncacheable")


@dataclass
class ProtocolStats:
    writes: int = 0
    reads: int = 0
    written_bytes: int = 0
    read_bytes: int = 0
    flush_lines: int = 0
    fences: int = 0
    nt_ops: int = 0             # non-temporal control-word accesses
    uncached_ops: int = 0
    # every physical data move through the view: payload AND framing/
    # metadata bytes (headers, descriptors, arena slots); nt control
    # words are counted separately as nt_ops
    copies: int = 0
    copied_bytes: int = 0
    # attribution overlay: PAYLOAD bytes of the copies above, broken down
    # by the pt2pt data-plane path that moved them (the messaging layers
    # report via count_path). Not additive to copied_bytes — framing,
    # descriptors and arena metadata stay unattributed.
    path_copied_bytes: dict = field(default_factory=lambda: {
        "eager": 0, "rndv_staged": 0, "rndv_posted": 0,
        # one-sided (RMA) data-plane paths: direct window stores/loads
        # (put/get/rput/rget/accumulate), the notified-put fast path
        # (put_notify — zero receiver-side copies by construction), and
        # the schedule-compiled RMA collectives (PutOp/GetOp nodes)
        "rma_put": 0, "rma_get": 0, "rma_notify": 0, "rma_coll": 0})
    # postable receives whose matchbox posting was still waiting in the
    # per-pair OVERFLOW list when a fallback (eager/staged/parked)
    # delivery completed them — i.e. capacity cost the receive its
    # one-copy path. Postings that spill but get PROMOTED before their
    # payload arrives are not misses (chunked pre-post bursts through
    # shallow strips legitimately measure 0): a non-zero count says the
    # strips are too shallow for the posting pattern in flight. This is
    # a RECEIVER-side signal; a sender that raced past a not-yet-
    # promoted entry and fell back to staged shows up in the sender's
    # ``posted_sends``/``rndv_sends`` hit ratio instead (the complement
    # the benchmarks gate on) — read both when sizing
    # ``Communicator(matchbox_slots=...)``.
    mb_capacity_misses: int = 0
    # SENDER-side matchbox cost: every strip slot a ``_mb_claim`` call
    # probed (fast-path single-slot probes and full scans alike). A
    # chunked send stream through an N-slot strip that keeps rescanning
    # costs ~N slots per chunk; the claim cursor drops that toward 1 —
    # this counter is the proof (tests/test_tuning.py gates the ratio).
    mb_slots_scanned: int = 0

    def lines(self, n: int) -> int:
        return (n + CACHELINE - 1) // CACHELINE

    def snapshot(self) -> dict:
        """Deep-copied point-in-time view of every counter. Pair with
        :meth:`delta` so benchmarks and tests stop hand-diffing fields::

            s0 = view.stats.snapshot()
            ... traffic ...
            d = view.stats.delta(s0)      # {"copied_bytes": ..., ...}
        """
        out = dict(self.__dict__)
        out["path_copied_bytes"] = dict(self.path_copied_bytes)
        return out

    def delta(self, prev: dict) -> dict:
        """Counter-wise difference of the current stats against a prior
        :meth:`snapshot`. ``path_copied_bytes`` is diffed per path and
        keeps only the paths that moved; scalar counters absent from
        ``prev`` (an older snapshot) diff against zero."""
        out = {}
        for k, v in self.snapshot().items():
            if k == "path_copied_bytes":
                pv = prev.get(k, {})
                out[k] = {p: n - pv.get(p, 0) for p, n in v.items()
                          if n - pv.get(p, 0)}
            else:
                out[k] = v - prev.get(k, 0)
        return out


class CoherentView:
    """Protocol-applying accessor for one rank over one pool."""

    def __init__(self, pool: Pool, mode: str = "coherent"):
        assert mode in MODES, mode
        self.pool = pool
        self.mode = mode
        self.stats = ProtocolStats()
        self._inc = isinstance(pool, IncoherentPool)
        if mode == "incoherent" and not self._inc:
            raise ValueError("incoherent mode requires an IncoherentPool")

    # ------------------------------------------------------------------
    # raw (protocol-free) access — used by tests to demonstrate staleness
    # ------------------------------------------------------------------
    def raw_read(self, off: int, n: int) -> bytes:
        return self.pool.read(off, n)

    def raw_write(self, off: int, data: bytes) -> None:
        self.pool.write(off, data)

    # ------------------------------------------------------------------
    # protocol access
    # ------------------------------------------------------------------
    def count_copy(self, nbytes: int, k: int = 1) -> None:
        """Report ``k`` payload copies of ``nbytes`` each that happened
        outside the view (staging memcpys in the messaging layers)."""
        self.stats.copies += k
        self.stats.copied_bytes += k * nbytes

    def count_path(self, path: str, nbytes: int) -> None:
        """Attribute ``nbytes`` of already-counted payload movement to a
        data-plane path: pt2pt (eager / rndv_staged / rndv_posted),
        one-sided (rma_put / rma_get / rma_notify / rma_coll), or any
        new subsystem's bucket — unknown paths upsert (defaultdict
        style), so e.g. a future serving tier can count ``serve_*``
        buckets without editing this file. The core buckets stay
        pre-declared in ``ProtocolStats`` so zero-traffic paths still
        report 0."""
        pc = self.stats.path_copied_bytes
        pc[path] = pc.get(path, 0) + nbytes

    def count_mb_miss(self) -> None:
        """Report a matchbox capacity miss: a postable receive's spilled
        posting never reached the strip before a fallback delivery
        completed it (the strips are too shallow for the pattern)."""
        self.stats.mb_capacity_misses += 1

    def write_release(self, off: int, data) -> None:
        """store; flush; sfence — makes the write globally visible.
        ``data`` is any C-contiguous buffer-protocol object (bytes,
        memoryview slice, numpy array) — moved into the pool exactly
        once. Single-part case of ``write_release_gather``."""
        self.write_release_gather(off, (data,))

    def write_release_gather(self, off: int, parts) -> int:
        """Scatter-gather write_release: store each part back-to-back
        from ``off``, then ONE flush + fence over the whole span —
        exactly how a queue cell is filled on hardware (stores, clwb the
        span, one sfence). Counts one copy per non-empty part. Returns
        total bytes written."""
        views = [as_u8(p) for p in parts]
        n = sum(len(v) for v in views)
        self.stats.writes += 1
        self.stats.written_bytes += n
        self.stats.copies += sum(1 for v in views if len(v))
        self.stats.copied_bytes += n
        o = off
        for v in views:
            if len(v):
                self.pool.write(o, v)
                o += len(v)
        if self.mode == "uncacheable":
            self.stats.uncached_ops += self.stats.lines(n)
            return n
        if self._inc:
            self.pool.flush(off, n)
            self.pool.fence()
        self.stats.flush_lines += self.stats.lines(n)
        self.stats.fences += 1
        return n

    def read_acquire(self, off: int, n: int) -> bytes:
        """lfence; invalidate; load — defeats stale cached/prefetched data."""
        self.stats.reads += 1
        self.stats.read_bytes += n
        self.stats.copies += 1
        self.stats.copied_bytes += n
        if self.mode == "uncacheable":
            self.stats.uncached_ops += self.stats.lines(n)
            return self.pool.read(off, n)
        if self._inc:
            self.pool.fence()
            self.pool.invalidate(off, n)  # drop stale lines
        self.stats.flush_lines += self.stats.lines(n)
        self.stats.fences += 1
        return self.pool.read(off, n)

    def read_acquire_into(self, off: int, dst) -> int:
        """lfence; invalidate; load straight into the caller's writable
        buffer — the pool-to-destination move happens exactly once, with
        no intermediate ``bytes``. Returns bytes read (= len(dst))."""
        d = as_u8(dst)
        n = len(d)
        self.stats.reads += 1
        self.stats.read_bytes += n
        self.stats.copies += 1
        self.stats.copied_bytes += n
        if self.mode == "uncacheable":
            self.stats.uncached_ops += self.stats.lines(n)
            return self.pool.readinto(off, d)
        if self._inc:
            self.pool.fence()
            self.pool.invalidate(off, n)
        self.stats.flush_lines += self.stats.lines(n)
        self.stats.fences += 1
        return self.pool.readinto(off, d)

    # ------------------------------------------------------------------
    # non-temporal control words (u64 head/tail pointers, flags)
    # ------------------------------------------------------------------
    def nt_store_u64(self, off: int, value: int) -> None:
        self.stats.nt_ops += 1
        data = int(value).to_bytes(8, "little")
        if self._inc:
            # non-temporal: write straight to the pool, bypassing the cache,
            # and kill any stale private copy of that line.
            self.pool.backing.write(off, data)
            self.pool.invalidate(off, 8)
        else:
            self.pool.write(off, data)

    def nt_load_u64(self, off: int) -> int:
        self.stats.nt_ops += 1
        if self._inc:
            self.pool.invalidate(off, 8)
            data = self.pool.backing.read(off, 8)
        else:
            data = self.pool.read(off, 8)
        return int.from_bytes(data, "little")

    def nt_store_u8(self, off: int, value: int) -> None:
        self.stats.nt_ops += 1
        data = bytes([value & 0xFF])
        if self._inc:
            self.pool.backing.write(off, data)
            self.pool.invalidate(off, 1)
        else:
            self.pool.write(off, data)

    def nt_load_u8(self, off: int) -> int:
        self.stats.nt_ops += 1
        if self._inc:
            self.pool.invalidate(off, 1)
            return self.pool.backing.read(off, 1)[0]
        return self.pool.read(off, 1)[0]

    def nt_store_u32(self, off: int, value: int) -> None:
        self.stats.nt_ops += 1
        data = int(value).to_bytes(4, "little")
        if self._inc:
            self.pool.backing.write(off, data)
            self.pool.invalidate(off, 4)
        else:
            self.pool.write(off, data)

    def nt_load_u32(self, off: int) -> int:
        self.stats.nt_ops += 1
        if self._inc:
            self.pool.invalidate(off, 4)
            data = self.pool.backing.read(off, 4)
        else:
            data = self.pool.read(off, 4)
        return int.from_bytes(data, "little")
