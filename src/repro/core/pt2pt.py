"""Two-sided communication: the cMPI Communicator (paper §3.3).

Send/recv over the SPSC queue matrix: the sender enqueues into queue
(receiver_row, sender_col); the receiver polls its row. In-order delivery
per (src, dst) pair; tag matching uses a local reorder buffer (messages of
other tags are parked, never dropped).

Two data-plane protocols, selected per message by ``eager_threshold``:

  EAGER       payload <= threshold. Chunks flow through the pair's SPSC
              queue cells as memoryview slices (gather-enqueue; no
              intermediate ``bytes`` is ever materialized). Copies per
              message: user -> cell (1) + cell -> user (1).

  RENDEZVOUS  payload > threshold, or any ``PoolBuffer``/``PoolView``
              send. The sender stages the payload ONCE into a
              pool-resident object ([ack 64B | payload]) and enqueues a
              single 32-byte control descriptor
              (total, tag, ack offset, data offset). The receiver
              ``read_acquire_into``s its destination buffer straight from
              the staging object and writes the ack byte; the sender's
              progress engine then reclaims the stager. A ``PoolBuffer``
              (pool-resident application buffer, MPI_Alloc_mem analogue)
              — or a ``PoolView`` slice of one — skips the staging copy
              entirely: zero sender-side copies, the one-sided bulk path
              the paper's CXL fabric enables (cf. foMPI routing large
              transfers through RMA windows). ``Comm``'s method
              collectives (core/comm.py) send ``PoolView`` slices of
              persistent round buffers so ring/Bruck rounds never
              re-stage.

  POSTED      rendezvous, receiver-first (foMPI's lesson: expose the
              DESTINATION, not the source). ``recv_into``/``irecv_into``
              on a pool-resident (``PoolBuffer``/``PoolView``) or
              pool-registered (``Registration``) destination publish a
              MATCHBOX entry ``[post_id | tag | dest_off | capacity]``
              for their (src, dst) pair before the sender's descriptor
              exists. A sender that finds a matching entry writes the
              payload STRAIGHT into the receiver's buffer — one copy
              total, zero receiver-side drain — signals readiness
              through the entry's claim word (the drain-ack byte role,
              reversed), and ships a ``FLAG_POSTED`` descriptor naming
              the entry so per-pair FIFO matching still happens in
              queue order. A posting that finds its strip full SPILLS
              to a per-pair overflow list and is promoted (FIFO) as
              entries retire, so deep pre-post bursts (chunked
              schedules) never lose their postings. A sender-side miss
              or an unregistered destination fall back to the staged
              path above: wire-compatible in both directions (old
              senders never see entries; old receivers never post
              them).

Non-blocking isend/irecv return Request objects driven by an explicit
progress pump (MPI_Test/MPI_Wait semantics — paper §3.4 keeps these
unchanged, as do we: the message path itself is what got optimized).
Every blocking call AND every ``test()``/``wait()`` — receives included —
turns the send progress engine, so ``isend`` + ``irecv().wait()`` loops
cannot deadlock on full queues. ``recv_into``/``irecv_into`` deliver
straight into caller buffers (numpy arrays included) with no
``frombuffer().copy()`` round trip.

This module is the pt2pt ENGINE. The user-facing v2 surface — method
collectives, ``split``/``dup`` sub-communicators, persistent requests,
``eager_threshold="auto"`` — is the ``Comm`` facade in
``repro.core.comm``, which subclasses ``Communicator``.

Bootstrap: rank 0 creates the queue-matrix and barrier objects in the
arena; other ranks poll ``open`` until they appear — this mirrors the
paper's 'root rank creates, broadcasts the object name' flow (here the
names are deterministic, which IS the broadcast).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core.arena import Arena, ObjHandle
from repro.core.coherence import CoherentView
from repro.core.pool import Registration, as_u8
from repro.core.progress import ProgressEngine
from repro.core.progress import testall as _testall
from repro.core.progress import waitall as _waitall
from repro.core.progress import waitany as _waitany
from repro.core.ringqueue import (DEFAULT_CELL_SIZE, FLAG_FIRST, FLAG_LAST,
                                  FLAG_POSTED, FLAG_RNDV,
                                  TAG_RESERVED_BASE, QueueMatrix)
from repro.core.rma import DynamicWindow, Window
from repro.core.sync import SeqBarrier
from repro.core.trace import (EV_MB_CLAIM, EV_MB_CONSUME, EV_MB_POST,
                              EV_MB_PROMOTE, EV_MB_RETRACT, EV_MB_SPILL,
                              EV_PT2PT_EAGER, EV_PT2PT_POSTED,
                              EV_PT2PT_STAGED, as_tracer)

ANY_TAG = -1

# tags at or above TAG_RESERVED_BASE are RESERVED for internal traffic
# (collective schedule rounds live at 0x7E??????, the legacy collective
# tag space at 0x7F000000+). ANY_TAG receives — and ANY_TAG matchbox
# wildcards — never match reserved tags, so in-flight user wildcard
# receives cannot steal a collective round (MPI's separate communication
# contexts, enforced through tag-space partitioning). The constant is
# defined in the wire layer (``ringqueue``) and re-exported here.
# per-launch tag window for collective schedules (see Communicator.
# _alloc_coll_tags): sequence-numbered windows of MAX_ROUNDS tags
_TAG_SCHED_BASE = 0x7E000000
_TAG_SCHED_SEQS = 2048
# persistent collectives lease windows from a separate, longer-lived
# sequence space so a long-lived allreduce_init never collides with the
# wrapping transient windows
_TAG_PERSIST_BASE = 0x7E800000


def _tag_match(want: int, got: int) -> bool:
    """Receive-side tag matching: exact, or ANY_TAG against any USER
    tag (reserved internal tags are never wildcard-matched)."""
    if want == ANY_TAG:
        return got < TAG_RESERVED_BASE
    return want == got

# rendezvous staging object layout: [ctrl 64B | payload]; ctrl byte 0 is
# the receiver-written ack ("drained, reclaim/reuse me")
_RNDV_CTRL = 64

# --------------------------------------------------------------------------
# matchbox: receiver-posted rendezvous entries (one strip per ordered pair)
# --------------------------------------------------------------------------
# Entry layout (one cacheline, every field accessed non-temporally so no
# rank ever caches another rank's control words):
#
#   0:8    post_id   receiver-written; 0 = empty, else a per-pair
#                    monotonically increasing id (published LAST)
#   8:16   tag       receiver-written; 2^64-1 = ANY_TAG wildcard
#   16:24  dest_off  receiver-written; absolute pool offset of the
#                    destination payload region
#   24:32  capacity  receiver-written
#   32:40  claim     sender-written; (post_id << 2) | state — the
#                    drain-ack byte of the staged path, role-reversed:
#                    the SENDER acks delivery into the receiver's buffer
#   40:48  fill      sender-written; delivered payload bytes
#
# Single-writer discipline (CXL pooled memory has no cross-host atomic
# RMW, paper §3.5): the receiver only writes the first four words, the
# sender only the last two. The claim/retract race is resolved
# Dekker-style: the sender publishes a PENDING claim, re-reads post_id,
# and only then commits (after the payload write) or aborts; a receiver
# retracting a posting waits out a PENDING claim and salvages a
# committed one (see Communicator._mb_retract).
_MB_ENTRY = 64
_MB_TAG = 8
_MB_DEST = 16
_MB_CAP = 24
_MB_CLAIM = 32
_MB_FILL = 40
_MB_ANY = (1 << 64) - 1
_CLAIM_PENDING, _CLAIM_COMMIT, _CLAIM_ABORT = 1, 2, 3
DEFAULT_MB_SLOTS = 4


class Matchbox:
    """The per-pair strips of receiver-posted entries, addressed like the
    queue matrix: the strip for (receiver, sender) holds ``n_slots``
    entries the receiver posts and the sender scans."""

    def __init__(self, view: CoherentView, base: int, n_ranks: int,
                 n_slots: int, *, initialize: bool = False):
        self.view = view
        self.base = base
        self.n = n_ranks
        self.n_slots = n_slots
        if initialize:
            # derived comms recycle dirty heap: zero every entry before
            # the communicator's :ok publication makes them findable
            view.write_release(
                base, bytes(self.region_bytes(n_ranks, n_slots)))

    @staticmethod
    def region_bytes(n_ranks: int, n_slots: int) -> int:
        return n_ranks * n_ranks * n_slots * _MB_ENTRY

    def entry_off(self, recv: int, send: int, slot: int) -> int:
        return self.base + ((recv * self.n + send) * self.n_slots
                            + slot) * _MB_ENTRY

    # mb-writer: receiver
    def post(self, recv: int, send: int, slot: int, post_id: int,
             tag: int, dest_off: int, capacity: int) -> None:
        v = self.view
        off = self.entry_off(recv, send, slot)
        v.nt_store_u64(off + _MB_TAG,
                       _MB_ANY if tag == ANY_TAG else int(tag) & _MB_ANY)
        v.nt_store_u64(off + _MB_DEST, dest_off)
        v.nt_store_u64(off + _MB_CAP, capacity)
        v.nt_store_u64(off, post_id)          # publish last


@dataclass
class _PostRecord:
    """Receiver-side bookkeeping for one live matchbox posting."""
    src: int
    slot: int
    post_id: int
    tag: int                                 # the receive's criterion
    dest: "_RecvDest"
    owner: Any                               # the posting Request


@dataclass
class _PendingPost:
    """A postable receive's matchbox intent, live from irecv to
    completion. ``rec`` is None while the posting waits in the per-pair
    OVERFLOW list (every strip slot occupied); consuming or retracting
    an entry promotes the oldest overflow posting into the freed slot,
    so postings reach the matchbox in FIFO order no matter how deep a
    chunked pre-post burst runs — no lazy retry, no capacity miss."""
    src: int
    tag: int
    dest: "_RecvDest"
    owner: Any                               # the posting Request
    rec: Optional[_PostRecord] = None
    closed: bool = False


class _RecvDest:
    """Resolved destination of a ``*_into`` receive: a writable sink for
    the eager/staged delivery paths plus, when the destination is
    pool-addressable, the coordinates a matchbox posting advertises.

      plain buffer          sink = the user view; not postable
      PoolBuffer/PoolView   sink aliases pool memory (or a bounce temp on
                            pools without raw views); postable
      Registration          sink = the user view (eager/staged bypass the
                            shadow); postable at the shadow's offset,
                            with a shadow -> user drain on posted
                            completion
    """

    __slots__ = ("mv", "capacity", "post_off", "postable", "indirect",
                 "reg")

    def __init__(self, mv: memoryview, *, post_off: int = -1,
                 postable: bool = False, indirect: bool = False,
                 reg: Registration | None = None):
        self.mv = mv
        self.capacity = len(mv)
        self.post_off = post_off
        self.postable = postable
        self.indirect = indirect
        self.reg = reg

    def flush(self, view: CoherentView, n: int) -> None:
        """Indirect pool destination: move the bounce temp into the pool
        through the coherence protocol (counted)."""
        if self.indirect and n:
            view.write_release(self.post_off, self.mv[:n])

    def finish_posted(self, view: CoherentView, n: int) -> None:
        """Posted completion landed at ``post_off``; for a registration
        that is the shadow — drain it into the user view once."""
        if self.reg is not None and n:
            view.read_acquire_into(self.post_off, self.mv[:n])
            view.count_path("rndv_posted", n)


class PoolBuffer:
    """Message buffer RESIDENT in the shared pool (the MPI_Alloc_mem /
    CXL-resident application buffer of the paper).

    Sending one takes the rendezvous path with ZERO sender-side payload
    copies: the control descriptor points at this object and the receiver
    pulls straight from it. The send completes (synchronous-mode send)
    once the receiver acks the drain, after which the buffer is reusable.

    Arena object layout: [ctrl 64B | payload nbytes].
    """

    def __init__(self, comm: "Communicator", handle: ObjHandle):
        self._comm = comm
        self._handle = handle
        self.nbytes = handle.size - _RNDV_CTRL
        # one ack byte => at most ONE outstanding send per buffer
        self._in_flight = False

    @property
    def offset(self) -> int:
        """Absolute payload offset in the pool."""
        return self._handle.offset + _RNDV_CTRL

    def view(self) -> memoryview:
        """Writable zero-copy window into pool memory (memory-backed,
        hardware-coherent pools only — on incoherent pools use write)."""
        return self._comm.arena.pool.memview(self.offset, self.nbytes)

    def write(self, data, off: int = 0) -> None:
        """Protocol-correct fill (valid on every pool mode)."""
        mv = as_u8(data)
        if off < 0 or off + len(mv) > self.nbytes:
            raise IndexError("write beyond PoolBuffer")
        self._comm.arena.view.write_release(self.offset + off, mv)

    def read(self, off: int = 0, n: int | None = None) -> bytes:
        n = self.nbytes - off if n is None else n
        return self._comm.arena.view.read_acquire(self.offset + off, n)

    def free(self) -> None:
        self._comm.arena.destroy(self._handle)

    def slice(self, off: int = 0, nbytes: int | None = None) -> "PoolView":
        """A sendable window [off, off+nbytes) of this buffer. Slices
        share the buffer's single ack slot, so at most one send per
        underlying buffer may be in flight at a time."""
        nbytes = self.nbytes - off if nbytes is None else nbytes
        if off < 0 or nbytes < 0 or off + nbytes > self.nbytes:
            raise IndexError(
                f"slice [{off}, {off + nbytes}) beyond PoolBuffer "
                f"of {self.nbytes}B")
        return PoolView(self, off, nbytes)


@dataclass(frozen=True)
class PoolView:
    """A contiguous slice of a PoolBuffer, sendable with zero sender-side
    copies: the rendezvous descriptor points the receiver straight at
    pool memory. Produced by ``PoolBuffer.slice``; the ``Comm`` method
    collectives send these for every ring/Bruck round."""
    buffer: PoolBuffer
    off: int
    nbytes: int


@dataclass
class Request:
    kind: str                        # send | recv
    done: bool = False
    cancelled: bool = False          # done via cancel(): no data arrived
    data: Optional[bytes] = None     # recv result (bytes-mode receives)
    nbytes: int = 0                  # payload size delivered/accepted
    tag: int = 0
    src: int = -1
    _gen: Any = field(default=None, repr=False)
    _comm: Any = field(default=None, repr=False)
    _error: Optional[BaseException] = field(default=None, repr=False)
    # True while the receive generator is suspended MID-MESSAGE (eager
    # multi-chunk drain): closing it there would strand the message's
    # tail chunks in the pair queue and corrupt framing
    _draining: bool = field(default=False, repr=False)
    # completion callback feeding the shared progress engine: schedule
    # executions hang a node-retirement hook here so a finishing pt2pt
    # request immediately readies its dependents (core/progress.py)
    _on_done: Any = field(default=None, repr=False)

    def _finish(self) -> None:
        """Mark complete exactly once and fire the completion callback."""
        if self.done:
            return
        self.done = True
        cb = self._on_done
        if cb is not None:
            self._on_done = None
            cb(self)

    def cancel(self) -> None:
        """Withdraw a pending receive (MPI_Cancel, receives only):
        closes the generator — which retracts any live matchbox posting
        — and unlinks it from the posted-receive FIFO. A no-op on
        completed requests. On success the request reports done with
        ``cancelled=True`` (the MPI_Test_cancelled observable): no data
        arrived, and any completion callback is dropped, never fired.
        BEST-EFFORT, per MPI: a receive already draining a multi-chunk
        eager message cannot be cancelled (closing it mid-message would
        strand tail chunks in the pair queue and corrupt framing) — it
        is left to complete normally, ``cancelled`` stays False."""
        if self.done or self.kind != "recv" or self._draining:
            return
        if self._gen is not None:
            self._gen.close()
        self.cancelled = True
        self._on_done = None
        self.done = True
        fifo = self._comm._recv_fifo.get(self.src) \
            if self._comm is not None else None
        if fifo is not None:
            try:
                fifo.remove(self)
            except ValueError:
                pass

    def test(self) -> bool:
        if self._error is not None:
            raise self._error
        if self.done:
            return True
        if self.kind == "send":
            # sends are pumped ONLY through the per-destination FIFO —
            # chunks of different messages must never interleave in one
            # SPSC queue (framing is contiguous per message)
            self._comm._progress()
            return self.done
        # a receive must ALSO turn the full progress engine: a bare
        # isend-to-peer + irecv().wait() loop would otherwise deadlock
        # once the pair queue fills (each rank blocked in a recv that
        # never advances its own outstanding send), and a synchronous
        # send waited before a posted receive needs that receive matched
        # passively (MPI posted-receive semantics)
        if self._comm is not None:
            self._comm._progress()
            if self.done:                # completed by the engine
                return True
            if self._error is not None:
                raise self._error
        try:
            next(self._gen)
        except StopIteration:
            self._finish()
            self._unpost()
        except BaseException:
            self._unpost()               # keep the FIFO draining
            raise
        return self.done

    def _unpost(self) -> None:
        if self._comm is None or self.kind != "recv":
            return
        fifo = self._comm._recv_fifo.get(self.src)
        if fifo and fifo[0] is self:
            fifo.popleft()

    def wait(self, timeout: float | None = 30.0):
        t0 = time.monotonic()
        while not self.test():
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"{self.kind} request timed out")
            time.sleep(0)
        return self.data


class Communicator:
    """MPI_COMM_WORLD-alike over one arena."""

    def __init__(self, arena: Arena, rank: int, size: int, *,
                 cell_size: int = DEFAULT_CELL_SIZE, n_cells: int = 8,
                 eager_threshold: int | None = None,
                 mb_slots: int = DEFAULT_MB_SLOTS,
                 matchbox_slots: int | None = None,
                 name: str = "world", open_timeout: float = 30.0,
                 trace=None):
        self.arena = arena
        self.rank = rank
        self.size = size
        self.name = name
        self.cell_size = cell_size
        self.n_cells = n_cells
        # flight recorder (core/trace.py): off by default — every hot
        # path checks ``self.tracer.enabled`` and nothing else. Must
        # exist before the engine and the init barrier below run.
        self.tracer = as_tracer(trace, rank)
        # protocol switch: payloads <= threshold go through queue cells
        # (eager), larger ones through a pool staging object (rendezvous)
        self.eager_threshold = (cell_size if eager_threshold is None
                                else eager_threshold)
        self.eager_sends = 0
        self.rndv_sends = 0
        self.posted_sends = 0         # rendezvous sends that hit an entry
        if matchbox_slots is not None:
            # preferred spelling; ``mb_slots`` stays as the historical
            # alias. Pre-posted schedules size this to schedule depth
            # (2x the deepest per-peer receive count for persistent
            # collectives — two iterations' entries coexist).
            mb_slots = matchbox_slots
        self.mb_slots = mb_slots      # posted entries per (src, dst); 0 off
        region = QueueMatrix.region_bytes(size, cell_size, n_cells)
        bar_bytes = SeqBarrier.region_bytes(size)
        mb_bytes = Matchbox.region_bytes(size, mb_slots) if mb_slots else 0
        self._mb_obj: Optional[ObjHandle] = None
        self._ok_obj: Optional[ObjHandle] = None
        if rank == 0:
            self._mq_obj = arena.create(f"{name}:mq", region)
            self._bar_obj = arena.create(f"{name}:bar", bar_bytes)
            self.mq = QueueMatrix(arena.view, self._mq_obj.offset, size, rank,
                                  cell_size, n_cells, initialize=True)
            self._barrier = SeqBarrier(arena.view, self._bar_obj.offset, size,
                                       rank, initialize=True)
            if mb_bytes:
                self._mb_obj = arena.create(f"{name}:mb", mb_bytes)
                self._mb = Matchbox(arena.view, self._mb_obj.offset, size,
                                    mb_slots, initialize=True)
            else:
                self._mb = None
            # publication flag LAST: arena.create makes a name findable
            # before its contents are initialized, and derived comms
            # (split/dup) recycle dirty heap — a member must never map
            # control words rank 0 has not zeroed yet. Its 64 bytes
            # double as free()'s per-rank exit-fence flags — zero them
            # (dirty heap) before the init barrier lets anyone proceed.
            self._ok_obj = arena.create(f"{name}:ok", max(64, size))
            arena.view.write_release(self._ok_obj.offset,
                                     bytes(max(64, size)))
        else:
            t0 = time.monotonic()
            while True:
                try:
                    self._ok_obj = arena.open(f"{name}:ok")
                    self._mq_obj = arena.open(f"{name}:mq")
                    self._bar_obj = arena.open(f"{name}:bar")
                    if mb_bytes:
                        self._mb_obj = arena.open(f"{name}:mb")
                    break
                except FileNotFoundError:
                    if time.monotonic() - t0 > open_timeout:
                        raise
                    time.sleep(0.0005)
            self.mq = QueueMatrix(arena.view, self._mq_obj.offset, size, rank,
                                  cell_size, n_cells)
            self._barrier = SeqBarrier(arena.view, self._bar_obj.offset, size,
                                       rank)
            self._mb = (Matchbox(arena.view, self._mb_obj.offset, size,
                                 mb_slots) if mb_bytes else None)
        # tag reorder buffers per src: (payload, tag, rndv) — rndv
        # records whether the payload arrived via a rendezvous path
        # (the capacity-miss accounting needs the DELIVERY path, not a
        # size heuristic: pool-resident sends are rendezvous at any
        # size)
        self._parked: dict[int, deque[tuple[bytes, int, bool]]] = {
            s: deque() for s in range(size)}
        # matchbox state. Receiver side: live postings by (src, slot),
        # per-src post_id counters, and payloads salvaged out of postings
        # that were retracted after the sender had already committed.
        # Sender side: the last post_id claimed per (dst, slot), so a
        # consumed-but-not-yet-recycled entry is never claimed twice.
        self._mb_records: dict[tuple[int, int], _PostRecord] = {}
        # per-source FIFO of postings that found every strip slot
        # occupied; promoted (oldest first) whenever a slot frees
        self._mb_overflow: dict[int, deque[_PendingPost]] = {}
        self._mb_next_id: dict[int, int] = {}
        self._mb_salvage: dict[tuple[int, int, int], bytes] = {}
        self._mb_claimed: dict[tuple[int, int], int] = {}
        # claim cursor per destination strip: the slot AFTER the last
        # successful claim (the receiver promotes spilled postings into
        # the slot the previous claim freed, so the next-oldest entry
        # usually lands there) plus the retire frontier — the highest
        # post_id F with every pid <= F known dead or claimed by us.
        # pid == F+1 at the cursor slot proves oldest-live without a
        # scan; see _mb_claim.
        self._mb_cursor: dict[int, int] = {}
        self._mb_frontier: dict[int, int] = {}
        self._aliasable: Optional[bool] = None
        self._reg_seq = 0
        self._freed = False
        # the SHARED PROGRESS CORE (core/progress.py): owns the send/
        # recv FIFOs, the stager reclaim list AND every active
        # collective schedule execution; every blocking call and every
        # test()/wait() turns it (MPI progress rule — without it, two
        # ranks that isend to each other then recv would deadlock on
        # full queues, and an iallreduce would never advance)
        self._engine = ProgressEngine(self)
        # collective-schedule state: compiled-DAG cache (one entry per
        # (op, size, topology)) and the launch sequence counters that
        # hand each collective a disjoint tag window
        self._sched_cache: dict = {}
        self._coll_seq = 0
        self._persist_seq = 0
        self._rndv_seq = 0
        self._pbuf_seq = 0
        # init barrier (paper §3.4: creation of shared queues synchronized
        # by the seq-number barrier)
        self.barrier()

    # engine-owned state, re-exposed under the historical names
    @property
    def _send_fifo(self) -> dict[int, deque]:
        return self._engine.send_fifo

    @property
    def _recv_fifo(self) -> dict[int, deque]:
        return self._engine.recv_fifo

    @property
    def _stagers(self) -> list:
        return self._engine.stagers

    def _progress(self) -> None:
        """One tick of the shared progress engine."""
        self._engine.tick()

    def progress(self) -> None:
        """Explicit progress tick: advances outstanding sends, posted
        receives, stager reclaim and every active collective schedule.
        Call this from compute loops between ``iallreduce`` start and
        ``wait`` to keep payloads moving — the engine is cooperative,
        there is no progress thread."""
        self._engine.tick()

    def _alloc_coll_tags(self, persistent: bool = False) -> int:
        """A per-launch window of ``sched.MAX_ROUNDS`` reserved tags.
        The sequence counters advance identically on every rank
        (collectives are issued in the same order everywhere — the MPI
        calling convention), so windows agree without communication.
        Persistent collectives draw from a separate sequence: their
        windows live as long as the request does and must not collide
        with the wrapping transient ones."""
        from repro.core.sched import MAX_ROUNDS
        if persistent:
            seq = self._persist_seq
            self._persist_seq += 1
            return _TAG_PERSIST_BASE + (seq % _TAG_SCHED_SEQS) * MAX_ROUNDS
        seq = self._coll_seq
        self._coll_seq += 1
        return _TAG_SCHED_BASE + (seq % _TAG_SCHED_SEQS) * MAX_ROUNDS

    # ------------------------------------------------------------------
    # pool-resident buffers (zero-copy sends)
    # ------------------------------------------------------------------
    def alloc_buffer(self, nbytes: int) -> PoolBuffer:
        """Allocate a pool-resident message buffer (MPI_Alloc_mem)."""
        h = self.arena.create(f"pb:{self.name}:{self.rank}:{self._pbuf_seq}",
                              _RNDV_CTRL + nbytes)
        self._pbuf_seq += 1
        return PoolBuffer(self, h)

    def register(self, buf) -> Registration:
        """Pin a writable user buffer for receiver-posted rendezvous:
        allocates its pool-resident shadow once; receives posted on the
        registration advertise the shadow in the matchbox and drain it
        into the user buffer on completion. Release with ``.free()``."""
        mv = as_u8(buf)
        if mv.readonly:
            raise ValueError("register needs a writable buffer")
        h = self.arena.create(f"rg:{self.name}:{self.rank}:{self._reg_seq}",
                              max(len(mv), 1))
        self._reg_seq += 1
        return Registration(mv, h.offset, h, self)

    def unregister(self, reg: Registration) -> None:
        if reg.closed:
            return
        reg.closed = True
        self.arena.destroy(reg._handle)

    def _pool_aliasable(self) -> bool:
        """True when the pool hands out raw memoryview windows (memory-
        backed, hardware-coherent) — pool-resident payloads can then be
        moved with a single protocol copy."""
        if self._aliasable is None:
            try:
                self.arena.pool.memview(0, 1)
                self._aliasable = True
            except TypeError:
                self._aliasable = False
        return self._aliasable

    def _resolve_dest(self, buf) -> _RecvDest:
        """Classify a ``*_into`` destination (see _RecvDest)."""
        if isinstance(buf, Registration):
            if buf.closed:
                raise ValueError("registration already freed")
            return _RecvDest(buf.mv, post_off=buf.shadow_off,
                             postable=self._mb is not None, reg=buf)
        if isinstance(buf, PoolBuffer):
            buf = PoolView(buf, 0, buf.nbytes)
        if isinstance(buf, PoolView):
            off = buf.buffer.offset + buf.off
            if self._pool_aliasable():
                mv = self.arena.pool.memview(off, buf.nbytes)
                indirect = False
            else:
                mv = memoryview(bytearray(buf.nbytes))
                indirect = True
            return _RecvDest(mv, post_off=off,
                             postable=self._mb is not None,
                             indirect=indirect)
        mv = as_u8(buf)
        if mv.readonly:
            raise ValueError("irecv_into needs a writable buffer")
        return _RecvDest(mv)

    # ------------------------------------------------------------------
    # matchbox: receiver side
    # ------------------------------------------------------------------
    def _next_pid(self, src: int) -> int:
        """Per-pair monotonically increasing post_id (the matchbox's
        freshness token: claim re-checks, salvage keys and oldest-entry
        selection all key off it)."""
        pid = self._mb_next_id.get(src, 1)
        self._mb_next_id[src] = pid + 1
        return pid

    def _mb_post(self, src: int, tag: int, dest: _RecvDest,
                 req: "Request") -> Optional[_PostRecord]:
        """Publish a posted-rendezvous entry for ``req``; None when every
        slot of the pair is occupied."""
        for slot in range(self._mb.n_slots):
            if (src, slot) in self._mb_records:
                continue
            pid = self._next_pid(src)
            self._mb.post(self.rank, src, slot, pid, tag,
                          dest.post_off, dest.capacity)
            rec = _PostRecord(src, slot, pid, tag, dest, req)
            self._mb_records[(src, slot)] = rec
            tr = self.tracer
            if tr.enabled:
                tr.emit(EV_MB_POST, pid, src, dest.capacity)
            return rec
        return None

    def _mb_post_or_spill(self, src: int, tag: int, dest: _RecvDest,
                          req: "Request") -> _PendingPost:
        """Publish an entry, or SPILL the posting to the pair's overflow
        list when the strip is full (promoted FIFO as slots free). A
        posting behind a non-empty overflow spills too — it must not
        overtake earlier receives in the matchbox."""
        pend = _PendingPost(src, tag, dest, req)
        ovf = self._mb_overflow.get(src)
        if not ovf:
            pend.rec = self._mb_post(src, tag, dest, req)
            if pend.rec is not None:
                return pend
        self._mb_overflow.setdefault(src, deque()).append(pend)
        tr = self.tracer
        if tr.enabled:
            tr.emit(EV_MB_SPILL, 0, src)
        return pend

    def _mb_promote(self, src: int) -> None:
        """A (src -> us) slot freed: move the oldest spilled posting of
        that pair into the matchbox."""
        ovf = self._mb_overflow.get(src)
        while ovf:
            pend = ovf[0]
            if pend.closed:
                ovf.popleft()
                continue
            rec = self._mb_post(src, pend.tag, pend.dest, pend.owner)
            if rec is None:
                return
            pend.rec = rec
            ovf.popleft()
            tr = self.tracer
            if tr.enabled:
                tr.emit(EV_MB_PROMOTE, rec.post_id, src)

    def _mb_withdraw(self, pend: Optional[_PendingPost], *,
                     fallback_delivery: bool = False) -> None:
        """The receive behind ``pend`` is completing some way other than
        its own posted entry: retract a live posting (salvaging any
        committed claim) or unlink a still-spilled one. A fallback
        DELIVERY that finds the posting still spilled is the one true
        capacity miss left — the strip was too shallow for the posting
        to reach the matchbox in time — and is what
        ``ProtocolStats.mb_capacity_misses`` now counts."""
        if pend is None or pend.closed:
            return
        pend.closed = True
        if pend.rec is not None:
            self._mb_retract(pend.rec)
            pend.rec = None
            return
        ovf = self._mb_overflow.get(pend.src)
        if ovf:
            try:
                ovf.remove(pend)
            except ValueError:
                pass
        if fallback_delivery:
            self.arena.view.count_mb_miss()

    # mb-writer: receiver
    def _mb_retract(self, rec: _PostRecord) -> None:
        """Withdraw a posting whose receive is completing another way
        (eager, staged, parked, error). If the sender committed a claim
        concurrently, the payload it delivered belongs to a LATER message
        whose FLAG_POSTED descriptor is already in flight — salvage it
        out of the buffer before the owner reuses it."""
        key = (rec.src, rec.slot)
        if self._mb_records.get(key) is not rec:
            return                            # consumed or already gone
        del self._mb_records[key]
        try:
            v = self.arena.view
            off = self._mb.entry_off(self.rank, rec.src, rec.slot)
            v.nt_store_u64(off, 0)
            # yield (a syscall) between our store and the claim load: a
            # sender that read the stale post_id issued its PENDING store
            # BEFORE that read, so after the yield any such claim is
            # visible — closing the StoreLoad window a bare store+load
            # would leave (on the paper's hardware the nt store is
            # followed by sfence)
            time.sleep(0)
            w = v.nt_load_u64(off + _MB_CLAIM)
            if (w >> 2) != rec.post_id:
                return
            t0 = time.monotonic()
            while (w & 3) == _CLAIM_PENDING:  # sender mid-claim: wait out
                if time.monotonic() - t0 > 10.0:
                    raise RuntimeError(
                        "matchbox retract: peer claim stuck PENDING")
                time.sleep(0)
                w = v.nt_load_u64(off + _MB_CLAIM)
            if (w & 3) == _CLAIM_COMMIT:
                n = v.nt_load_u64(off + _MB_FILL)
                data = bytes(v.read_acquire(rec.dest.post_off, n)) \
                    if n else b""
                v.count_path("rndv_posted", n)
                self._mb_salvage[(rec.src, rec.slot, rec.post_id)] = data
        finally:
            tr = self.tracer
            if tr.enabled:
                tr.emit(EV_MB_RETRACT, rec.post_id, rec.src)
            self._mb_promote(rec.src)         # the slot is free again

    # mb-writer: receiver
    def _mb_consume(self, rec: _PostRecord) -> None:
        """A posted delivery completed in place: recycle the entry and
        promote the pair's oldest spilled posting into the slot."""
        off = self._mb.entry_off(self.rank, rec.src, rec.slot)
        self.arena.view.nt_store_u64(off, 0)
        self._mb_records.pop((rec.src, rec.slot), None)
        tr = self.tracer
        if tr.enabled:
            tr.emit(EV_MB_CONSUME, rec.post_id, rec.src)
        self._mb_promote(rec.src)

    def _mb_repost(self, rec: _PostRecord) -> None:
        """The sender delivered a message that MPI order routes to a
        DIFFERENT receive: after salvaging the payload, re-arm the entry
        for its still-pending owner (whose buffer is undefined until
        completion, so the scribble was legal)."""
        pid = self._next_pid(rec.src)
        rec.post_id = pid
        self._mb.post(self.rank, rec.src, rec.slot, pid,
                      rec.tag, rec.dest.post_off, rec.dest.capacity)

    def _mb_take(self, src: int, slot: int, pid: int, total: int,
                 req: "Request") -> Optional[bytes]:
        """Resolve a FLAG_POSTED descriptor. Returns None when the
        payload was consumed IN PLACE by ``req`` (its own posting —
        zero receiver-side copies), else the payload bytes salvaged from
        a retracted or foreign posting."""
        sal = self._mb_salvage.pop((src, slot, pid), None)
        if sal is not None:
            return sal[:total]
        rec = self._mb_records.get((src, slot))
        if rec is None or rec.post_id != pid:
            raise RuntimeError(
                f"cMPI matchbox error: FLAG_POSTED descriptor for unknown "
                f"posting (src={src}, slot={slot}, post_id={pid})")
        v = self.arena.view
        if rec.owner is req:
            rec.dest.finish_posted(v, total)
            self._mb_consume(rec)
            return None
        data = bytes(v.read_acquire(rec.dest.post_off, total)) \
            if total else b""
        v.count_path("rndv_posted", total)
        self._mb_repost(rec)
        return data

    # ------------------------------------------------------------------
    # matchbox: sender side
    # ------------------------------------------------------------------
    def _mb_match(self, v, off: int, tag: int, wtag: int,
                  nbytes: int) -> bool:
        """Tag + capacity filter for one live strip entry."""
        etag = v.nt_load_u64(off + _MB_TAG)
        if etag == _MB_ANY:
            # a wildcard posting belongs to a USER receive — it must
            # never swallow reserved-tag traffic (collective rounds)
            if int(tag) >= TAG_RESERVED_BASE:
                return False
        elif etag != wtag:
            return False
        return v.nt_load_u64(off + _MB_CAP) >= nbytes

    # mb-writer: sender
    def _mb_commit_claim(self, dest: int, slot: int, pid: int,
                         off: int) -> Optional[tuple[int, int, int, int]]:
        """PENDING -> re-check -> owned on one chosen entry; advances
        the claim cursor on success. Returns the claim tuple or None
        when the receiver retracted the entry mid-claim."""
        v = self.arena.view
        self._mb_claimed[(dest, slot)] = pid
        v.nt_store_u64(off + _MB_CLAIM, (pid << 2) | _CLAIM_PENDING)
        if v.nt_load_u64(off) != pid:         # receiver retracted mid-claim
            v.nt_store_u64(off + _MB_CLAIM, (pid << 2) | _CLAIM_ABORT)
            return None
        self._mb_cursor[dest] = (slot + 1) % self._mb.n_slots
        tr = self.tracer
        if tr.enabled:
            tr.emit(EV_MB_CLAIM, pid, dest)
        return slot, pid, v.nt_load_u64(off + _MB_DEST), off

    def _mb_claim(self, dest: int, tag: int, nbytes: int,
                  pool_src: bool) -> Optional[tuple[int, int, int, int]]:
        """Claim the OLDEST matching posted entry of the (dest, self)
        strip (PENDING -> re-check -> owned). Returns
        (slot, post_id, dest_off, entry_off) or None on miss.

        Fast path first: a chunked send stream claims a strip's entries
        in strictly increasing post_id order, and the receiver promotes
        spilled postings into the slot the previous claim freed — so
        the next-oldest entry is usually at the cursor slot. Per-strip
        post_ids are monotone and never reused, so an entry there with
        ``pid == frontier + 1`` is PROVABLY the oldest live entry; if
        it also matches, claiming it without scanning preserves the
        oldest-match FIFO rule. Anything else falls back to the full
        scan. Every slot probed is counted in
        ``ProtocolStats.mb_slots_scanned``."""
        mb = self._mb
        if mb is None or (pool_src and not self._pool_aliasable()):
            # a pool-resident source on a pool without raw views would
            # need a bounce read+write (2 copies) — staged is cheaper
            return None
        v = self.arena.view
        st = v.stats
        wtag = int(tag) & _MB_ANY
        cur = self._mb_cursor.get(dest)
        fr = self._mb_frontier.get(dest, 0)
        if cur is not None:
            off = mb.entry_off(dest, self.rank, cur)
            st.mb_slots_scanned += 1
            pid = v.nt_load_u64(off)
            if (pid == fr + 1
                    and self._mb_claimed.get((dest, cur)) != pid
                    and self._mb_match(v, off, tag, wtag, nbytes)):
                got = self._mb_commit_claim(dest, cur, pid, off)
                if got is not None:
                    self._mb_frontier[dest] = pid
                    return got
        # ---- full scan: oldest matching post_id wins ----
        best = None
        lo = None                     # lowest LIVE unclaimed pid seen
        for slot in range(mb.n_slots):
            off = mb.entry_off(dest, self.rank, slot)
            st.mb_slots_scanned += 1
            pid = v.nt_load_u64(off)
            if not pid or self._mb_claimed.get((dest, slot)) == pid:
                continue
            if lo is None or pid < lo:
                lo = pid
            if not self._mb_match(v, off, tag, wtag, nbytes):
                continue
            if best is None or pid < best[1]:
                best = (slot, pid, off)
        if lo is not None:
            # every pid below the lowest live unclaimed one is retired —
            # re-arms the fast path across gaps left by receiver
            # retractions or tag-mismatched claims
            self._mb_frontier[dest] = max(fr, lo - 1)
        if best is None:
            return None
        slot, pid, off = best
        got = self._mb_commit_claim(dest, slot, pid, off)
        if got is not None and pid == self._mb_frontier.get(dest, 0) + 1:
            self._mb_frontier[dest] = pid
        return got

    # ------------------------------------------------------------------
    # teardown
    # ------------------------------------------------------------------
    def free(self) -> None:
        """Collective communicator teardown: every rank calls it.
        Retracts this rank's live matchbox postings (their destination
        buffers die with the caller), fences so no rank is still mid-
        message, then rank 0 destroys the queue matrix, barrier,
        matchbox and publication objects. Idempotent on every rank."""
        if self._freed:
            return
        self._freed = True
        self._engine.colls.clear()     # abandoned schedule executions
        if self._mb is not None:
            # close spilled postings FIRST: retraction frees slots and
            # would otherwise promote them into a dying matchbox
            for ovf in self._mb_overflow.values():
                for pend in ovf:
                    pend.closed = True
                ovf.clear()
            for rec in list(self._mb_records.values()):
                self._mb_retract(rec)
            self._mb_salvage.clear()
        self.barrier()
        # every rank is out of the data plane: reclaim rendezvous
        # stagers (acked ones were awaiting a _progress sweep that will
        # never come; unacked ones carry messages that die with the
        # communicator)
        for h in self._stagers:
            try:
                self.arena.destroy(h)
            except FileNotFoundError:
                pass
        self._stagers.clear()
        # exit fence: SeqBarrier.wait lets fast ranks return while a
        # laggard is still SCANNING the seq words, so destroying the
        # barrier region right after the barrier could hang it once the
        # heap recycles. Each rank raises its single-writer done byte in
        # the :ok object only AFTER leaving the barrier; rank 0 destroys
        # nothing until every byte is up.
        v = self.arena.view
        v.nt_store_u8(self._ok_obj.offset + self.rank, 1)
        if self.rank == 0:
            t0 = time.monotonic()
            while any(not v.nt_load_u8(self._ok_obj.offset + r)
                      for r in range(self.size)):
                if time.monotonic() - t0 > 30.0:
                    raise TimeoutError(
                        "free(): peers never left the teardown fence")
                time.sleep(0)
            for h in (self._mq_obj, self._bar_obj, self._mb_obj,
                      self._ok_obj):
                if h is None:               # matchbox may be disabled
                    continue
                try:
                    self.arena.destroy(h)
                except FileNotFoundError:
                    pass

    # ------------------------------------------------------------------
    # blocking pt2pt (implemented over the non-blocking path so every
    # blocking call keeps the progress engine turning)
    # ------------------------------------------------------------------
    def send(self, dest: int, data, tag: int = 0,
             timeout: float | None = 30.0, *,
             _internal: bool = False) -> None:
        """``data``: any buffer-protocol object or a PoolBuffer."""
        req = self.isend(dest, data, tag, _internal=_internal)
        t0 = time.monotonic()
        while not req.test():           # test() runs the progress sweep
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"send(dest={dest}, tag={tag})")
            time.sleep(0)

    def recv(self, src: int, tag: int = ANY_TAG,
             timeout: float | None = 30.0, *,
             _internal: bool = False) -> tuple[bytes, int]:
        req = self.irecv(src, tag, _internal=_internal)
        t0 = time.monotonic()
        while not req.test():           # test() runs the progress sweep
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"recv(src={src}, tag={tag})")
            time.sleep(0)
        return req.data, req.tag

    def recv_into(self, src: int, buf, tag: int = ANY_TAG,
                  timeout: float | None = 30.0, *,
                  _internal: bool = False) -> tuple[int, int]:
        """Receive straight into ``buf`` (writable buffer-protocol object,
        numpy arrays included); returns (nbytes, tag). If the arriving
        message exceeds ``buf`` it is consumed and DISCARDED, and a
        ValueError raised (MPI truncation semantics) — the communicator
        stays usable."""
        req = self.irecv_into(src, buf, tag, _internal=_internal)
        t0 = time.monotonic()
        while not req.test():           # test() runs the progress sweep
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"recv_into(src={src}, tag={tag})")
            time.sleep(0)
        return req.nbytes, req.tag

    # numpy convenience — ndarray views end to end, no tobytes/frombuffer
    def send_array(self, dest: int, arr: np.ndarray, tag: int = 0) -> None:
        self.send(dest, np.ascontiguousarray(arr), tag)

    def recv_array(self, src: int, shape, dtype,
                   tag: int = ANY_TAG) -> np.ndarray:
        out = np.empty(shape, dtype)
        n, _ = self.recv_into(src, out, tag)
        if n != out.nbytes:
            raise ValueError(
                f"recv_array: expected {out.nbytes}B for shape {shape} "
                f"dtype {np.dtype(dtype)}, got {n}B")
        return out

    # ------------------------------------------------------------------
    # non-blocking pt2pt
    # ------------------------------------------------------------------
    def isend(self, dest: int, data, tag: int = 0, *,
              _prestaged: Optional[PoolBuffer] = None,
              _internal: bool = False,
              _await_claim: float = 0.0) -> Request:
        """``_prestaged``: a persistent staging buffer (owned by a
        ``PersistentRequest``) refilled in place on a matchbox miss —
        the plan stays claim-aware without per-iteration arena churn.
        ``_internal``: schedule/probe traffic may use the reserved tag
        space user code is fenced out of.
        ``_await_claim``: seconds to keep retrying a missed matchbox
        claim before falling back to the staged path. Persistent CYCLIC
        schedules set it: their pre-post handshake guarantees the
        posting exists (possibly still in the receiver's overflow list
        awaiting promotion into a depth-capped strip), so waiting keeps
        the one-copy path deterministic; the deadline preserves
        liveness if the guarantee is ever violated."""
        if int(tag) < 0:
            # ANY_TAG is a receive-side wildcard; a negative wire tag
            # would never match (fail fast on every protocol path alike)
            raise ValueError(f"send tag must be non-negative, got {tag}")
        if int(tag) >= TAG_RESERVED_BASE and not _internal:
            # reserved for collective schedules / probes: ANY_TAG
            # receives skip these tags, so a user send here would park
            # forever against a wildcard receive — reject at the source
            raise ValueError(
                f"tag {tag:#x} is in the reserved internal tag space "
                f"(>= {TAG_RESERVED_BASE:#x})")
        req = Request(kind="send", tag=tag)
        if isinstance(data, PoolBuffer):
            pview: Optional[PoolView] = PoolView(data, 0, data.nbytes)
        elif isinstance(data, PoolView):
            pview = data
        else:
            pview = None
        pbuf = pview.buffer if pview is not None else None
        if pbuf is not None:
            if pbuf._in_flight:
                raise ValueError(
                    "PoolBuffer already has an in-flight send; wait for "
                    "it to complete before sending the buffer again "
                    "(one ack slot per buffer)")
            pbuf._in_flight = True
        mv = None if pview is not None else as_u8(data)
        nbytes = pview.nbytes if pview is not None else len(mv)
        req.nbytes = nbytes

        def gen():  # mb-writer: sender
            if dest == self.rank:
                if pview is not None:
                    payload = bytes(self.arena.view.read_acquire(
                        pbuf.offset + pview.off, nbytes)) if nbytes else b""
                    pbuf._in_flight = False
                else:
                    payload = mv.tobytes()
                self._parked[self.rank].append((payload, tag, False))
                return
            q = self.mq.send_queue(dest)
            v = self.arena.view
            if pview is None and nbytes <= self.eager_threshold:
                # ---- eager: memoryview slices through queue cells ----
                self.eager_sends += 1
                tr = self.tracer
                if tr.enabled:
                    tr.emit(EV_PT2PT_EAGER, dest, nbytes, tag)
                for parts, flags in q.plan_message(mv, tag):
                    while not q.try_enqueue_parts(parts, flags):
                        yield
                v.count_path("eager", nbytes)
                return
            self.rndv_sends += 1
            # ---- posted rendezvous: the receiver advertised its
            # destination — write the payload straight into it (the ONE
            # copy of the whole transfer) and name the entry in the
            # descriptor; per-pair FIFO matching still happens in queue
            # order on the receiver
            claim = self._mb_claim(dest, tag, nbytes, pview is not None)
            if claim is None and _await_claim > 0.0 \
                    and self._mb is not None:
                deadline = time.monotonic() + _await_claim
                while claim is None and time.monotonic() < deadline:
                    yield
                    claim = self._mb_claim(dest, tag, nbytes,
                                           pview is not None)
            if claim is not None:
                slot, pid, dst_off, eoff = claim
                try:
                    if nbytes:
                        src_mv = (self.arena.pool.memview(
                            pbuf.offset + pview.off, nbytes)
                            if pview is not None else mv)
                        v.write_release(dst_off, src_mv)
                        v.count_path("rndv_posted", nbytes)
                except BaseException:
                    v.nt_store_u64(eoff + _MB_CLAIM,
                                   (pid << 2) | _CLAIM_ABORT)
                    raise
                v.nt_store_u64(eoff + _MB_FILL, nbytes)
                # commit AFTER the payload write: the claim word is the
                # staged path's drain-ack byte with the roles reversed
                v.nt_store_u64(eoff + _MB_CLAIM,
                               (pid << 2) | _CLAIM_COMMIT)
                self.posted_sends += 1
                tr = self.tracer
                if tr.enabled:
                    tr.emit(EV_PT2PT_POSTED, dest, nbytes, tag)
                # wire: [total u64 | tag u64 | slot u64 | post_id u64]
                desc = (nbytes.to_bytes(8, "little")
                        + (int(tag) & _MB_ANY).to_bytes(8, "little")
                        + slot.to_bytes(8, "little")
                        + pid.to_bytes(8, "little"))
                while not q.try_enqueue_parts(
                        (desc,),
                        FLAG_FIRST | FLAG_LAST | FLAG_RNDV | FLAG_POSTED):
                    yield
                if pview is not None:
                    # the payload left the source at the write above
                    pbuf._in_flight = False
                return
            # ---- staged rendezvous: stage once, ship a descriptor ----
            tr = self.tracer
            if tr.enabled:
                tr.emit(EV_PT2PT_STAGED, dest, nbytes, tag)
            sync_done = None
            if pview is not None:
                # pool-resident source: no staging copy at all
                ack_off = pbuf._handle.offset
                data_off = pbuf.offset + pview.off
                v.nt_store_u8(ack_off, 0)           # arm the ack

                def sync_done():
                    pbuf._in_flight = False
            elif _prestaged is not None:
                # persistent plan: refill the caller's long-lived stager
                ack_off = _prestaged._handle.offset
                data_off = _prestaged.offset
                v.nt_store_u8(ack_off, 0)
                if nbytes:
                    v.write_release(data_off, mv)
                    v.count_path("rndv_staged", nbytes)

                def sync_done():
                    pass
            else:
                h = self.arena.create(
                    f"rv:{self.name}:{self.rank}:{dest}:{self._rndv_seq}",
                    _RNDV_CTRL + nbytes)
                self._rndv_seq += 1
                ack_off = h.offset
                data_off = h.offset + _RNDV_CTRL
                v.nt_store_u8(ack_off, 0)           # heap memory is dirty
                if nbytes:
                    v.write_release(data_off, mv)
                    v.count_path("rndv_staged", nbytes)
            # wire descriptor: [total u64 | tag u64 | ack u64 | data u64]
            desc = (nbytes.to_bytes(8, "little")
                    + (int(tag) & _MB_ANY).to_bytes(8, "little")
                    + ack_off.to_bytes(8, "little")
                    + data_off.to_bytes(8, "little"))
            while not q.try_enqueue_parts(
                    (desc,), FLAG_FIRST | FLAG_LAST | FLAG_RNDV):
                yield
            if sync_done is not None:
                # synchronous-mode: complete when the receiver drained
                # the staging memory (it is then reusable)
                while not v.nt_load_u8(ack_off):
                    yield
                sync_done()
            else:
                self._stagers.append(h)             # reclaimed on ack
        req._gen = gen()
        req._comm = self
        self._send_fifo.setdefault(dest, deque()).append(req)
        self._progress()                         # start eagerly (in order)
        return req

    def irecv(self, src: int, tag: int = ANY_TAG, *,
              _internal: bool = False) -> Request:
        return self._irecv_impl(src, tag, None, _internal=_internal)

    def irecv_into(self, src: int, buf, tag: int = ANY_TAG, *,
                   _internal: bool = False) -> Request:
        """``buf``: any writable buffer-protocol object, a PoolBuffer /
        PoolView (pool-resident destination), or a Registration (pinned
        user buffer). Pool-addressable destinations are PUBLISHED in the
        matchbox so a matching sender can deliver the payload with one
        copy and no receiver-side drain (posted rendezvous)."""
        return self._irecv_impl(src, tag, self._resolve_dest(buf),
                                _internal=_internal)

    def _irecv_impl(self, src: int, tag: int,
                    dest: Optional[_RecvDest], *,
                    _internal: bool = False) -> Request:
        if tag != ANY_TAG and int(tag) >= TAG_RESERVED_BASE \
                and not _internal:
            # mirror of the isend fence: a user receive on a reserved
            # tag could steal a collective schedule round
            raise ValueError(
                f"tag {tag:#x} is in the reserved internal tag space "
                f"(>= {TAG_RESERVED_BASE:#x})")
        req = Request(kind="recv", tag=tag, src=src)
        dst = dest.mv if dest is not None else None
        cap = dest.capacity if dest is not None else 0

        def deliver_bytes(d: bytes, t: int) -> None:
            """Parked / staged-pull / salvaged payload -> destination."""
            if dest is not None:
                if len(d) > cap:
                    raise ValueError(
                        f"recv_into: message of {len(d)}B exceeds "
                        f"buffer of {cap}B")
                dst[:len(d)] = d
                self.arena.view.count_copy(len(d))
                dest.flush(self.arena.view, len(d))
            else:
                req.data = d
            req.nbytes, req.tag = len(d), t

        def gen():
            pend = None              # our matchbox intent (live/spilled)

            def secure_dst(rndv: bool):
                """About to deliver a NON-posted payload into the
                destination: withdraw our posting FIRST. A sender may
                already have committed a claim into the same buffer —
                retracting salvages that payload before the delivery
                below overwrites it (the salvage-before-scribble
                ordering the matchbox protocol requires). A posting
                still in the overflow list is unlinked; that counts as
                a capacity miss only when the payload actually RODE a
                rendezvous path (``rndv``) — an eager delivery never
                had a one-copy path to lose, so it must not inflate
                the matchbox sizing signal."""
                self._mb_withdraw(pend, fallback_delivery=rndv)

            try:
                park = self._parked[src]
                while True:
                    for i, (d, t, rv) in enumerate(park):
                        if _tag_match(tag, t):
                            del park[i]
                            secure_dst(rv)
                            deliver_bytes(d, t)
                            return
                    if src == self.rank:
                        yield
                        continue
                    # publish the destination BEFORE draining: a sender
                    # arriving from now on can deliver straight into it.
                    # A full strip SPILLS the posting to the pair's
                    # overflow list (promoted FIFO as entries retire) —
                    # never a lazy retry, never a lost posting.
                    if pend is None and dest is not None \
                            and dest.postable:
                        pend = self._mb_post_or_spill(src, tag, dest,
                                                      req)
                    # per-source matching is ordered: only the EFFECTIVE
                    # HEAD posted receive may drain the pair queue (it
                    # parks foreign tags; two generators interleaving one
                    # message's chunks would corrupt the framing).
                    # Non-head receives above still complete from parked
                    # messages.
                    fifo = self._recv_fifo.get(src)
                    if fifo:
                        while fifo and (fifo[0].done
                                        or fifo[0]._error is not None):
                            fifo.popleft()
                        if fifo and fifo[0] is not req:
                            yield
                            continue
                    q = self.mq.recv_queue(src)
                    out = q.try_dequeue()
                    if out is None:
                        yield
                        continue
                    payload, flags = out
                    if not flags & FLAG_FIRST:
                        raise RuntimeError(
                            "cMPI framing error: expected FIRST chunk")
                    total = int.from_bytes(payload[:8], "little")
                    t = int.from_bytes(payload[8:16], "little")
                    match = _tag_match(tag, t)
                    v = self.arena.view
                    # an undersized dst is a truncation error (MPI_ERR_
                    # TRUNCATE): the message is still fully consumed (so
                    # the pair queue stays framed and rendezvous stagers
                    # get ack'd) and then discarded before raising
                    truncate = (match and dest is not None
                                and total > cap)
                    if flags & FLAG_POSTED:
                        # ---- posted rendezvous: the payload already
                        # sits in a buffer THIS rank posted
                        slot = int.from_bytes(payload[16:24], "little")
                        pid = int.from_bytes(payload[24:32], "little")
                        d = self._mb_take(src, slot, pid, total, req)
                        if d is None:
                            # consumed in place by our own posting:
                            # zero receiver-side copies (_mb_take
                            # already recycled the entry)
                            if pend is not None:
                                pend.closed = True
                                pend.rec = None
                            req.nbytes, req.tag = total, t
                            return
                        # salvaged from a foreign/retracted posting —
                        # route it exactly like a parked payload
                        if match:
                            secure_dst(True)
                            deliver_bytes(d, t)
                            return
                        park.append((d, t, True))
                        continue
                    if flags & FLAG_RNDV:
                        # ---- staged rendezvous: bulk-pull from the
                        # pool-resident source (staging object or
                        # PoolBuffer/PoolView)
                        ack_off = int.from_bytes(payload[16:24], "little")
                        data_off = int.from_bytes(payload[24:32], "little")
                        if match and dest is not None and not truncate:
                            secure_dst(True)
                            if total:
                                v.read_acquire_into(data_off, dst[:total])
                                v.count_path("rndv_staged", total)
                            dest.flush(v, total)
                            v.nt_store_u8(ack_off, 1)    # ack the drain
                            req.nbytes, req.tag = total, t
                            return
                        if truncate:
                            v.nt_store_u8(ack_off, 1)  # release the sender
                            raise ValueError(
                                f"recv_into: message of {total}B exceeds "
                                f"buffer of {cap}B (message discarded)")
                        d = (bytes(v.read_acquire(data_off, total))
                             if total else b"")
                        v.nt_store_u8(ack_off, 1)
                        if total:
                            v.count_path("rndv_staged", total)
                        if match:
                            req.data = d
                            req.nbytes, req.tag = total, t
                            return
                        park.append((d, t, True))
                        continue
                    # ---- eager: drain chunk cells straight into the sink
                    if match and dest is not None and not truncate:
                        secure_dst(False)
                        sink = dst
                    else:
                        sink = memoryview(bytearray(total))
                    k = min(len(payload) - 16, total)
                    sink[:k] = payload[16:16 + k]
                    v.count_copy(k)
                    req._draining = True     # mid-message: not cancellable
                    while k < total:
                        got = q.try_dequeue_into(sink[k:total])
                        if got is None:
                            yield
                            continue
                        k += got[0]
                    req._draining = False
                    v.count_path("eager", total)
                    if truncate:
                        raise ValueError(
                            f"recv_into: message of {total}B exceeds "
                            f"buffer of {cap}B (message discarded)")
                    if match and dest is not None:
                        dest.flush(v, total)
                        req.nbytes, req.tag = total, t
                        return
                    d = bytes(sink)
                    if match:
                        req.data = d
                        req.nbytes, req.tag = total, t
                        return
                    park.append((d, t, False))
            finally:
                # completing any way other than our own posted entry
                # (eager, staged, parked, salvage, error, abandonment)
                # leaves that entry live (or spilled) — withdraw it
                # before the user buffer changes owner
                self._mb_withdraw(pend)
        req._gen = gen()
        req._comm = self        # wait()/test() must pump the send engine
        self._recv_fifo.setdefault(src, deque()).append(req)
        # prime once: a parked match completes immediately, and a
        # postable destination is published before control returns to
        # the caller (the matchbox contract: entries exist BEFORE the
        # sender's descriptor does)
        try:
            next(req._gen)
        except StopIteration:
            req._finish()
            req._unpost()
        except BaseException as e:
            req._error = e
            req._unpost()
        return req

    def waitall(self, reqs: list, timeout: float | None = 30.0) -> None:
        """Complete every request — plain pt2pt ``Request``s, persistent
        requests and ``CollRequest``s may be mixed freely. Each sweep
        pumps the SHARED progress engine through every still-pending
        request once, so no request starves behind an earlier one."""
        _waitall(reqs, timeout)

    def waitany(self, reqs: list,
                timeout: float | None = 30.0) -> tuple[int, Any]:
        """Block until ANY of the (mixed-kind) requests completes;
        returns ``(index, request)``."""
        return _waitany(reqs, timeout)

    def testall(self, reqs: list) -> bool:
        """One fair engine sweep across the (mixed-kind) requests;
        True iff all have completed."""
        return _testall(reqs)

    # ------------------------------------------------------------------
    def barrier(self) -> None:
        self._barrier.wait()

    def win_allocate(self, name: str, win_size: int) -> Window:
        """Collective window creation: root creates, others open-poll.

        The window is bound to this communicator, enabling the full RMA
        v2 surface: request-based ``rput``/``rget`` (engine-pumped,
        composable with pt2pt requests in ``waitall``), notified access
        (``put_notify``/``wait_notify``), passive-target
        ``lock_all``/``flush``, and the schedule-compiled window
        collectives (``Window.allgather``/``bcast``). Every RMA byte is
        accounted under ``stats().path_copied_bytes["rma_*"]``."""
        if self.rank == 0:
            w = Window(self.arena, name, self.size, self.rank, win_size,
                       create=True, comm=self)
        else:
            t0 = time.monotonic()
            while True:
                try:
                    w = Window(self.arena, name, self.size, self.rank,
                               win_size, create=False, comm=self)
                    break
                except FileNotFoundError:
                    if time.monotonic() - t0 > 30.0:
                        raise
                    time.sleep(0.0005)
        self.barrier()
        return w

    def win_create_dynamic(self, name: str,
                           attach_slots: int = 32) -> DynamicWindow:
        """Collective MPI_Win_create_dynamic: a window with no backing
        arena object. Each rank ``attach``-es pool-resident buffers
        (``PoolBuffer``/``PoolView``/``ObjHandle``) and peers address
        them by the ABSOLUTE pool offset ``attach`` returned — an
        existing KV page is served one-sided without copying it into a
        window arena, and attach/detach themselves move zero payload
        bytes. ``attach_slots`` bounds the per-rank live-region count
        (it sizes the shared attach table, so pass the same value on
        every rank)."""
        if self.rank == 0:
            w = DynamicWindow(self.arena, name, self.size, self.rank,
                              create=True, comm=self,
                              attach_slots=attach_slots)
        else:
            t0 = time.monotonic()
            while True:
                try:
                    w = DynamicWindow(self.arena, name, self.size,
                                      self.rank, create=False, comm=self,
                                      attach_slots=attach_slots)
                    break
                except FileNotFoundError:
                    if time.monotonic() - t0 > 30.0:
                        raise
                    time.sleep(0.0005)
        self.barrier()
        return w
