"""Two-sided communication: the cMPI Communicator (paper §3.3).

Send/recv over the SPSC queue matrix: the sender enqueues into queue
(receiver_row, sender_col); the receiver polls its row. In-order delivery
per (src, dst) pair; tag matching uses a local reorder buffer (messages of
other tags are parked, never dropped).

Non-blocking isend/irecv return Request objects driven by an explicit
progress pump (MPI_Test/MPI_Wait semantics — paper §3.4 keeps these
unchanged, as do we: the message path itself is what got optimized).

Bootstrap: rank 0 creates the queue-matrix and barrier objects in the
arena; other ranks poll ``open`` until they appear — this mirrors the
paper's 'root rank creates, broadcasts the object name' flow (here the
names are deterministic, which IS the broadcast).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core.arena import Arena
from repro.core.ringqueue import DEFAULT_CELL_SIZE, QueueMatrix
from repro.core.rma import Window
from repro.core.sync import SeqBarrier

ANY_TAG = -1


@dataclass
class Request:
    kind: str                        # send | recv
    done: bool = False
    data: Optional[bytes] = None     # recv result
    tag: int = 0
    src: int = -1
    _gen: Any = field(default=None, repr=False)
    _comm: Any = field(default=None, repr=False)

    def test(self) -> bool:
        if self.done:
            return True
        if self.kind == "send":
            # sends are pumped ONLY through the per-destination FIFO —
            # chunks of different messages must never interleave in one
            # SPSC queue (framing is contiguous per message)
            self._comm._progress()
            return self.done
        try:
            next(self._gen)
        except StopIteration:
            self.done = True
        return self.done

    def wait(self, timeout: float | None = 30.0):
        t0 = time.monotonic()
        while not self.test():
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"{self.kind} request timed out")
            time.sleep(0)
        return self.data


class Communicator:
    """MPI_COMM_WORLD-alike over one arena."""

    def __init__(self, arena: Arena, rank: int, size: int, *,
                 cell_size: int = DEFAULT_CELL_SIZE, n_cells: int = 8,
                 name: str = "world", open_timeout: float = 30.0):
        self.arena = arena
        self.rank = rank
        self.size = size
        self.cell_size = cell_size
        region = QueueMatrix.region_bytes(size, cell_size, n_cells)
        bar_bytes = SeqBarrier.region_bytes(size)
        if rank == 0:
            self._mq_obj = arena.create(f"{name}:mq", region)
            self._bar_obj = arena.create(f"{name}:bar", bar_bytes)
            self.mq = QueueMatrix(arena.view, self._mq_obj.offset, size, rank,
                                  cell_size, n_cells, initialize=True)
            self._barrier = SeqBarrier(arena.view, self._bar_obj.offset, size,
                                       rank, initialize=True)
        else:
            t0 = time.monotonic()
            while True:
                try:
                    self._mq_obj = arena.open(f"{name}:mq")
                    self._bar_obj = arena.open(f"{name}:bar")
                    break
                except FileNotFoundError:
                    if time.monotonic() - t0 > open_timeout:
                        raise
                    time.sleep(0.0005)
            self.mq = QueueMatrix(arena.view, self._mq_obj.offset, size, rank,
                                  cell_size, n_cells)
            self._barrier = SeqBarrier(arena.view, self._bar_obj.offset, size,
                                       rank)
        # tag reorder buffers per src
        self._parked: dict[int, deque[tuple[bytes, int]]] = {
            s: deque() for s in range(size)}
        # progress engine: outstanding non-blocking sends advanced by every
        # blocking call (MPI progress rule — without it, two ranks that
        # isend to each other then recv would deadlock on full queues).
        # One FIFO per destination: a message's chunks must occupy the
        # pair queue CONTIGUOUSLY, so only the head request of each
        # destination is ever pumped.
        self._send_fifo: dict[int, deque[Request]] = {}
        # init barrier (paper §3.4: creation of shared queues synchronized
        # by the seq-number barrier)
        self.barrier()

    def _progress(self) -> None:
        """Advance the head send of every destination FIFO."""
        for fifo in self._send_fifo.values():
            while fifo:
                head = fifo[0]
                try:
                    next(head._gen)
                    break                    # blocked on queue space
                except StopIteration:
                    head.done = True
                    fifo.popleft()           # next message may start

    # ------------------------------------------------------------------
    # blocking pt2pt (implemented over the non-blocking path so every
    # blocking call keeps the progress engine turning)
    # ------------------------------------------------------------------
    def send(self, dest: int, data: bytes, tag: int = 0,
             timeout: float | None = 30.0) -> None:
        req = self.isend(dest, data, tag)
        t0 = time.monotonic()
        while not req.test():
            self._progress()
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"send(dest={dest}, tag={tag})")
            time.sleep(0)

    def recv(self, src: int, tag: int = ANY_TAG,
             timeout: float | None = 30.0) -> tuple[bytes, int]:
        req = self.irecv(src, tag)
        t0 = time.monotonic()
        while not req.test():
            self._progress()
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"recv(src={src}, tag={tag})")
            time.sleep(0)
        return req.data, req.tag

    # numpy convenience
    def send_array(self, dest: int, arr: np.ndarray, tag: int = 0) -> None:
        self.send(dest, np.ascontiguousarray(arr).tobytes(), tag)

    def recv_array(self, src: int, shape, dtype,
                   tag: int = ANY_TAG) -> np.ndarray:
        data, _ = self.recv(src, tag)
        return np.frombuffer(data, dtype=dtype).reshape(shape).copy()

    # ------------------------------------------------------------------
    # non-blocking pt2pt
    # ------------------------------------------------------------------
    def isend(self, dest: int, data: bytes, tag: int = 0) -> Request:
        req = Request(kind="send", tag=tag)

        def gen():
            if dest == self.rank:
                self._parked[self.rank].append((bytes(data), tag))
                return
            q = self.mq.send_queue(dest)
            first_room = q.cell_size - q._MSG_HDR
            head = (len(data).to_bytes(8, "little")
                    + int(tag).to_bytes(8, "little")
                    + bytes(data[:first_room]))
            rest = bytes(data[first_room:])
            chunks = [head] + [rest[i:i + q.cell_size]
                               for i in range(0, len(rest), q.cell_size)]
            from repro.core.ringqueue import FLAG_FIRST, FLAG_LAST
            for i, ch in enumerate(chunks):
                flags = (FLAG_FIRST if i == 0 else 0) | \
                        (FLAG_LAST if i == len(chunks) - 1 else 0)
                while not q.try_enqueue(ch, flags):
                    yield
        req._gen = gen()
        req._comm = self
        self._send_fifo.setdefault(dest, deque()).append(req)
        self._progress()                         # start eagerly (in order)
        return req

    def irecv(self, src: int, tag: int = ANY_TAG) -> Request:
        req = Request(kind="recv", tag=tag, src=src)

        def gen():
            park = self._parked[src]
            while True:
                for i, (d, t) in enumerate(park):
                    if tag in (ANY_TAG, t):
                        del park[i]
                        req.data, req.tag = d, t
                        return
                if src == self.rank:
                    yield
                    continue
                q = self.mq.recv_queue(src)
                out = q.try_dequeue()
                if out is None:
                    yield
                    continue
                payload, flags = out
                total = int.from_bytes(payload[:8], "little")
                t = int.from_bytes(payload[8:16], "little")
                parts = [payload[16:]]
                got = len(payload) - 16
                while got < total:
                    nxt = q.try_dequeue()
                    if nxt is None:
                        yield
                        continue
                    parts.append(nxt[0])
                    got += len(nxt[0])
                d = b"".join(parts)[:total]
                if tag in (ANY_TAG, t):
                    req.data, req.tag = d, t
                    return
                park.append((d, t))
        req._gen = gen()
        return req

    def waitall(self, reqs: list[Request],
                timeout: float | None = 30.0) -> None:
        t0 = time.monotonic()
        pending = list(reqs)
        while pending:
            self._progress()
            pending = [r for r in pending if not r.test()]
            if pending and timeout is not None \
                    and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"waitall: {len(pending)} pending")
            if pending:
                time.sleep(0)

    # ------------------------------------------------------------------
    def barrier(self) -> None:
        self._barrier.wait()

    def win_allocate(self, name: str, win_size: int) -> Window:
        """Collective window creation: root creates, others open-poll."""
        if self.rank == 0:
            w = Window(self.arena, name, self.size, self.rank, win_size,
                       create=True)
        else:
            t0 = time.monotonic()
            while True:
                try:
                    w = Window(self.arena, name, self.size, self.rank,
                               win_size, create=False)
                    break
                except FileNotFoundError:
                    if time.monotonic() - t0 > 30.0:
                        raise
                    time.sleep(0.0005)
        self.barrier()
        return w
