"""Two-sided communication: the cMPI Communicator (paper §3.3).

Send/recv over the SPSC queue matrix: the sender enqueues into queue
(receiver_row, sender_col); the receiver polls its row. In-order delivery
per (src, dst) pair; tag matching uses a local reorder buffer (messages of
other tags are parked, never dropped).

Two data-plane protocols, selected per message by ``eager_threshold``:

  EAGER       payload <= threshold. Chunks flow through the pair's SPSC
              queue cells as memoryview slices (gather-enqueue; no
              intermediate ``bytes`` is ever materialized). Copies per
              message: user -> cell (1) + cell -> user (1).

  RENDEZVOUS  payload > threshold, or any ``PoolBuffer``/``PoolView``
              send. The sender stages the payload ONCE into a
              pool-resident object ([ack 64B | payload]) and enqueues a
              single 32-byte control descriptor
              (total, tag, ack offset, data offset). The receiver
              ``read_acquire_into``s its destination buffer straight from
              the staging object and writes the ack byte; the sender's
              progress engine then reclaims the stager. A ``PoolBuffer``
              (pool-resident application buffer, MPI_Alloc_mem analogue)
              — or a ``PoolView`` slice of one — skips the staging copy
              entirely: zero sender-side copies, the one-sided bulk path
              the paper's CXL fabric enables (cf. foMPI routing large
              transfers through RMA windows). ``Comm``'s method
              collectives (core/comm.py) send ``PoolView`` slices of
              persistent round buffers so ring/Bruck rounds never
              re-stage.

Non-blocking isend/irecv return Request objects driven by an explicit
progress pump (MPI_Test/MPI_Wait semantics — paper §3.4 keeps these
unchanged, as do we: the message path itself is what got optimized).
Every blocking call AND every ``test()``/``wait()`` — receives included —
turns the send progress engine, so ``isend`` + ``irecv().wait()`` loops
cannot deadlock on full queues. ``recv_into``/``irecv_into`` deliver
straight into caller buffers (numpy arrays included) with no
``frombuffer().copy()`` round trip.

This module is the pt2pt ENGINE. The user-facing v2 surface — method
collectives, ``split``/``dup`` sub-communicators, persistent requests,
``eager_threshold="auto"`` — is the ``Comm`` facade in
``repro.core.comm``, which subclasses ``Communicator``.

Bootstrap: rank 0 creates the queue-matrix and barrier objects in the
arena; other ranks poll ``open`` until they appear — this mirrors the
paper's 'root rank creates, broadcasts the object name' flow (here the
names are deterministic, which IS the broadcast).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core.arena import Arena, ObjHandle
from repro.core.pool import as_u8
from repro.core.ringqueue import (DEFAULT_CELL_SIZE, FLAG_FIRST, FLAG_LAST,
                                  FLAG_RNDV, QueueMatrix)
from repro.core.rma import Window
from repro.core.sync import SeqBarrier

ANY_TAG = -1

# rendezvous staging object layout: [ctrl 64B | payload]; ctrl byte 0 is
# the receiver-written ack ("drained, reclaim/reuse me")
_RNDV_CTRL = 64


class PoolBuffer:
    """Message buffer RESIDENT in the shared pool (the MPI_Alloc_mem /
    CXL-resident application buffer of the paper).

    Sending one takes the rendezvous path with ZERO sender-side payload
    copies: the control descriptor points at this object and the receiver
    pulls straight from it. The send completes (synchronous-mode send)
    once the receiver acks the drain, after which the buffer is reusable.

    Arena object layout: [ctrl 64B | payload nbytes].
    """

    def __init__(self, comm: "Communicator", handle: ObjHandle):
        self._comm = comm
        self._handle = handle
        self.nbytes = handle.size - _RNDV_CTRL
        # one ack byte => at most ONE outstanding send per buffer
        self._in_flight = False

    @property
    def offset(self) -> int:
        """Absolute payload offset in the pool."""
        return self._handle.offset + _RNDV_CTRL

    def view(self) -> memoryview:
        """Writable zero-copy window into pool memory (memory-backed,
        hardware-coherent pools only — on incoherent pools use write)."""
        return self._comm.arena.pool.memview(self.offset, self.nbytes)

    def write(self, data, off: int = 0) -> None:
        """Protocol-correct fill (valid on every pool mode)."""
        mv = as_u8(data)
        if off < 0 or off + len(mv) > self.nbytes:
            raise IndexError("write beyond PoolBuffer")
        self._comm.arena.view.write_release(self.offset + off, mv)

    def read(self, off: int = 0, n: int | None = None) -> bytes:
        n = self.nbytes - off if n is None else n
        return self._comm.arena.view.read_acquire(self.offset + off, n)

    def free(self) -> None:
        self._comm.arena.destroy(self._handle)

    def slice(self, off: int = 0, nbytes: int | None = None) -> "PoolView":
        """A sendable window [off, off+nbytes) of this buffer. Slices
        share the buffer's single ack slot, so at most one send per
        underlying buffer may be in flight at a time."""
        nbytes = self.nbytes - off if nbytes is None else nbytes
        if off < 0 or nbytes < 0 or off + nbytes > self.nbytes:
            raise IndexError(
                f"slice [{off}, {off + nbytes}) beyond PoolBuffer "
                f"of {self.nbytes}B")
        return PoolView(self, off, nbytes)


@dataclass(frozen=True)
class PoolView:
    """A contiguous slice of a PoolBuffer, sendable with zero sender-side
    copies: the rendezvous descriptor points the receiver straight at
    pool memory. Produced by ``PoolBuffer.slice``; the ``Comm`` method
    collectives send these for every ring/Bruck round."""
    buffer: PoolBuffer
    off: int
    nbytes: int


@dataclass
class Request:
    kind: str                        # send | recv
    done: bool = False
    data: Optional[bytes] = None     # recv result (bytes-mode receives)
    nbytes: int = 0                  # payload size delivered/accepted
    tag: int = 0
    src: int = -1
    _gen: Any = field(default=None, repr=False)
    _comm: Any = field(default=None, repr=False)
    _error: Optional[BaseException] = field(default=None, repr=False)

    def test(self) -> bool:
        if self._error is not None:
            raise self._error
        if self.done:
            return True
        if self.kind == "send":
            # sends are pumped ONLY through the per-destination FIFO —
            # chunks of different messages must never interleave in one
            # SPSC queue (framing is contiguous per message)
            self._comm._progress()
            return self.done
        # a receive must ALSO turn the full progress engine: a bare
        # isend-to-peer + irecv().wait() loop would otherwise deadlock
        # once the pair queue fills (each rank blocked in a recv that
        # never advances its own outstanding send), and a synchronous
        # send waited before a posted receive needs that receive matched
        # passively (MPI posted-receive semantics)
        if self._comm is not None:
            self._comm._progress()
            if self.done:                # completed by the engine
                return True
            if self._error is not None:
                raise self._error
        try:
            next(self._gen)
        except StopIteration:
            self.done = True
            self._unpost()
        except BaseException:
            self._unpost()               # keep the FIFO draining
            raise
        return self.done

    def _unpost(self) -> None:
        if self._comm is None or self.kind != "recv":
            return
        fifo = self._comm._recv_fifo.get(self.src)
        if fifo and fifo[0] is self:
            fifo.popleft()

    def wait(self, timeout: float | None = 30.0):
        t0 = time.monotonic()
        while not self.test():
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"{self.kind} request timed out")
            time.sleep(0)
        return self.data


class Communicator:
    """MPI_COMM_WORLD-alike over one arena."""

    def __init__(self, arena: Arena, rank: int, size: int, *,
                 cell_size: int = DEFAULT_CELL_SIZE, n_cells: int = 8,
                 eager_threshold: int | None = None,
                 name: str = "world", open_timeout: float = 30.0):
        self.arena = arena
        self.rank = rank
        self.size = size
        self.name = name
        self.cell_size = cell_size
        self.n_cells = n_cells
        # protocol switch: payloads <= threshold go through queue cells
        # (eager), larger ones through a pool staging object (rendezvous)
        self.eager_threshold = (cell_size if eager_threshold is None
                                else eager_threshold)
        self.eager_sends = 0
        self.rndv_sends = 0
        region = QueueMatrix.region_bytes(size, cell_size, n_cells)
        bar_bytes = SeqBarrier.region_bytes(size)
        if rank == 0:
            self._mq_obj = arena.create(f"{name}:mq", region)
            self._bar_obj = arena.create(f"{name}:bar", bar_bytes)
            self.mq = QueueMatrix(arena.view, self._mq_obj.offset, size, rank,
                                  cell_size, n_cells, initialize=True)
            self._barrier = SeqBarrier(arena.view, self._bar_obj.offset, size,
                                       rank, initialize=True)
            # publication flag LAST: arena.create makes a name findable
            # before its contents are initialized, and derived comms
            # (split/dup) recycle dirty heap — a member must never map
            # control words rank 0 has not zeroed yet
            arena.create(f"{name}:ok", 64)
        else:
            t0 = time.monotonic()
            while True:
                try:
                    arena.open(f"{name}:ok")
                    self._mq_obj = arena.open(f"{name}:mq")
                    self._bar_obj = arena.open(f"{name}:bar")
                    break
                except FileNotFoundError:
                    if time.monotonic() - t0 > open_timeout:
                        raise
                    time.sleep(0.0005)
            self.mq = QueueMatrix(arena.view, self._mq_obj.offset, size, rank,
                                  cell_size, n_cells)
            self._barrier = SeqBarrier(arena.view, self._bar_obj.offset, size,
                                       rank)
        # tag reorder buffers per src
        self._parked: dict[int, deque[tuple[bytes, int]]] = {
            s: deque() for s in range(size)}
        # progress engine: outstanding non-blocking sends advanced by every
        # blocking call (MPI progress rule — without it, two ranks that
        # isend to each other then recv would deadlock on full queues).
        # One FIFO per destination: a message's chunks must occupy the
        # pair queue CONTIGUOUSLY, so only the head request of each
        # destination is ever pumped.
        self._send_fifo: dict[int, deque[Request]] = {}
        # posted receives, one FIFO per source (the MPI posted-receive
        # queue): the progress engine matches the HEAD of each source so
        # a synchronous send can complete even if its peer waits other
        # requests first; only the head ever drains the pair queue, so
        # two receive generators never interleave one message's chunks
        self._recv_fifo: dict[int, deque[Request]] = {}
        # rendezvous stagers awaiting the receiver's ack (then destroyed)
        self._stagers: list[ObjHandle] = []
        self._rndv_seq = 0
        self._pbuf_seq = 0
        # init barrier (paper §3.4: creation of shared queues synchronized
        # by the seq-number barrier)
        self.barrier()

    def _progress(self) -> None:
        """Advance the head send of every destination FIFO and the head
        posted receive of every source FIFO, then reclaim any rendezvous
        stagers the receivers have drained."""
        for fifo in self._send_fifo.values():
            while fifo:
                head = fifo[0]
                try:
                    next(head._gen)
                    break                    # blocked on queue space
                except StopIteration:
                    head.done = True
                    fifo.popleft()           # next message may start
                except BaseException as e:
                    # a failed send (e.g. ArenaFullError while staging)
                    # must not be reported done: record it on the
                    # request, unblock the FIFO, surface it to the
                    # caller that pumped progress
                    head._error = e
                    fifo.popleft()
                    raise
        for fifo in self._recv_fifo.values():
            # pump EVERY posted receive once: generators self-restrict
            # so only the effective head drains the pair queue, while
            # later receives may still complete from parked messages
            # (MPI: receives of different tags complete independently)
            for req in list(fifo):
                if req.done or req._error is not None:
                    continue
                try:
                    next(req._gen)
                except StopIteration:
                    req.done = True          # matched passively
                except BaseException as e:
                    # a failed receive (e.g. truncation) is recorded on
                    # its own request — never surfaced to the innocent
                    # caller that happened to pump progress
                    req._error = e
            while fifo and (fifo[0].done or fifo[0]._error is not None):
                fifo.popleft()
        if self._stagers:
            self._reclaim_stagers()

    def _reclaim_stagers(self) -> None:
        v = self.arena.view
        still = []
        for h in self._stagers:
            if v.nt_load_u8(h.offset):       # receiver ack'd the drain
                self.arena.destroy(h)
            else:
                still.append(h)
        self._stagers = still

    # ------------------------------------------------------------------
    # pool-resident buffers (zero-copy sends)
    # ------------------------------------------------------------------
    def alloc_buffer(self, nbytes: int) -> PoolBuffer:
        """Allocate a pool-resident message buffer (MPI_Alloc_mem)."""
        h = self.arena.create(f"pb:{self.name}:{self.rank}:{self._pbuf_seq}",
                              _RNDV_CTRL + nbytes)
        self._pbuf_seq += 1
        return PoolBuffer(self, h)

    # ------------------------------------------------------------------
    # blocking pt2pt (implemented over the non-blocking path so every
    # blocking call keeps the progress engine turning)
    # ------------------------------------------------------------------
    def send(self, dest: int, data, tag: int = 0,
             timeout: float | None = 30.0) -> None:
        """``data``: any buffer-protocol object or a PoolBuffer."""
        req = self.isend(dest, data, tag)
        t0 = time.monotonic()
        while not req.test():           # test() runs the progress sweep
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"send(dest={dest}, tag={tag})")
            time.sleep(0)

    def recv(self, src: int, tag: int = ANY_TAG,
             timeout: float | None = 30.0) -> tuple[bytes, int]:
        req = self.irecv(src, tag)
        t0 = time.monotonic()
        while not req.test():           # test() runs the progress sweep
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"recv(src={src}, tag={tag})")
            time.sleep(0)
        return req.data, req.tag

    def recv_into(self, src: int, buf, tag: int = ANY_TAG,
                  timeout: float | None = 30.0) -> tuple[int, int]:
        """Receive straight into ``buf`` (writable buffer-protocol object,
        numpy arrays included); returns (nbytes, tag). If the arriving
        message exceeds ``buf`` it is consumed and DISCARDED, and a
        ValueError raised (MPI truncation semantics) — the communicator
        stays usable."""
        req = self.irecv_into(src, buf, tag)
        t0 = time.monotonic()
        while not req.test():           # test() runs the progress sweep
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"recv_into(src={src}, tag={tag})")
            time.sleep(0)
        return req.nbytes, req.tag

    # numpy convenience — ndarray views end to end, no tobytes/frombuffer
    def send_array(self, dest: int, arr: np.ndarray, tag: int = 0) -> None:
        self.send(dest, np.ascontiguousarray(arr), tag)

    def recv_array(self, src: int, shape, dtype,
                   tag: int = ANY_TAG) -> np.ndarray:
        out = np.empty(shape, dtype)
        n, _ = self.recv_into(src, out, tag)
        if n != out.nbytes:
            raise ValueError(
                f"recv_array: expected {out.nbytes}B for shape {shape} "
                f"dtype {np.dtype(dtype)}, got {n}B")
        return out

    # ------------------------------------------------------------------
    # non-blocking pt2pt
    # ------------------------------------------------------------------
    def isend(self, dest: int, data, tag: int = 0) -> Request:
        req = Request(kind="send", tag=tag)
        if isinstance(data, PoolBuffer):
            pview: Optional[PoolView] = PoolView(data, 0, data.nbytes)
        elif isinstance(data, PoolView):
            pview = data
        else:
            pview = None
        pbuf = pview.buffer if pview is not None else None
        if pbuf is not None:
            if pbuf._in_flight:
                raise ValueError(
                    "PoolBuffer already has an in-flight send; wait for "
                    "it to complete before sending the buffer again "
                    "(one ack slot per buffer)")
            pbuf._in_flight = True
        mv = None if pview is not None else as_u8(data)
        nbytes = pview.nbytes if pview is not None else len(mv)
        req.nbytes = nbytes

        def gen():
            if dest == self.rank:
                if pview is not None:
                    payload = bytes(self.arena.view.read_acquire(
                        pbuf.offset + pview.off, nbytes)) if nbytes else b""
                    pbuf._in_flight = False
                else:
                    payload = mv.tobytes()
                self._parked[self.rank].append((payload, tag))
                return
            q = self.mq.send_queue(dest)
            if pview is None and nbytes <= self.eager_threshold:
                # ---- eager: memoryview slices through queue cells ----
                self.eager_sends += 1
                for parts, flags in q.plan_message(mv, tag):
                    while not q.try_enqueue_parts(parts, flags):
                        yield
                return
            # ---- rendezvous: stage once, ship a descriptor ----
            self.rndv_sends += 1
            v = self.arena.view
            if pview is not None:
                # pool-resident source: no staging copy at all
                ack_off = pbuf._handle.offset
                data_off = pbuf.offset + pview.off
                v.nt_store_u8(ack_off, 0)           # arm the ack
            else:
                h = self.arena.create(
                    f"rv:{self.name}:{self.rank}:{dest}:{self._rndv_seq}",
                    _RNDV_CTRL + nbytes)
                self._rndv_seq += 1
                ack_off = h.offset
                data_off = h.offset + _RNDV_CTRL
                v.nt_store_u8(ack_off, 0)           # heap memory is dirty
                if nbytes:
                    v.write_release(data_off, mv)
            # wire descriptor: [total u64 | tag u64 | ack u64 | data u64]
            desc = (nbytes.to_bytes(8, "little")
                    + int(tag).to_bytes(8, "little")
                    + ack_off.to_bytes(8, "little")
                    + data_off.to_bytes(8, "little"))
            while not q.try_enqueue_parts(
                    (desc,), FLAG_FIRST | FLAG_LAST | FLAG_RNDV):
                yield
            if pview is not None:
                # synchronous-mode: complete when the receiver drained
                # the user's buffer (it is then reusable)
                while not v.nt_load_u8(ack_off):
                    yield
                pbuf._in_flight = False
            else:
                self._stagers.append(h)             # reclaimed on ack
        req._gen = gen()
        req._comm = self
        self._send_fifo.setdefault(dest, deque()).append(req)
        self._progress()                         # start eagerly (in order)
        return req

    def irecv(self, src: int, tag: int = ANY_TAG) -> Request:
        return self._irecv_impl(src, tag, None)

    def irecv_into(self, src: int, buf, tag: int = ANY_TAG) -> Request:
        dst = as_u8(buf)
        if dst.readonly:
            raise ValueError("irecv_into needs a writable buffer")
        return self._irecv_impl(src, tag, dst)

    def _irecv_impl(self, src: int, tag: int, dst) -> Request:
        req = Request(kind="recv", tag=tag, src=src)

        def deliver_parked(d: bytes, t: int) -> None:
            if dst is not None:
                if len(d) > len(dst):
                    raise ValueError(
                        f"recv_into: message of {len(d)}B exceeds "
                        f"buffer of {len(dst)}B")
                dst[:len(d)] = d
                self.arena.view.count_copy(len(d))
            else:
                req.data = d
            req.nbytes, req.tag = len(d), t

        def gen():
            park = self._parked[src]
            while True:
                for i, (d, t) in enumerate(park):
                    if tag in (ANY_TAG, t):
                        del park[i]
                        deliver_parked(d, t)
                        return
                if src == self.rank:
                    yield
                    continue
                # per-source matching is ordered: only the EFFECTIVE
                # HEAD posted receive may drain the pair queue (it parks
                # foreign tags; two generators interleaving one
                # message's chunks would corrupt the framing). Non-head
                # receives above still complete from parked messages.
                fifo = self._recv_fifo.get(src)
                if fifo:
                    while fifo and (fifo[0].done
                                    or fifo[0]._error is not None):
                        fifo.popleft()
                    if fifo and fifo[0] is not req:
                        yield
                        continue
                q = self.mq.recv_queue(src)
                out = q.try_dequeue()
                if out is None:
                    yield
                    continue
                payload, flags = out
                if not flags & FLAG_FIRST:
                    raise RuntimeError(
                        "cMPI framing error: expected FIRST chunk")
                total = int.from_bytes(payload[:8], "little")
                t = int.from_bytes(payload[8:16], "little")
                match = tag in (ANY_TAG, t)
                v = self.arena.view
                # an undersized dst is a truncation error (MPI_ERR_
                # TRUNCATE): the message is still fully consumed (so the
                # pair queue stays framed and rendezvous stagers get
                # ack'd) and then discarded before raising
                truncate = (match and dst is not None
                            and total > len(dst))
                if flags & FLAG_RNDV:
                    # ---- rendezvous: bulk-pull from the pool-resident
                    # source (staging object or PoolBuffer/PoolView)
                    ack_off = int.from_bytes(payload[16:24], "little")
                    data_off = int.from_bytes(payload[24:32], "little")
                    if match and dst is not None and not truncate:
                        if total:
                            v.read_acquire_into(data_off, dst[:total])
                        v.nt_store_u8(ack_off, 1)    # ack the drain
                        req.nbytes, req.tag = total, t
                        return
                    if truncate:
                        v.nt_store_u8(ack_off, 1)    # release the sender
                        raise ValueError(
                            f"recv_into: message of {total}B exceeds "
                            f"buffer of {len(dst)}B (message discarded)")
                    d = (v.read_acquire(data_off, total)
                         if total else b"")
                    v.nt_store_u8(ack_off, 1)
                    if match:
                        req.data = d
                        req.nbytes, req.tag = total, t
                        return
                    park.append((d, t))
                    continue
                # ---- eager: drain chunk cells straight into the sink
                if match and dst is not None and not truncate:
                    sink = dst
                else:
                    sink = memoryview(bytearray(total))
                k = min(len(payload) - 16, total)
                sink[:k] = payload[16:16 + k]
                v.count_copy(k)
                while k < total:
                    got = q.try_dequeue_into(sink[k:total])
                    if got is None:
                        yield
                        continue
                    k += got[0]
                if truncate:
                    raise ValueError(
                        f"recv_into: message of {total}B exceeds "
                        f"buffer of {len(dst)}B (message discarded)")
                if match and dst is not None:
                    req.nbytes, req.tag = total, t
                    return
                d = bytes(sink)
                if match:
                    req.data = d
                    req.nbytes, req.tag = total, t
                    return
                park.append((d, t))
        req._gen = gen()
        req._comm = self        # wait()/test() must pump the send engine
        self._recv_fifo.setdefault(src, deque()).append(req)
        return req

    def waitall(self, reqs: list[Request],
                timeout: float | None = 30.0) -> None:
        t0 = time.monotonic()
        pending = list(reqs)
        while pending:                  # test() runs the progress sweep
            pending = [r for r in pending if not r.test()]
            if pending and timeout is not None \
                    and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"waitall: {len(pending)} pending")
            if pending:
                time.sleep(0)

    # ------------------------------------------------------------------
    def barrier(self) -> None:
        self._barrier.wait()

    def win_allocate(self, name: str, win_size: int) -> Window:
        """Collective window creation: root creates, others open-poll."""
        if self.rank == 0:
            w = Window(self.arena, name, self.size, self.rank, win_size,
                       create=True)
        else:
            t0 = time.monotonic()
            while True:
                try:
                    w = Window(self.arena, name, self.size, self.rank,
                               win_size, create=False)
                    break
                except FileNotFoundError:
                    if time.monotonic() - t0 > 30.0:
                        raise
                    time.sleep(0.0005)
        self.barrier()
        return w
