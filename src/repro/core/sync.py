"""Synchronization without cross-host atomics (paper §3.4).

* SeqBarrier — the paper's refactored init barrier: no shared counter
  (which needs atomic increment); instead a per-rank sequence-number array.
  Entering rank r increments ITS OWN slot and spin-waits until every other
  slot is >= its own sequence. Single writer per slot => plain stores +
  coherence protocol suffice.

* PSCW — Post-Start-Complete-Wait epochs as flag matrices in shared memory
  (one flag per (origin, target) pair, each written by exactly one rank and
  reset by exactly the other after observation — again single-writer-
  per-phase). Replaces the network notification messages of stock MPICH.

* BakeryLock — Lamport's bakery: mutual exclusion from per-rank
  single-writer slots only. Used for MPI_Win_lock(EXCLUSIVE) and arena
  mutations. MPI_Win_lock(SHARED) adds per-rank reader flags.

All memory goes through a CoherentView, so the same code is correct on an
incoherent (CXL-like) pool.
"""
from __future__ import annotations

import time

from repro.core.coherence import CoherentView

_SPIN_SLEEP = 0.0


class SeqBarrier:
    """Per-rank sequence-number barrier. Region: u64[n_ranks]."""

    def __init__(self, view: CoherentView, base: int, n_ranks: int, rank: int,
                 *, initialize: bool = False):
        self.view = view
        self.base = base
        self.n = n_ranks
        self.rank = rank
        self.seq = 0
        if initialize:
            for i in range(n_ranks):
                view.nt_store_u64(base + 8 * i, 0)

    @staticmethod
    def region_bytes(n_ranks: int) -> int:
        return 8 * n_ranks

    def wait(self, timeout: float | None = 30.0) -> None:
        self.seq += 1
        self.view.nt_store_u64(self.base + 8 * self.rank, self.seq)
        t0 = time.monotonic()
        for j in range(self.n):
            if j == self.rank:
                continue
            while self.view.nt_load_u64(self.base + 8 * j) < self.seq:
                if timeout is not None and time.monotonic() - t0 > timeout:
                    raise TimeoutError(
                        f"barrier timeout: rank {j} stuck below seq "
                        f"{self.seq}")
                time.sleep(_SPIN_SLEEP)


class PSCW:
    """Post-Start-Complete-Wait epoch flags.

    Region layout (u8 matrices, row-major [owner][peer]):
      post_flag[origin][target] : set by TARGET's post, cleared by ORIGIN's
                                  start once observed.
      comp_flag[target][origin] : set by ORIGIN's complete, cleared by
                                  TARGET's wait once observed.
    """

    def __init__(self, view: CoherentView, base: int, n_ranks: int, rank: int,
                 *, initialize: bool = False):
        self.view = view
        self.base = base
        self.n = n_ranks
        self.rank = rank
        if initialize:
            view.write_release(base, bytes(2 * n_ranks * n_ranks))

    @staticmethod
    def region_bytes(n_ranks: int) -> int:
        return 2 * n_ranks * n_ranks

    def _post_off(self, origin: int, target: int) -> int:
        return self.base + origin * self.n + target

    def _comp_off(self, target: int, origin: int) -> int:
        return self.base + self.n * self.n + target * self.n + origin

    # -- target side --------------------------------------------------
    def post(self, origin_group: list[int]) -> None:
        """Target exposes its window to each origin in the group."""
        for o in origin_group:
            self.view.write_release(self._post_off(o, self.rank), b"\x01")

    def wait(self, origin_group: list[int],
             timeout: float | None = 30.0) -> None:
        """Target waits for every origin's complete, consuming the flags."""
        t0 = time.monotonic()
        for o in origin_group:
            off = self._comp_off(self.rank, o)
            while self.view.read_acquire(off, 1) != b"\x01":
                if timeout is not None and time.monotonic() - t0 > timeout:
                    raise TimeoutError(f"PSCW wait: origin {o}")
                time.sleep(_SPIN_SLEEP)
            self.view.write_release(off, b"\x00")

    # -- origin side --------------------------------------------------
    def start(self, target_group: list[int],
              timeout: float | None = 30.0) -> None:
        """Origin waits for each target's post, consuming the flags."""
        t0 = time.monotonic()
        for t in target_group:
            off = self._post_off(self.rank, t)
            while self.view.read_acquire(off, 1) != b"\x01":
                if timeout is not None and time.monotonic() - t0 > timeout:
                    raise TimeoutError(f"PSCW start: target {t}")
                time.sleep(_SPIN_SLEEP)
            self.view.write_release(off, b"\x00")

    def complete(self, target_group: list[int]) -> None:
        for t in target_group:
            self.view.write_release(self._comp_off(t, self.rank), b"\x01")


class BakeryLock:
    """Lamport bakery lock over [choosing u8[n] | pad | number u64[n]]."""

    def __init__(self, view: CoherentView, base: int, n_ranks: int, rank: int,
                 *, initialize: bool = False):
        self.view = view
        self.base = base
        self.n = n_ranks
        self.rank = rank
        self._num_off = base + ((n_ranks + 63) // 64) * 64
        if initialize:
            view.write_release(base, bytes(self.region_bytes(n_ranks)))

    @staticmethod
    def region_bytes(n_ranks: int) -> int:
        return ((n_ranks + 63) // 64) * 64 + 8 * n_ranks

    def acquire(self, timeout: float | None = 30.0) -> None:
        v, r = self.view, self.rank
        v.nt_store_u8(self.base + r, 1)
        mx = 0
        for j in range(self.n):
            mx = max(mx, v.nt_load_u64(self._num_off + 8 * j))
        my = mx + 1
        v.nt_store_u64(self._num_off + 8 * r, my)
        v.nt_store_u8(self.base + r, 0)
        t0 = time.monotonic()
        for j in range(self.n):
            if j == r:
                continue
            while v.nt_load_u8(self.base + j):
                if timeout is not None and time.monotonic() - t0 > timeout:
                    raise TimeoutError("bakery: choosing stuck")
                time.sleep(_SPIN_SLEEP)
            while True:
                nj = v.nt_load_u64(self._num_off + 8 * j)
                if nj == 0 or (nj, j) > (my, r):
                    break
                if timeout is not None and time.monotonic() - t0 > timeout:
                    raise TimeoutError("bakery: ticket stuck")
                time.sleep(_SPIN_SLEEP)

    def release(self) -> None:
        self.view.nt_store_u64(self._num_off + 8 * self.rank, 0)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class RWLock:
    """Shared/exclusive lock: bakery for writers + per-rank reader flags.

    Region: [bakery | reader u8[n] (64-aligned)].
    Readers: take bakery briefly to set their flag only if consistent —
    simplified: reader sets flag, then checks writer ticket; if a writer
    holds the bakery, reader backs off. Writer: bakery acquire, then waits
    for all reader flags to clear.
    """

    def __init__(self, view: CoherentView, base: int, n_ranks: int, rank: int,
                 *, initialize: bool = False):
        self.view = view
        self.n = n_ranks
        self.rank = rank
        self.bakery = BakeryLock(view, base, n_ranks, rank,
                                 initialize=initialize)
        self._rd_off = base + BakeryLock.region_bytes(n_ranks)
        self._rd_off += (-self._rd_off) % 64
        if initialize:
            view.write_release(self._rd_off, bytes(n_ranks))

    @staticmethod
    def region_bytes(n_ranks: int) -> int:
        b = BakeryLock.region_bytes(n_ranks)
        b += (-b) % 64
        return b + n_ranks

    def acquire_shared(self, timeout: float | None = 30.0) -> None:
        # serialize flag-set against writers via the bakery, then release it:
        # readers only conflict with writers, not each other.
        self.bakery.acquire(timeout=timeout)
        self.view.write_release(self._rd_off + self.rank, b"\x01")
        self.bakery.release()

    def release_shared(self) -> None:
        self.view.write_release(self._rd_off + self.rank, b"\x00")

    def acquire_excl(self, timeout: float | None = 30.0) -> None:
        self.bakery.acquire(timeout=timeout)
        t0 = time.monotonic()
        for j in range(self.n):
            if j == self.rank:
                continue
            while self.view.read_acquire(self._rd_off + j, 1) != b"\x00":
                if timeout is not None and time.monotonic() - t0 > timeout:
                    self.bakery.release()
                    raise TimeoutError("RWLock: reader stuck")
                time.sleep(_SPIN_SLEEP)

    def release_excl(self) -> None:
        self.bakery.release()
