"""Measured machine profile: the single source for every tuned constant.

The comm core carries four policies that used to be hand-tuned magic:

  1. the eager/rendezvous crossover (``eager_threshold="auto"`` init
     ping-pong probe),
  2. the chunk size for pipelined large collectives
     (``auto_chunk_bytes``'s fixed ``8x-crossover / payload//8`` rule),
  3. the hierarchical-allreduce group size (``_hier_group``'s
     nearest-sqrt divisor heuristic),
  4. the matchbox strip depth (``DEFAULT_MB_SLOTS = 4``).

``benchmarks/roofline.py --profile`` runs an ERT-style per-host sweep
(copy/reduce bandwidth per working-set size, pt2pt eager-vs-posted
crossover, an end-to-end chunk-size sweep over a real chunked
iallreduce, strip-scan and spill-promote cost) and writes the results
here as a cached,
schema-versioned ``artifacts/bench/machine_profile.json``.
``Comm(tuning="auto")`` loads it — freshness- and host-checked — and
derives all four constants from measurements (the derivations live in
this module so they are unit-testable without a sweep). A missing or
stale profile falls back LOUDLY to the old heuristics.

Every value that shapes the wire format (chunk size, matchbox depth)
must be identical on all ranks: ranks agree via a max-allreduce at
``Comm`` init (the ``_chunk_probe_base`` idiom), and the matchbox depth
— fixed before the shared region is even sized — is derived
deterministically from the shared profile file, with a post-init
agreement check that hard-fails on divergence.
"""
from __future__ import annotations

import json
import os
import platform
import time
import warnings
from pathlib import Path
from typing import Optional

SCHEMA_VERSION = 1
DEFAULT_PATH = Path("artifacts/bench") / "machine_profile.json"
ENV_PATH = "REPRO_MACHINE_PROFILE"          # overrides the default path
ENV_MAX_AGE = "REPRO_PROFILE_MAX_AGE_S"
DEFAULT_MAX_AGE_S = 24 * 3600.0

# bandwidth knee: the largest working set still delivering this
# fraction of the peak measured bandwidth (ERT's ceiling-break point)
KNEE_FRACTION = 0.8

# matchbox depth bounds: never shallower than the historical default,
# never deeper than a strip scan can stay cheap relative to one claim
MB_DEPTH_MIN = 4
MB_DEPTH_MAX = 32

# hier tier ratio clamp: a measured cache/DRAM ratio outside this range
# is a measurement artifact, not a real hierarchy
TIER_RATIO_MIN = 1.0
TIER_RATIO_MAX = 64.0


def host_fingerprint() -> str:
    """Cheap identity of the measured host: a profile from a different
    machine (or container shape) must not be trusted."""
    return (f"{platform.node()}|{platform.machine()}"
            f"|cpus={os.cpu_count()}")


def profile_path(path: str | os.PathLike | None = None) -> Path:
    if path is not None:
        return Path(path)
    env = os.environ.get(ENV_PATH)
    return Path(env) if env else DEFAULT_PATH


# --------------------------------------------------------------------------
# policy derivations (pure functions — unit-tested without a sweep)
# --------------------------------------------------------------------------

def derive_eager_threshold(crossover_bytes: int) -> int:
    """Largest size still sent eagerly: half the measured crossover —
    the same safety margin the init probe applies when rendezvous wins
    at the smallest probed size."""
    return max(64, int(crossover_bytes) // 2)


def derive_chunk_floor(crossover_bytes: int,
                       best_chunk_bytes: int) -> int:
    """Pipeline chunk size from the MEASURED chunk-size sweep (a real
    chunked iallreduce timed at each candidate chunk): the measured
    argmax, never below the rendezvous-amortization floor of 8x the
    crossover, never below 64 KiB (tag-window pressure). The copy-
    bandwidth knee alone is NOT the answer — a knee-sized chunk keeps
    every tile cache-resident but multiplies the per-chunk engine
    round-trip cost, and on hosts where yields are expensive that
    overhead swamps the cache win; only the end-to-end sweep sees both
    forces. ``best_chunk_bytes == 0`` means unchunked won everywhere
    probed — returns 0, and ``auto_chunk_bytes`` disables chunking."""
    if int(best_chunk_bytes) <= 0:
        return 0
    return max(64 * 1024, 8 * int(crossover_bytes),
               int(best_chunk_bytes))


def derive_tier_ratio(cache_gbps: float, dram_gbps: float) -> float:
    """Measured intra/inter tier bandwidth ratio for hier grouping."""
    if dram_gbps <= 0:
        return TIER_RATIO_MIN
    r = float(cache_gbps) / float(dram_gbps)
    return min(TIER_RATIO_MAX, max(TIER_RATIO_MIN, r))


def derive_mb_depth(spill_promote_us: float,
                    strip_scan_us_per_slot: float) -> int:
    """Strip depth where scanning one more slot costs about what one
    spill+promote cycle saves: depth ~ promote-cost / per-slot scan
    cost, clamped to [4, 32]."""
    if strip_scan_us_per_slot <= 0:
        return MB_DEPTH_MIN
    d = round(float(spill_promote_us) / float(strip_scan_us_per_slot))
    return int(min(MB_DEPTH_MAX, max(MB_DEPTH_MIN, d)))


# --------------------------------------------------------------------------
# the profile object
# --------------------------------------------------------------------------

class MachineProfile:
    """Validated view over one ``machine_profile.json``."""

    REQUIRED = ("schema", "host", "created",
                "eager_crossover_bytes", "copy_knee_bytes",
                "best_chunk_bytes",
                "cache_gbps", "dram_gbps",
                "strip_scan_us_per_slot", "spill_promote_us",
                "yield_cost_us")

    def __init__(self, data: dict, path: Optional[Path] = None):
        missing = [k for k in self.REQUIRED if k not in data]
        if missing:
            raise ValueError(f"machine profile missing fields: {missing}")
        self.data = data
        self.path = path

    # -- raw fields ----------------------------------------------------
    @property
    def eager_crossover(self) -> int:
        return int(self.data["eager_crossover_bytes"])

    @property
    def copy_knee(self) -> int:
        return int(self.data["copy_knee_bytes"])

    @property
    def best_chunk(self) -> int:
        return int(self.data["best_chunk_bytes"])

    @property
    def yield_cost_us(self) -> float:
        return float(self.data["yield_cost_us"])

    @property
    def smoke(self) -> bool:
        return bool(self.data.get("smoke", False))

    # -- derived policies ----------------------------------------------
    @property
    def eager_threshold(self) -> int:
        return derive_eager_threshold(self.eager_crossover)

    @property
    def chunk_floor(self) -> int:
        return derive_chunk_floor(self.eager_crossover, self.best_chunk)

    @property
    def tier_ratio(self) -> float:
        return derive_tier_ratio(float(self.data["cache_gbps"]),
                                 float(self.data["dram_gbps"]))

    @property
    def mb_depth(self) -> int:
        return derive_mb_depth(
            float(self.data["spill_promote_us"]),
            float(self.data["strip_scan_us_per_slot"]))

    # -- freshness ------------------------------------------------------
    def stale_reason(self, now: Optional[float] = None) -> Optional[str]:
        """None when the profile is trustworthy on this host, else a
        human-readable reason (schema drift, foreign host, age)."""
        if int(self.data["schema"]) != SCHEMA_VERSION:
            return (f"schema {self.data['schema']} != "
                    f"{SCHEMA_VERSION}")
        if self.data["host"] != host_fingerprint():
            return (f"host fingerprint mismatch "
                    f"({self.data['host']!r} != "
                    f"{host_fingerprint()!r})")
        max_age = float(os.environ.get(ENV_MAX_AGE, DEFAULT_MAX_AGE_S))
        age = (time.time() if now is None else now) \
            - float(self.data["created"])
        if age > max_age:
            return f"profile is {age / 3600.0:.1f} h old (max " \
                   f"{max_age / 3600.0:.1f} h)"
        return None


def load_profile_info(path: str | os.PathLike | None = None, *,
                      quiet: bool = False
                      ) -> tuple[Optional[MachineProfile], Optional[str]]:
    """``(profile, reject_reason)``: the profile when it is fresh and
    trustworthy (reason None), else ``(None, reason)`` — the reason a
    long-lived process can SURFACE (``Comm.tuning_status``,
    ``trace_report()``) instead of losing it after the one warning."""
    p = profile_path(path)
    if not p.exists():
        return None, f"no machine profile at {p}"
    try:
        prof = MachineProfile(json.loads(p.read_text()), p)
    except (ValueError, OSError, json.JSONDecodeError) as e:
        reason = f"unreadable machine profile {p}: {e}"
        if not quiet:
            warnings.warn(f"ignoring {reason}", RuntimeWarning,
                          stacklevel=2)
        return None, reason
    reason = prof.stale_reason()
    if reason is not None:
        if not quiet:
            warnings.warn(
                f"ignoring stale machine profile {p}: {reason}; "
                f"falling back to heuristic tuning (regenerate with "
                f"`python -m benchmarks.roofline --profile`)",
                RuntimeWarning, stacklevel=2)
        return None, f"stale machine profile {p}: {reason}"
    return prof, None


def load_profile(path: str | os.PathLike | None = None, *,
                 quiet: bool = False) -> Optional[MachineProfile]:
    """Load a FRESH machine profile or return None. Stale / foreign /
    malformed profiles are rejected with a loud warning (the caller
    falls back to the heuristic policies) — silent mis-tuning from a
    recycled CI artifact is the failure mode this guards against.
    ``load_profile_info`` additionally returns the rejection reason."""
    return load_profile_info(path, quiet=quiet)[0]


def write_profile(data: dict,
                  path: str | os.PathLike | None = None) -> Path:
    """Stamp schema/host/created and write atomically. ``data`` holds
    the measured fields (see ``MachineProfile.REQUIRED`` plus the raw
    sweep curves the report prints)."""
    out = dict(data)
    out["schema"] = SCHEMA_VERSION
    out["host"] = host_fingerprint()
    out["created"] = time.time()
    MachineProfile(out)                      # validate before writing
    p = profile_path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_suffix(".tmp")
    tmp.write_text(json.dumps(out, indent=2, sort_keys=True) + "\n")
    tmp.replace(p)
    return p
