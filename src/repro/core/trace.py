"""Flight recorder + metrics registry for the comm core.

An always-compiled, off-by-default tracer: every hot path in the comm
core (engine ticks, schedule-node issue/complete, pt2pt protocol
decisions, matchbox lifecycle, RMA epoch edges) carries an
instrumentation point of the form::

    tr = self.tracer
    if tr.enabled:
        tr.emit(EV_..., a0, a1, a2)

so the *disabled* cost is exactly one attribute load and one branch per
site (LP005 in ``repro.analysis.lint_protocol`` enforces the shape:
every ``emit`` call in a tick path must sit under an ``.enabled`` guard
and must not build f-strings or dicts in its arguments).

The recorder is a fixed-capacity ring of binary event records — five
``int64`` words per record ``(t_ns, event_id, a0, a1, a2)`` in one
preallocated ``array('q')`` that is NEVER reallocated; wraparound
overwrites the oldest records, keeping the newest ``capacity`` events
(flight-recorder semantics). Timestamps are ``time.monotonic_ns()``,
which on Linux is CLOCK_MONOTONIC — one epoch for every process on the
host, so per-rank dumps from a multi-process run merge into a single
coherent timeline without clock alignment.

On top of the ring sits a small metrics registry (``Metrics``:
counters, gauges, log2-bucket latency histograms). ``emit`` keeps
three histograms live while tracing is enabled — engine-tick duration,
posted-rendezvous hit latency (matchbox post -> consume), and
``wait_notify`` spin latency — and ``Tracer.report`` unifies them with
the aggregate ``ProtocolStats`` counters into one observable view
(``comm.trace_report()``).

Exporters:

* ``chrome_events(dump)`` / ``merge_dumps(dumps)`` — Chrome
  trace-event JSON (load in Perfetto / chrome://tracing): one process
  lane per rank; engine ticks and schedule executions as duration
  slices; every schedule NODE gets its own sub-lane (so a chunked
  iallreduce renders as per-chunk lanes); pt2pt decisions and matchbox
  lifecycle as instants; RMA fence/flush/wait as nested B/E slices.
* ``summarize_dumps(dumps)`` — text top-N event summary + histogram
  percentiles.
* ``python -m repro.trace merge|summarize`` — stitch per-rank dump
  files from a multi-process run (see ``repro/trace.py``).

Thread safety: a tracer is written by its owning rank's cooperative
engine only (one writer); ``split()``/``dup()`` children share the
parent's tracer so a rank's whole comm tree lands in one ring.
"""
from __future__ import annotations

import json
import time
from array import array
from pathlib import Path

__all__ = [
    "Tracer", "Metrics", "as_tracer", "chrome_events", "merge_dumps",
    "summarize_dumps", "load_dump", "EV_NAMES",
]

_REC_WORDS = 5
DEFAULT_CAPACITY = 1 << 15          # 32768 records x 40 B = 1.25 MiB

# ---------------------------------------------------------------------------
# event taxonomy (ids are wire-stable within a dump via EV_NAMES)
# ---------------------------------------------------------------------------

EV_TICK = 1                 # engine tick with work    a0=duration_ns
EV_PT2PT_EAGER = 10         # eager send decision      a0=peer a1=nbytes a2=tag
EV_PT2PT_STAGED = 11        # staged-rendezvous send   a0=peer a1=nbytes a2=tag
EV_PT2PT_POSTED = 12        # posted-rendezvous send   a0=peer a1=nbytes a2=tag
EV_MB_POST = 20             # matchbox entry posted    a0=post_id a1=peer a2=cap
EV_MB_CLAIM = 21            # sender claimed an entry  a0=post_id a1=peer a2=nbytes
EV_MB_SPILL = 22            # posting spilled to FIFO  a0=post_id a1=peer
EV_MB_PROMOTE = 23          # spilled posting promoted a0=post_id a1=peer
EV_MB_RETRACT = 24          # receiver retracted       a0=post_id
EV_MB_CONSUME = 25          # posted data consumed     a0=post_id a1=peer a2=nbytes
EV_SCHED_BEGIN = 30         # schedule exec started    a0=exec a1=kind_sid a2=nodes
EV_SCHED_END = 31           # schedule exec complete   a0=exec
EV_SCHED_ISSUE = 32         # node issued              a0=exec a1=node_idx
EV_SCHED_DONE = 33          # node retired             a0=exec a1=node_idx
EV_SCHED_ABORT = 34         # exec aborted             a0=exec a1=node_idx
EV_RMA_PUT = 40             # window put executed      a0=target a1=nbytes
EV_RMA_GET = 41             # window get executed      a0=target a1=nbytes
EV_RMA_NOTIFY = 42          # put_notify payload+bump  a0=target a1=nbytes
EV_RMA_WAIT_BEGIN = 43      # wait_notify spin entered a0=source
EV_RMA_WAIT_END = 44        # wait_notify satisfied    a0=source
EV_RMA_FENCE_BEGIN = 45     # fence entered
EV_RMA_FENCE_END = 46       # fence passed
EV_RMA_FLUSH_BEGIN = 47     # flush entered            a0=target(-1=all)
EV_RMA_FLUSH_END = 48       # flush complete           a0=target(-1=all)
EV_RMA_LOCK_ALL = 49        # passive epoch opened
EV_RMA_UNLOCK_ALL = 50      # passive epoch closed

EV_NAMES = {
    EV_TICK: "engine.tick",
    EV_PT2PT_EAGER: "pt2pt.eager",
    EV_PT2PT_STAGED: "pt2pt.staged",
    EV_PT2PT_POSTED: "pt2pt.posted",
    EV_MB_POST: "mb.post",
    EV_MB_CLAIM: "mb.claim",
    EV_MB_SPILL: "mb.spill",
    EV_MB_PROMOTE: "mb.promote",
    EV_MB_RETRACT: "mb.retract",
    EV_MB_CONSUME: "mb.consume",
    EV_SCHED_BEGIN: "sched.begin",
    EV_SCHED_END: "sched.end",
    EV_SCHED_ISSUE: "sched.issue",
    EV_SCHED_DONE: "sched.done",
    EV_SCHED_ABORT: "sched.abort",
    EV_RMA_PUT: "rma.put",
    EV_RMA_GET: "rma.get",
    EV_RMA_NOTIFY: "rma.notify",
    EV_RMA_WAIT_BEGIN: "rma.wait_notify.begin",
    EV_RMA_WAIT_END: "rma.wait_notify.end",
    EV_RMA_FENCE_BEGIN: "rma.fence.begin",
    EV_RMA_FENCE_END: "rma.fence.end",
    EV_RMA_FLUSH_BEGIN: "rma.flush.begin",
    EV_RMA_FLUSH_END: "rma.flush.end",
    EV_RMA_LOCK_ALL: "rma.lock_all",
    EV_RMA_UNLOCK_ALL: "rma.unlock_all",
}


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class Histogram:
    """Log2-bucket latency histogram over nanosecond samples.

    Bucket ``b`` holds samples with ``bit_length() == b`` (i.e. values
    in ``[2**(b-1), 2**b)``); percentiles report the bucket's upper
    edge, so they are <= 2x the true value — the right fidelity for a
    "where did this microsecond go" histogram at zero allocation per
    sample.
    """

    __slots__ = ("buckets", "count", "total")

    def __init__(self):
        self.buckets = [0] * 64
        self.count = 0
        self.total = 0

    def record(self, ns: int) -> None:
        if ns < 0:
            ns = 0
        self.buckets[min(ns.bit_length(), 63)] += 1
        self.count += 1
        self.total += ns

    def percentile(self, q: float) -> int:
        """Upper bucket edge at quantile ``q`` in [0, 1]."""
        if self.count == 0:
            return 0
        target = max(1, int(q * self.count + 0.999999))
        cum = 0
        for b, n in enumerate(self.buckets):
            cum += n
            if cum >= target:
                return 1 << b
        return 1 << 63

    def summary(self) -> dict:
        return {
            "count": self.count,
            "total_ns": self.total,
            "avg_ns": self.total // self.count if self.count else 0,
            "p50_ns": self.percentile(0.50),
            "p90_ns": self.percentile(0.90),
            "p99_ns": self.percentile(0.99),
        }


class Metrics:
    """Named counters, gauges and histograms for non-hot-path metrics.

    Hot paths go through ``Tracer.emit`` (int event ids, no string
    keys); this registry is for everything else — subsystem-level
    counters (a future serving tier's admission counts), gauges
    (queue depths), and extra latency histograms.
    """

    def __init__(self):
        self.counters: dict[str, int] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}

    def counter(self, name: str, inc: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def observe(self, name: str, ns: int) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        h.record(ns)

    def view(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.summary()
                           for k, h in self.histograms.items()},
        }


# ---------------------------------------------------------------------------
# the flight recorder
# ---------------------------------------------------------------------------

class Tracer:
    """Fixed-capacity binary ring of ``(t_ns, ev, a0, a1, a2)`` records.

    ``enabled`` is THE predicate every instrumentation site checks; a
    disabled tracer is a real object (so tests can inject a counting
    recorder and assert zero writes) whose only runtime footprint is
    that one attribute.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY, rank: int = 0,
                 enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.enabled = enabled
        self.rank = rank
        self.capacity = capacity
        # one preallocated int64 array; emit never allocates records
        self._buf = array("q", bytes(8 * _REC_WORDS * capacity))
        self._head = 0                  # total records ever written
        self._strings: dict[str, int] = {}
        self._names: dict[int, str] = {}
        self._next_exec = 0
        # keyed (post_id, peer): post_ids are per-pair monotone
        # sequences each starting at 1, so ids alone collide across
        # source ranks
        self._post_t: dict[tuple[int, int], int] = {}
        self._wait_t: dict[int, int] = {}     # source  -> wait-begin t_ns
        self.metrics = Metrics()
        self.counts: dict[int, int] = {}      # event id -> emits
        self.hist_tick = Histogram()
        self.hist_posted_hit = Histogram()
        self.hist_notify_wait = Histogram()

    # -- hot path ----------------------------------------------------------

    def emit(self, ev: int, a0: int = 0, a1: int = 0, a2: int = 0) -> None:
        """Append one record. Callers in tick paths must guard with
        ``if tracer.enabled:`` (LP005)."""
        t = time.monotonic_ns()
        b = self._buf
        i = (self._head % self.capacity) * _REC_WORDS
        b[i] = t
        b[i + 1] = ev
        b[i + 2] = a0
        b[i + 3] = a1
        b[i + 4] = a2
        self._head += 1
        self.counts[ev] = self.counts.get(ev, 0) + 1
        # live histograms: tick duration, post->consume, wait_notify spin
        if ev == EV_TICK:
            self.hist_tick.record(a0)
        elif ev == EV_MB_POST:
            self._post_t[(a0, a1)] = t
        elif ev == EV_MB_CONSUME:
            t0 = self._post_t.pop((a0, a1), None)
            if t0 is not None:
                self.hist_posted_hit.record(t - t0)
        elif ev == EV_RMA_WAIT_BEGIN:
            self._wait_t[a0] = t
        elif ev == EV_RMA_WAIT_END:
            t0 = self._wait_t.pop(a0, None)
            if t0 is not None:
                self.hist_notify_wait.record(t - t0)

    def intern(self, s: str) -> int:
        """Map a string (schedule kind, lane label) to a small id so
        hot-path records carry ints only. Call once per execution at
        setup time, not per event."""
        sid = self._strings.get(s)
        if sid is None:
            sid = self._strings[s] = len(self._strings) + 1
            self._names[sid] = s
        return sid

    def next_exec_id(self) -> int:
        self._next_exec += 1
        return self._next_exec

    # -- inspection --------------------------------------------------------

    @property
    def recorded(self) -> int:
        """Total records ever written (wraparound does not reset it)."""
        return self._head

    def events(self) -> list[tuple[int, int, int, int, int]]:
        """The newest ``min(recorded, capacity)`` records, oldest
        first."""
        n = min(self._head, self.capacity)
        b = self._buf
        out = []
        for k in range(self._head - n, self._head):
            i = (k % self.capacity) * _REC_WORDS
            out.append((b[i], b[i + 1], b[i + 2], b[i + 3], b[i + 4]))
        return out

    def clear(self) -> None:
        self._head = 0
        self.counts.clear()
        self._post_t.clear()
        self._wait_t.clear()
        self.hist_tick = Histogram()
        self.hist_posted_hit = Histogram()
        self.hist_notify_wait = Histogram()

    def report(self, stats=None) -> dict:
        """Unified metrics view: event counters, the live latency
        histograms, registry metrics and (when given) the aggregate
        ``ProtocolStats`` snapshot."""
        reg = self.metrics.view()
        counters = {EV_NAMES.get(ev, f"ev{ev}"): n
                    for ev, n in sorted(self.counts.items())}
        counters.update(reg["counters"])
        hists = {
            "engine_tick_ns": self.hist_tick.summary(),
            "posted_hit_ns": self.hist_posted_hit.summary(),
            "notify_wait_ns": self.hist_notify_wait.summary(),
        }
        hists.update(reg["histograms"])
        out = {
            "rank": self.rank,
            "enabled": self.enabled,
            "events_recorded": self._head,
            "events_kept": min(self._head, self.capacity),
            "counters": counters,
            "gauges": reg["gauges"],
            "histograms": hists,
        }
        if stats is not None:
            out["protocol_stats"] = stats.snapshot()
        return out

    def dump(self, path, stats=None) -> str:
        """Write this rank's ring + report as a JSON dump file that
        ``python -m repro.trace merge`` can stitch with its peers."""
        d = {
            "schema": 1,
            "rank": self.rank,
            "strings": {str(k): v for k, v in self._names.items()},
            "events": [list(e) for e in self.events()],
            "report": self.report(stats),
        }
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(d) + "\n")
        return str(p)


def as_tracer(trace, rank: int) -> Tracer:
    """Normalize the ``Comm(trace=...)`` argument.

    None/False -> disabled 1-slot tracer; True -> enabled default
    capacity; int -> enabled with that capacity; a ``Tracer`` instance
    is used as-is (tests inject counting recorders this way; children
    of ``split()``/``dup()`` share the parent's).
    """
    if isinstance(trace, Tracer):
        return trace
    if trace is None or trace is False:
        return Tracer(capacity=1, rank=rank, enabled=False)
    if trace is True:
        return Tracer(rank=rank)
    if isinstance(trace, int):
        return Tracer(capacity=trace, rank=rank)
    raise TypeError(f"trace= must be None, bool, int capacity or a "
                    f"Tracer, got {type(trace).__name__}")


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------

# fixed lanes (tid) within each rank's process lane (pid)
LANE_ENGINE = 0
LANE_PT2PT = 1
LANE_MATCHBOX = 2
LANE_RMA = 3
_SCHED_TID_BASE = 100       # exec e -> lane base 100 + e*512; node i at +1+i
_SCHED_LANE_SPAN = 512

_PT2PT_EVS = {EV_PT2PT_EAGER: "eager", EV_PT2PT_STAGED: "staged",
              EV_PT2PT_POSTED: "posted"}
_MB_EVS = {EV_MB_POST: "post", EV_MB_CLAIM: "claim", EV_MB_SPILL: "spill",
           EV_MB_PROMOTE: "promote", EV_MB_RETRACT: "retract",
           EV_MB_CONSUME: "consume"}
_RMA_INSTANTS = {EV_RMA_PUT: "put", EV_RMA_GET: "get",
                 EV_RMA_NOTIFY: "put_notify", EV_RMA_LOCK_ALL: "lock_all",
                 EV_RMA_UNLOCK_ALL: "unlock_all"}
_RMA_BEGINS = {EV_RMA_WAIT_BEGIN: "wait_notify",
               EV_RMA_FENCE_BEGIN: "fence", EV_RMA_FLUSH_BEGIN: "flush"}
_RMA_ENDS = {EV_RMA_WAIT_END: "wait_notify", EV_RMA_FENCE_END: "fence",
             EV_RMA_FLUSH_END: "flush"}


def _meta(pid: int, tid: int, name: str) -> dict:
    return {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": name}}


def chrome_events(dump: dict) -> list[dict]:
    """Convert one rank's dump to Chrome trace-event dicts.

    pid = rank. Fixed lanes: engine (tick duration slices), pt2pt
    (protocol-decision instants), matchbox (lifecycle instants), rma
    (epoch edges as properly nested B/E slices — fence encloses the
    flush it performs). Each schedule execution gets an exec lane (one
    enclosing slice) plus ONE LANE PER NODE, so slices never overlap
    within a lane and a chunked schedule reads as per-chunk rows.
    """
    rank = int(dump["rank"])
    strings = {int(k): v for k, v in dump.get("strings", {}).items()}
    out = [
        {"ph": "M", "name": "process_name", "pid": rank, "tid": 0,
         "args": {"name": f"rank {rank}"}},
        _meta(rank, LANE_ENGINE, "engine"),
        _meta(rank, LANE_PT2PT, "pt2pt"),
        _meta(rank, LANE_MATCHBOX, "matchbox"),
        _meta(rank, LANE_RMA, "rma"),
    ]
    sched_kind: dict[int, str] = {}
    open_sched: dict[int, int] = {}
    open_node: dict[tuple[int, int], int] = {}
    named_lanes: set[int] = set()
    for t, ev, a0, a1, a2 in dump["events"]:
        ts = t / 1000.0                          # Chrome wants us
        if ev == EV_TICK:
            out.append({"name": "tick", "ph": "X", "pid": rank,
                        "tid": LANE_ENGINE, "ts": (t - a0) / 1000.0,
                        "dur": a0 / 1000.0})
        elif ev in _PT2PT_EVS:
            out.append({"name": _PT2PT_EVS[ev], "ph": "i", "s": "t",
                        "pid": rank, "tid": LANE_PT2PT, "ts": ts,
                        "args": {"peer": a0, "bytes": a1, "tag": a2}})
        elif ev in _MB_EVS:
            out.append({"name": _MB_EVS[ev], "ph": "i", "s": "t",
                        "pid": rank, "tid": LANE_MATCHBOX, "ts": ts,
                        "args": {"post_id": a0, "peer": a1, "bytes": a2}})
        elif ev == EV_SCHED_BEGIN:
            sched_kind[a0] = strings.get(a1, f"kind{a1}")
            open_sched[a0] = t
        elif ev == EV_SCHED_ISSUE:
            open_node[(a0, a1)] = t
        elif ev == EV_SCHED_DONE:
            t0 = open_node.pop((a0, a1), None)
            if t0 is None:
                continue                         # issue fell off the ring
            kind = sched_kind.get(a0, "sched")
            base = _SCHED_TID_BASE + (a0 % 1024) * _SCHED_LANE_SPAN
            tid = base + 1 + a1 % (_SCHED_LANE_SPAN - 1)
            if tid not in named_lanes:
                named_lanes.add(tid)
                out.append(_meta(rank, tid, f"{kind}#{a0} nodes"))
            out.append({"name": f"{kind}[{a1}]", "ph": "X", "pid": rank,
                        "tid": tid, "ts": t0 / 1000.0,
                        "dur": max(t - t0, 1) / 1000.0,
                        "args": {"exec": a0, "node": a1}})
        elif ev in (EV_SCHED_END, EV_SCHED_ABORT):
            t0 = open_sched.pop(a0, None)
            if t0 is None:
                continue
            kind = sched_kind.get(a0, "sched")
            tid = _SCHED_TID_BASE + (a0 % 1024) * _SCHED_LANE_SPAN
            if tid not in named_lanes:
                named_lanes.add(tid)
                out.append(_meta(rank, tid, f"{kind}#{a0}"))
            name = f"sched:{kind}" + (" ABORTED"
                                      if ev == EV_SCHED_ABORT else "")
            out.append({"name": name, "ph": "X", "pid": rank, "tid": tid,
                        "ts": t0 / 1000.0, "dur": max(t - t0, 1) / 1000.0,
                        "args": {"exec": a0}})
        elif ev in _RMA_INSTANTS:
            out.append({"name": _RMA_INSTANTS[ev], "ph": "i", "s": "t",
                        "pid": rank, "tid": LANE_RMA, "ts": ts,
                        "args": {"peer": a0, "bytes": a1}})
        elif ev in _RMA_BEGINS:
            out.append({"name": _RMA_BEGINS[ev], "ph": "B", "pid": rank,
                        "tid": LANE_RMA, "ts": ts, "args": {"peer": a0}})
        elif ev in _RMA_ENDS:
            out.append({"name": _RMA_ENDS[ev], "ph": "E", "pid": rank,
                        "tid": LANE_RMA, "ts": ts})
    return out


def load_dump(path) -> dict:
    return json.loads(Path(path).read_text())


def merge_dumps(dumps: list[dict]) -> dict:
    """Stitch per-rank dumps into one Perfetto-loadable trace object."""
    events: list[dict] = []
    for d in sorted(dumps, key=lambda d: int(d.get("rank", 0))):
        events.extend(chrome_events(d))
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def summarize_dumps(dumps: list[dict], top: int = 10) -> str:
    """Text top-N summary across ranks: event counts + histogram
    percentiles, for terminals without a trace viewer."""
    total: dict[str, int] = {}
    lines = []
    for d in sorted(dumps, key=lambda d: int(d.get("rank", 0))):
        rep = d.get("report", {})
        for name, n in rep.get("counters", {}).items():
            total[name] = total.get(name, 0) + n
        lines.append(f"rank {d.get('rank', '?')}: "
                     f"{rep.get('events_recorded', 0)} events recorded, "
                     f"{rep.get('events_kept', 0)} kept")
        for hname, h in rep.get("histograms", {}).items():
            if h.get("count"):
                lines.append(
                    f"  {hname}: n={h['count']} avg={h['avg_ns']}ns "
                    f"p50<={h['p50_ns']}ns p99<={h['p99_ns']}ns")
    lines.append(f"top {top} events across {len(dumps)} rank(s):")
    width = max((len(n) for n in total), default=1)
    for name, n in sorted(total.items(), key=lambda kv: -kv[1])[:top]:
        lines.append(f"  {name:<{width}}  {n}")
    return "\n".join(lines)
