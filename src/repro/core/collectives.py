"""Collective ALGORITHMS built on cMPI point-to-point (paper §3.6).

The paper leaves collectives as future work but notes they decompose into
pt2pt via standard algorithms (recursive doubling [5], Bruck [20]). We
implement that decomposition — these run the framework's HOST-side
coordination (checkpoint manifests, data-pipeline epochs, elastic control),
and their communication patterns are mirrored device-side in
``distributed/schedules.py``.

NOTE (Comm API v2): the free-function surface here (``bcast(comm, arr)``
-style) is DEPRECATED as a public API — use the method collectives on
``repro.core.Comm`` (``comm.bcast(arr)``, ``comm.allreduce(...)``, ...),
which additionally route large payloads through persistent pool-resident
round buffers (zero-sender-copy PoolView rounds) and add hierarchical
algorithms over ``comm.split()`` sub-communicators. The functions in this
module remain as the protocol-correct view-based engine: ``Comm`` falls
back to them for small payloads and on pools without raw memory views
(incoherent mode), and importing them via ``repro.core`` emits a
``DeprecationWarning`` while continuing to work.

Copy-aware: every per-round exchange sends ndarray views (buffer-protocol
sends) and receives with ``recv_into`` into preallocated ndarrays — no
``tobytes()`` serialization and no ``frombuffer().copy()`` round trips in
the hot loops. Large rounds ride the communicator's rendezvous path (one
staged copy per round, vs ZERO sender-side copies on the Comm method
path, which is the difference ``benchmarks/fig5_8_osu.py`` measures).

Algorithms (n = comm size, numpy arrays):
  barrier         dissemination (log n rounds of pairwise messages)
  bcast           binomial tree
  reduce          binomial tree (op applied bottom-up)
  allreduce       recursive doubling (pow2) | ring RS+AG (any n)
  allgather       Bruck | ring
  reduce_scatter  ring
  alltoall        pairwise exchange
"""
from __future__ import annotations

import numpy as np

from repro.core.pt2pt import Communicator

_T = 0x7F000000   # tag space reserved for collectives


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def shards_to_chunk_order(flat: np.ndarray, n: int) -> np.ndarray:
    """After a ring reduce-scatter + allgather, rank i's reduced shard is
    CHUNK (i+1) % n of the padded payload — reorder the allgathered flat
    vector from rank order into chunk order. Shared by the free-function
    and Comm-method allreduce compositions."""
    per = flat.size // n
    parts = [flat[i * per:(i + 1) * per] for i in range(n)]
    return np.concatenate([parts[(c - 1) % n] for c in range(n)])


def barrier_dissemination(comm: Communicator) -> None:
    n, r = comm.size, comm.rank
    k = 1
    rnd = 0
    while k < n:
        dst = (r + k) % n
        src = (r - k) % n
        sreq = comm.isend(dst, b"", tag=_T + rnd)
        comm.recv(src, tag=_T + rnd)
        sreq.wait()
        k <<= 1
        rnd += 1


def bcast(comm: Communicator, arr: np.ndarray | None, root: int = 0
          ) -> np.ndarray:
    """Binomial tree broadcast. Non-root ranks pass arr=None or a buffer of
    the right shape/dtype; shape/dtype metadata travels with the data."""
    n, r = comm.size, comm.rank
    vr = (r - root) % n          # virtual rank
    if vr == 0:
        payload = _pack(arr)
    else:
        # receive from parent: highest set bit of vr
        k = 1
        while k * 2 <= vr:
            k *= 2
        parent = (vr - k + root) % n
        data, _ = comm.recv(parent, tag=_T + 16)
        payload = data
    # forward to children: vr + k for every k = 2^j > vr, within range
    k = 1
    while k < n:
        if vr < k and vr + k < n:
            comm.send((vr + k + root) % n, payload, tag=_T + 16)
        k *= 2
    return _unpack(payload)


def reduce(comm: Communicator, arr: np.ndarray, op=np.add, root: int = 0
           ) -> np.ndarray | None:
    n, r = comm.size, comm.rank
    vr = (r - root) % n
    acc = arr.copy()
    k = 1
    while k < n:
        if vr % (2 * k) == 0:
            src_vr = vr + k
            if src_vr < n:
                other = comm.recv_array((src_vr + root) % n, arr.shape,
                                        arr.dtype, tag=_T + 32)
                acc = op(acc, other)
        elif vr % (2 * k) == k:
            comm.send_array((vr - k + root) % n, acc, tag=_T + 32)
            return None if r != root else acc
        k *= 2
    return acc if r == root else None


def allreduce_rd(comm: Communicator, arr: np.ndarray, op=np.add
                 ) -> np.ndarray:
    """Recursive doubling (pow2 sizes) — paper's cited algorithm [5]."""
    n, r = comm.size, comm.rank
    assert _is_pow2(n), "recursive doubling needs power-of-two size"
    acc = np.ascontiguousarray(arr).copy()
    other = np.empty_like(acc)
    k = 1
    rnd = 0
    while k < n:
        peer = r ^ k
        sreq = comm.isend(peer, acc, tag=_T + 64 + rnd)
        comm.recv_into(peer, other, tag=_T + 64 + rnd)
        sreq.wait()
        acc = op(acc, other)     # new array: in-flight views stay valid
        k <<= 1
        rnd += 1
    return acc


def reduce_scatter_ring(comm: Communicator, arr: np.ndarray, op=np.add
                        ) -> np.ndarray:
    """Ring reduce-scatter; returns this rank's reduced shard (flat)."""
    n, r = comm.size, comm.rank
    flat = np.ascontiguousarray(arr).reshape(-1)
    pad = (-len(flat)) % n
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, flat.dtype)])
    shards = np.split(flat.copy(), n)
    inc = np.empty(len(flat) // n, flat.dtype)
    right, left = (r + 1) % n, (r - 1) % n
    for step in range(n - 1):
        send_idx = (r - step) % n
        recv_idx = (r - step - 1) % n
        sreq = comm.isend(right, shards[send_idx], tag=_T + 128 + step)
        comm.recv_into(left, inc, tag=_T + 128 + step)
        sreq.wait()
        shards[recv_idx] = op(shards[recv_idx], inc)
    return shards[(r + 1) % n]


def allgather_ring(comm: Communicator, shard: np.ndarray) -> np.ndarray:
    n, r = comm.size, comm.rank
    shard = np.ascontiguousarray(shard)
    shards = [np.empty(shard.shape, shard.dtype) for _ in range(n)]
    shards[r][...] = shard
    right, left = (r + 1) % n, (r - 1) % n
    for step in range(n - 1):
        send_idx = (r - step) % n
        recv_idx = (r - step - 1) % n
        sreq = comm.isend(right, shards[send_idx], tag=_T + 256 + step)
        comm.recv_into(left, shards[recv_idx], tag=_T + 256 + step)
        sreq.wait()
    return np.concatenate([s.reshape(-1) for s in shards])


def allgather_bruck(comm: Communicator, shard: np.ndarray) -> np.ndarray:
    """Bruck all-gather — paper's cited algorithm [20]; ceil(log2 n) rounds."""
    n, r = comm.size, comm.rank
    shard = np.ascontiguousarray(shard)
    per = shard.size
    blocks = [shard]
    k = 1
    rnd = 0
    while k < n:
        dst = (r - k) % n
        src = (r + k) % n
        count = min(k, n - k)
        # the block gather is the algorithm's packing step, done once as
        # an ndarray concat; the wire exchange itself is view-based
        payload = np.concatenate([b.reshape(-1) for b in blocks[:count]])
        got = np.empty(count * per, shard.dtype)
        sreq = comm.isend(dst, payload, tag=_T + 512 + rnd)
        comm.recv_into(src, got, tag=_T + 512 + rnd)
        sreq.wait()
        for i in range(count):
            blocks.append(got[i * per:(i + 1) * per].reshape(shard.shape))
        k <<= 1
        rnd += 1
    blocks = blocks[:n]
    # blocks[i] is rank (r+i) % n's shard — rotate into rank order
    ordered = [blocks[(i - r) % n] for i in range(n)]
    return np.concatenate([b.reshape(-1) for b in ordered])


def allreduce(comm: Communicator, arr: np.ndarray, op=np.add,
              algo: str = "auto") -> np.ndarray:
    n = comm.size
    if n == 1:
        return arr.copy()
    if algo == "auto":
        algo = "rd" if (_is_pow2(n) and arr.size < 4096) else "ring"
    if algo == "rd":
        return allreduce_rd(comm, arr, op)
    shard = reduce_scatter_ring(comm, arr, op)
    flat = shards_to_chunk_order(allgather_ring(comm, shard), n)
    return flat[:arr.size].reshape(arr.shape).astype(arr.dtype)


def alltoall(comm: Communicator, blocks: list[np.ndarray]
             ) -> list[np.ndarray]:
    """blocks[i] goes to rank i; returns what each rank sent to us."""
    n, r = comm.size, comm.rank
    assert len(blocks) == n
    out: list[np.ndarray | None] = [None] * n
    out[r] = blocks[r].copy()
    reqs = []
    for off in range(1, n):
        dst = (r + off) % n
        reqs.append(comm.isend(dst, np.ascontiguousarray(blocks[dst]),
                               tag=_T + 1024 + off))
    for off in range(1, n):
        src = (r - off) % n
        out[src] = np.empty(blocks[src].shape, blocks[src].dtype)
        comm.recv_into(src, out[src], tag=_T + 1024 + off)
    comm.waitall(reqs)
    return out


def _pack(arr: np.ndarray) -> bytes:
    meta = (str(arr.dtype).encode() + b"|"
            + ",".join(map(str, arr.shape)).encode() + b"|")
    return len(meta).to_bytes(4, "little") + meta + arr.tobytes()


def _unpack(data: bytes) -> np.ndarray:
    mlen = int.from_bytes(data[:4], "little")
    meta = data[4:4 + mlen].split(b"|")
    dtype = np.dtype(meta[0].decode())
    shape = tuple(int(x) for x in meta[1].decode().split(",") if x)
    return np.frombuffer(data[4 + mlen:], dtype=dtype).reshape(shape).copy()
