"""Collective ALGORITHMS built on cMPI point-to-point (paper §3.6).

The paper leaves collectives as future work but notes they decompose into
pt2pt via standard algorithms (recursive doubling [5], Bruck [20]). Since
the schedule-DAG subsystem (``repro.core.sched`` + ``repro.core.progress``)
landed, the algorithms live in ONE place — the schedule compilers — and
this module is the launch layer: it binds a compiled schedule to a buffer
backend, hands the execution to the communicator's shared progress
engine, and returns a ``CollRequest``. The deprecated free-function
surface (``bcast(comm, arr)``-style) is a set of blocking wrappers over
the same launches with the plain-heap backend; the ``Comm`` method
collectives (core/comm.py) call the identical ``icoll_*`` launchers with
the pool-resident backend when the pool supports it. Backends are
wire-compatible round for round (same tags, sizes, order), so ranks may
disagree on backend choice within one collective and still interoperate.

NOTE (Comm API v2): the free-function surface here is DEPRECATED as a
public API — use the method collectives on ``repro.core.Comm``
(``comm.bcast(arr)``, ``comm.allreduce(...)``, ...) and their
non-blocking forms (``comm.iallreduce(...)`` returning a request).
Importing the free functions via ``repro.core`` emits a
``DeprecationWarning`` while continuing to work.

Algorithms (n = comm size, numpy arrays):
  barrier         dissemination (log n rounds of pairwise messages)
  bcast           binomial tree
  reduce          binomial tree (op applied bottom-up)
  allreduce       recursive doubling (pow2) | fused ring RS+AG (any n)
  allgather       Bruck | ring
  reduce_scatter  ring
  alltoall        pairwise exchange
"""
from __future__ import annotations

import numpy as np

from repro.core.progress import (CollRequest, _HeapBufs, _ResidentBufs,
                                 _SchedExec)
from repro.core.pt2pt import Communicator
from repro.core.sched import Schedule, SendOp, compile_schedule

_T = 0x7F000000   # legacy tag space (alltoall pairwise lanes)
_META_BYTES = 192  # fixed-size dtype/shape descriptor for bcast


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def auto_allreduce_algo(n: int, nelem: int) -> str:
    """The ONE rd-vs-ring cutoff, shared by every allreduce surface
    (blocking, nonblocking, persistent, deprecated free function):
    recursive doubling ships the full payload log2(n) times, so it only
    wins for small payloads on power-of-two sizes."""
    return "rd" if (_is_pow2(n) and nelem < 4096) else "ring"


def auto_chunk_bytes(comm, nbytes: int) -> int | None:
    """The ``chunk_bytes="auto"`` policy. Two forces bound the chunk:

    * FLOOR — 8x the probed eager/posted crossover (64 KiB minimum):
      every sub-message must sit well inside one-copy rendezvous
      territory, where the descriptor + matchbox round-trip amortizes
      (measured: 128 KiB chunks at 8 MiB run as slow as unchunked —
      per-message overhead eats the pipeline).
    * DEPTH CAP — nbytes/8: at most ~8 chunks per payload. Pipelining
      saturates at a handful of in-flight chunks; beyond that, extra
      sub-messages only add posting/claim traffic.

    Payloads under two chunks have nothing to pipeline — None keeps
    them message-granular.

    A TUNED comm (``Comm(tuning="auto")`` with a fresh machine profile)
    replaces the fixed nbytes/8 rule with the measured bandwidth knee:
    the chunk is the rank-agreed ``chunk_floor`` — half the largest
    working set that still runs at peak copy bandwidth (two operands
    stream through a reduce round), floored at 8x the measured
    crossover — so every sub-message stays inside the fast cache tier
    regardless of payload size, instead of scaling with it.

    The probe basis must be RANK-AGREED: chunk counts become sub-round
    wire tags, and per-rank probes (``eager_threshold="auto"``) may
    measure different crossovers. ``Comm`` exposes the agreed maximum
    (``_chunk_probe_base``, a one-time collective; tuned comms agree
    once at init); bare communicators fall back to the local value
    (their thresholds are constructor arguments, identical on every
    rank by construction)."""
    if nbytes <= 2 * 64 * 1024:
        # the 64 KiB floor alone forces None here — decide before the
        # (blocking, collective) probe agreement below, which would
        # stall a nonblocking call for a provably-None answer. Exact
        # and rank-uniform: nbytes agrees across ranks by MPI contract.
        return None
    tuned = getattr(comm, "_tuned", None)
    if tuned is not None:
        cb = int(tuned["chunk_floor"])
        if cb <= 0:          # measured sweep: unchunked won everywhere
            return None
        return cb if nbytes > 2 * cb else None
    agree = getattr(comm, "_chunk_probe_base", None)
    if agree is not None:
        base = agree()
    else:
        base = (getattr(comm, "probed_crossover", None)
                or comm.eager_threshold)
    cb = max(64 * 1024, 8 * int(base), nbytes // 8)
    return cb if nbytes > 2 * cb else None


def _resolve_chunk(comm, chunk_bytes, nbytes: int) -> int | None:
    return (auto_chunk_bytes(comm, nbytes) if chunk_bytes == "auto"
            else chunk_bytes)


def bruck_to_rank_order(work: np.ndarray, rank: int, n: int
                        ) -> np.ndarray:
    """Bruck allgather accumulates blocks contiguously in BRUCK order
    (own block first, then +k neighbours): rotate ``work`` (n rows, one
    per block) back to rank order. Shared by the one-shot launcher and
    the persistent init — one definition of the block layout."""
    out = np.empty_like(work)
    for i in range(n):
        out[(rank + i) % n] = work[i]
    return out.reshape(-1)


def shards_to_chunk_order(flat: np.ndarray, n: int) -> np.ndarray:
    """After a ring reduce-scatter + allgather, rank i's reduced shard is
    CHUNK (i+1) % n of the padded payload — reorder the allgathered flat
    vector from rank order into chunk order. (The FUSED ring and fused
    hierarchical allreduce schedules receive chunks in place and never
    need this; it remains a utility for hand-rolled RS+AG
    compositions.)"""
    per = flat.size // n
    parts = [flat[i * per:(i + 1) * per] for i in range(n)]
    return np.concatenate([parts[(c - 1) % n] for c in range(n)])


# --------------------------------------------------------------------------
# launch layer: bind a compiled schedule to buffers, hand it to the engine
# --------------------------------------------------------------------------

def _make_bufs(comm: Communicator, sched: Schedule, resident: bool):
    """Pool-resident round buffers (leased from the communicator's round
    pool — ``Comm`` provides ``_lease_round_bufs``) or plain heap."""
    if resident:
        bufs, release = comm._lease_round_bufs(sched.slot_sizes)
        return _ResidentBufs(bufs, release)
    return _HeapBufs(sched.slot_sizes)


def _launch(comm: Communicator, sched: Schedule, bufs, dtype, op,
            finalize, *, win=None, win_disp: int = 0,
            rma_path: str = "rma_coll") -> CollRequest:
    """Bind a compiled schedule to its buffers and hand it to the shared
    progress engine. ``win`` attaches an RMA window for schedules with
    Put/Get nodes (the one-sided collectives launched from
    ``repro.core.rma``); their payload bytes land in the ``rma_path``
    ``ProtocolStats`` bucket."""
    ex = _SchedExec(comm, sched, bufs, comm._alloc_coll_tags(),
                    dtype=dtype, op=op, finalize=finalize, win=win,
                    win_disp=win_disp, rma_path=rma_path)
    comm._engine.add_coll(ex)
    return CollRequest(comm, ex)


def immediate(comm: Communicator, result) -> CollRequest:
    """A pre-completed CollRequest (size-1 communicators)."""
    ex = _SchedExec(comm, Schedule("noop", comm.size, comm.rank),
                    _HeapBufs({}), 0, finalize=lambda b: result)
    return CollRequest(comm, ex)


def icoll_allreduce(comm: Communicator, arr: np.ndarray, op=np.add,
                    algo: str = "ring", resident: bool = False,
                    chunk_bytes=None) -> CollRequest:
    arr = np.ascontiguousarray(arr)
    if comm.size == 1:
        return immediate(comm, arr.copy())
    cb = _resolve_chunk(comm, chunk_bytes, arr.nbytes)
    shape, dtype, count = arr.shape, arr.dtype, arr.size
    if algo == "rd":
        sched = compile_schedule(comm, "allreduce_rd", arr.nbytes,
                                 arr.dtype.itemsize, chunk_bytes=cb)
        fin = (lambda b: np.array(b.ndview(sched.result, dtype))
               .reshape(shape))
    else:
        sched = compile_schedule(comm, "allreduce_ring", arr.nbytes,
                                 arr.dtype.itemsize, chunk_bytes=cb)
        # fused RS+AG: slot 0 finishes in CHUNK order — truncate the
        # zero padding and reshape, no reorder pass
        fin = (lambda b: np.array(b.ndview(sched.result, dtype)[:count])
               .reshape(shape))
    bufs = _make_bufs(comm, sched, resident)
    bufs.fill(0, arr, pad_to=sched.slot_sizes[0])
    return _launch(comm, sched, bufs, dtype, op, fin)


def icoll_allreduce_hier(comm: Communicator, arr: np.ndarray, op=np.add,
                         group: int = 2, resident: bool = False,
                         chunk_bytes=None) -> CollRequest:
    """Nonblocking hierarchical allreduce: ONE fused schedule (intra
    ring RS -> inter recursive doubling -> intra ring AG) over the
    parent communicator — no sub-communicators, no phase barriers."""
    arr = np.ascontiguousarray(arr)
    if comm.size == 1:
        return immediate(comm, arr.copy())
    cb = _resolve_chunk(comm, chunk_bytes, arr.nbytes)
    shape, dtype, count = arr.shape, arr.dtype, arr.size
    sched = compile_schedule(comm, "allreduce_hier", arr.nbytes,
                             arr.dtype.itemsize, group=group,
                             chunk_bytes=cb)
    fin = (lambda b: np.array(b.ndview(sched.result, dtype)[:count])
           .reshape(shape))
    bufs = _make_bufs(comm, sched, resident)
    bufs.fill(0, arr, pad_to=sched.slot_sizes[0])
    return _launch(comm, sched, bufs, dtype, op, fin)


def icoll_reduce_scatter(comm: Communicator, arr: np.ndarray, op=np.add,
                         resident: bool = False,
                         chunk_bytes=None) -> CollRequest:
    arr = np.ascontiguousarray(arr)
    if comm.size == 1:
        return immediate(comm, arr.reshape(-1).copy())
    dtype = arr.dtype
    sched = compile_schedule(comm, "reduce_scatter_ring", arr.nbytes,
                             arr.dtype.itemsize,
                             chunk_bytes=_resolve_chunk(
                                 comm, chunk_bytes, arr.nbytes))
    bufs = _make_bufs(comm, sched, resident)
    bufs.fill(0, arr, pad_to=sched.slot_sizes[0])
    fin = lambda b: np.array(b.ndview(sched.result, dtype))  # noqa: E731
    return _launch(comm, sched, bufs, dtype, op, fin)


def icoll_allgather(comm: Communicator, shard: np.ndarray,
                    algo: str = "ring", resident: bool = False,
                    chunk_bytes=None) -> CollRequest:
    shard = np.ascontiguousarray(shard)
    n, rank = comm.size, comm.rank
    if n == 1:
        return immediate(comm, shard.reshape(-1).copy())
    dtype, per_b = shard.dtype, shard.nbytes
    kind = "allgather_bruck" if algo == "bruck" else "allgather_ring"
    sched = compile_schedule(comm, kind, per_b, shard.dtype.itemsize,
                             chunk_bytes=_resolve_chunk(
                                 comm, chunk_bytes, per_b))
    bufs = _make_bufs(comm, sched, resident)
    # own shard: bruck block 0, ring chunk `rank`
    bufs.fill_at(0, 0 if algo == "bruck" else rank * per_b, shard)
    if algo == "bruck":
        per = shard.size

        def fin(b):
            work = np.array(b.ndview(sched.result, dtype)).reshape(n, per)
            return bruck_to_rank_order(work, rank, n)
    else:
        fin = lambda b: np.array(b.ndview(sched.result, dtype))  # noqa: E731
    return _launch(comm, sched, bufs, dtype, None, fin)


def icoll_bcast_known(comm: Communicator, arr: np.ndarray, root: int = 0,
                      resident: bool = False,
                      chunk_bytes=None) -> CollRequest:
    """ibcast with the payload buffer KNOWN on every rank (MPI
    semantics: same shape/dtype everywhere; non-root buffers are
    overwritten in place). The heap backend aliases slot 0 to the user
    array — leaves receive straight into it with no round-buffer
    detour; the resident backend lands the payload once in a round
    buffer and forwards zero-copy PoolViews."""
    if not (isinstance(arr, np.ndarray) and arr.flags.c_contiguous):
        # ascontiguousarray would silently detach a COPY — the caller's
        # buffer would never see the broadcast, violating the in-place
        # contract
        raise ValueError("ibcast needs a C-contiguous ndarray "
                         "(the payload is delivered in place)")
    if comm.size == 1:
        return immediate(comm, arr)
    # a chunked bcast PIPELINES the binomial tree: an interior rank
    # forwards chunk c to its children the moment chunk c lands
    sched = compile_schedule(comm, "bcast", arr.nbytes,
                             arr.dtype.itemsize, root=root,
                             chunk_bytes=_resolve_chunk(
                                 comm, chunk_bytes, arr.nbytes))
    # a leaf (no forwarding sends) gains nothing from a round buffer —
    # it would just pay an extra pool -> user drain
    resident = resident and any(isinstance(nd, SendOp)
                                for nd in sched.nodes)
    is_root = comm.rank == root
    if resident:
        bufs = _make_bufs(comm, sched, True)
        if is_root:
            bufs.fill(0, arr)
        u8 = arr.reshape(-1).view(np.uint8)

        def fin(b):
            if not is_root:
                u8[:] = b.ndview(sched.result, np.uint8)
            return arr
    else:
        bufs = _HeapBufs({})             # slot 0 IS the user array
        bufs.alias(0, arr)
        fin = lambda b: arr              # noqa: E731
    return _launch(comm, sched, bufs, arr.dtype, None, fin)


def icoll_reduce(comm: Communicator, arr: np.ndarray, op=np.add,
                 root: int = 0, resident: bool = False) -> CollRequest:
    arr = np.ascontiguousarray(arr)
    if comm.size == 1:
        return immediate(comm, arr.copy())
    shape, dtype = arr.shape, arr.dtype
    sched = compile_schedule(comm, "reduce", arr.nbytes,
                             arr.dtype.itemsize, root=root)
    bufs = _make_bufs(comm, sched, resident)
    bufs.fill(0, arr)
    if comm.rank == root:
        fin = (lambda b: np.array(b.ndview(sched.result, dtype))
               .reshape(shape))
    else:
        fin = lambda b: None             # noqa: E731
    return _launch(comm, sched, bufs, dtype, op, fin)


def icoll_barrier(comm: Communicator) -> CollRequest:
    if comm.size == 1:
        return immediate(comm, None)
    sched = compile_schedule(comm, "barrier")
    return _launch(comm, sched, _HeapBufs(sched.slot_sizes), None, None,
                   lambda b: None)


# --------------------------------------------------------------------------
# bcast metadata phase (dtype/shape travel ahead of the payload)
# --------------------------------------------------------------------------

def _bcast_impl(comm: Communicator, arr: np.ndarray | None, root: int,
                use_resident=None) -> np.ndarray:
    """Blocking bcast where only the root knows shape/dtype: a
    fixed-size metadata bcast (eager, one cell) announces them, then the
    payload rides ``icoll_bcast_known``. ``use_resident``: optional
    ``nbytes -> bool`` predicate evaluated per rank once the payload
    size is known (each rank picks its own path — the wire protocol is
    self-describing per message)."""
    if comm.size == 1:
        return np.asarray(arr).copy()
    meta = np.zeros(_META_BYTES, np.uint8)
    if comm.rank == root:
        a = np.ascontiguousarray(arr)
        # ';' separator: dtype.str itself may contain '|' (e.g. "|u1")
        desc = (f"{a.dtype.str};"
                f"{','.join(map(str, a.shape))}").encode()
        if len(desc) > _META_BYTES:
            raise ValueError(f"bcast metadata over {_META_BYTES}B "
                             f"(shape rank too large)")
        meta[:len(desc)] = np.frombuffer(desc, np.uint8)
    icoll_bcast_known(comm, meta, root).wait()
    if comm.rank == root:
        out = a
    else:
        dts, shs = bytes(meta).rstrip(b"\0").decode().split(";")
        shape = tuple(int(x) for x in shs.split(",") if x)
        out = np.empty(shape, np.dtype(dts))
    resident = bool(use_resident(out.nbytes)) if use_resident else False
    icoll_bcast_known(comm, out, root, resident=resident).wait()
    return np.array(out) if comm.rank == root else out


# --------------------------------------------------------------------------
# deprecated free-function surface (blocking wrappers, heap backend)
# --------------------------------------------------------------------------

def barrier_dissemination(comm: Communicator) -> None:
    icoll_barrier(comm).wait()


def bcast(comm: Communicator, arr: np.ndarray | None, root: int = 0
          ) -> np.ndarray:
    """Binomial tree broadcast. Non-root ranks pass arr=None or a buffer of
    the right shape/dtype; shape/dtype metadata travels with the data."""
    return _bcast_impl(comm, arr, root)


def reduce(comm: Communicator, arr: np.ndarray, op=np.add, root: int = 0
           ) -> np.ndarray | None:
    return icoll_reduce(comm, arr, op, root).wait()


def allreduce_rd(comm: Communicator, arr: np.ndarray, op=np.add
                 ) -> np.ndarray:
    """Recursive doubling (pow2 sizes) — paper's cited algorithm [5]."""
    assert _is_pow2(comm.size), \
        "recursive doubling needs power-of-two size"
    return icoll_allreduce(comm, arr, op, algo="rd").wait()


def reduce_scatter_ring(comm: Communicator, arr: np.ndarray, op=np.add
                        ) -> np.ndarray:
    """Ring reduce-scatter; returns this rank's reduced shard (flat)."""
    return icoll_reduce_scatter(comm, arr, op).wait()


def allgather_ring(comm: Communicator, shard: np.ndarray) -> np.ndarray:
    return icoll_allgather(comm, shard, algo="ring").wait()


def allgather_bruck(comm: Communicator, shard: np.ndarray) -> np.ndarray:
    """Bruck all-gather — paper's cited algorithm [20]; ceil(log2 n)
    rounds."""
    return icoll_allgather(comm, shard, algo="bruck").wait()


def allreduce(comm: Communicator, arr: np.ndarray, op=np.add,
              algo: str = "auto") -> np.ndarray:
    n = comm.size
    if n == 1:
        return arr.copy()
    if algo == "auto":
        algo = auto_allreduce_algo(n, arr.size)
    return icoll_allreduce(comm, arr, op, algo=algo).wait()


def alltoall(comm: Communicator, blocks: list[np.ndarray]
             ) -> list[np.ndarray]:
    """blocks[i] goes to rank i; returns what each rank sent to us."""
    n, r = comm.size, comm.rank
    assert len(blocks) == n
    out: list[np.ndarray | None] = [None] * n
    out[r] = blocks[r].copy()
    reqs = []
    for off in range(1, n):
        dst = (r + off) % n
        reqs.append(comm.isend(dst, np.ascontiguousarray(blocks[dst]),
                               tag=_T + 1024 + off, _internal=True))
    for off in range(1, n):
        src = (r - off) % n
        out[src] = np.empty(blocks[src].shape, blocks[src].dtype)
        comm.recv_into(src, out[src], tag=_T + 1024 + off,
                       _internal=True)
    comm.waitall(reqs)
    return out
