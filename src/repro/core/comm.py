"""Comm: the cMPI v2 user-facing communicator facade.

The paper presents cMPI as an MPI library; this module is that library's
public surface. ``Comm`` subclasses the pt2pt engine (``Communicator``)
and adds everything an MPI application expects from a first-class
communicator object:

* **Method collectives** — ``comm.bcast / reduce / allreduce / allgather
  / reduce_scatter / alltoall / barrier``. Large payloads are routed
  through a per-comm pool of persistent pool-resident ROUND BUFFERS
  (``_RoundPool``): every ring/Bruck round sends a ``PoolView`` slice of
  a resident buffer, so exchanges ride the zero-sender-copy rendezvous
  path instead of re-staging into a fresh arena object each round (the
  foMPI lesson: route bulk transfers through window/pool-resident
  memory). On pools without raw views (incoherent mode) the methods fall
  back to the protocol-correct view-based algorithms in
  ``core/collectives``.

* **Sub-communicators** — ``comm.split(color, key)`` and ``comm.dup()``
  derive new communicators over the SAME arena with namespaced queue
  matrices and remapped ranks (``sub.parent_ranks`` maps sub-rank ->
  parent rank). Tag spaces are disjoint by construction: each derived
  comm owns its own SPSC queue matrix. This enables the hierarchical
  allreduce (``algo="hier"``): intra-group ring reduce-scatter,
  inter-group recursive doubling on the shards, intra-group ring
  allgather — selected automatically for large payloads on composite
  communicator sizes.

* **Persistent requests** (MPI-4 style) — ``comm.send_init`` /
  ``comm.recv_init`` return a ``PersistentRequest`` whose
  ``start()/wait()`` pair can be reused across iterations. The wire plan
  (eager vs staged vs pool-resident) is decided ONCE at init; a staged
  persistent send allocates its staging object once and reuses it every
  ``start()`` — no arena create/destroy churn in steady state.

* **Auto-tuned eager threshold** — ``eager_threshold="auto"`` runs a
  one-shot micro-probe at init measuring the eager cell path against the
  rendezvous staging path on this host and records the measured
  crossover (``comm.probed_crossover``).

The pre-v2 surface (free-function collectives, the ``Communicator``
name) remains importable from ``repro.core`` as deprecation shims.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core import collectives as _coll
from repro.core.arena import Arena, _hash_name
from repro.core.collectives import _is_pow2, shards_to_chunk_order
from repro.core.pool import Registration, as_u8
from repro.core.pt2pt import (ANY_TAG, DEFAULT_MB_SLOTS, Communicator,
                              PoolBuffer, PoolView, Request, _RNDV_CTRL)
from repro.core.ringqueue import DEFAULT_CELL_SIZE

_T = 0x7F000000          # collectives tag space (shared with collectives.py)
_NAME_BUDGET = 24        # derived comm names are hashed beyond this length


def _derived_name(parent: str, suffix: str) -> str:
    """Deterministic (rank-independent) name for a derived communicator,
    kept short enough that pb:/rv: object names stay under NAME_MAX."""
    name = f"{parent}.{suffix}"
    if len(name) > _NAME_BUDGET:
        name = f"c{_hash_name(name.encode(), 0):016x}"
    return name


def _best_group(n: int) -> int:
    """Largest divisor of n no larger than sqrt(n) (1 if n is prime)."""
    g = 1
    d = 2
    while d * d <= n:
        if n % d == 0:
            g = d
        d += 1
    return g


class _RoundPool:
    """Per-comm pool of persistent pool-resident round buffers.

    Collectives index buffers by role (0 = working buffer, 1 = incoming
    block, 2.. = per-peer alltoall lanes). Capacity grows to the
    high-water mark (rounded to a power of two) and is then REUSED across
    rounds and across collective calls — steady-state iterative workloads
    do zero arena create/destroy work.
    """

    def __init__(self, comm: "Comm"):
        self._comm = comm
        self._bufs: dict[int, PoolBuffer] = {}

    def buf(self, idx: int, nbytes: int) -> PoolBuffer:
        pb = self._bufs.get(idx)
        if pb is None or pb.nbytes < nbytes:
            if pb is not None:
                pb.free()
            cap = 1 << max(6, (max(nbytes, 1) - 1).bit_length())
            pb = self._comm.alloc_buffer(cap)
            self._bufs[idx] = pb
        return pb

    def array(self, idx: int, shape, dtype) -> tuple[PoolBuffer, np.ndarray]:
        """A numpy array aliasing pool memory (coherent pools only) plus
        its backing buffer — fills and op-applications write straight
        into pool-resident memory, so sends need no staging copy."""
        shape = tuple(shape)
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        pb = self.buf(idx, nbytes)
        arr = np.frombuffer(pb.view()[:nbytes], dtype=dtype).reshape(shape)
        return pb, arr

    def free_all(self) -> None:
        for pb in self._bufs.values():
            try:
                pb.free()
            except FileNotFoundError:
                pass
        self._bufs.clear()


class PersistentRequest:
    """MPI-4-style persistent communication request.

    Created by ``Comm.send_init`` / ``Comm.recv_init``; ``start()``
    launches one operation over the pre-planned wire layout, ``wait()``
    (or ``test()``) completes it, and the pair may be repeated any number
    of times. The buffer handed to ``*_init`` is captured as a live view:
    mutate it between iterations, never replace it.

    Send plans, fixed at init time:
      eager   payload <= eager_threshold: chunk through queue cells
      staged  payload  > threshold: ONE persistent pool staging buffer,
              refilled (one counted copy) and re-sent each start() — the
              per-iteration arena create/destroy of a plain ``isend`` is
              gone, so the arena slot count stays constant across
              iterations
      pool    a PoolBuffer/PoolView source: zero sender-side copies
    """

    def __init__(self, comm: "Comm", kind: str, peer: int, buf,
                 tag: int):
        self._comm = comm
        self.kind = kind
        self.peer = peer
        self.tag = tag
        self.started = 0
        self._active: Optional[Request] = None
        self._stager: Optional[PoolBuffer] = None
        self._reg: Optional[Registration] = None
        if kind == "send":
            if isinstance(buf, (PoolBuffer, PoolView)):
                self._mode = "pool"
                self._payload = buf
                self._mv = None
            else:
                self._mv = as_u8(buf)
                if len(self._mv) > comm.eager_threshold:
                    self._mode = "staged"
                    self._stager = comm.alloc_buffer(len(self._mv))
                else:
                    self._mode = "eager"
        else:
            if isinstance(buf, (PoolBuffer, PoolView, Registration)):
                # pool-addressable destination: every start() re-arms a
                # matchbox entry pointing straight at it
                self._dest = buf
                self._mv = None
            else:
                self._mv = as_u8(buf)
                if self._mv.readonly:
                    raise ValueError("recv_init needs a writable buffer")
                if len(self._mv) > comm.eager_threshold \
                        and comm._mb is not None:
                    # pre-post pinning: register the user buffer ONCE so
                    # each start() re-arms the same shadow-backed entry —
                    # flat arena footprint, one receiver-side copy
                    # (shadow -> user) per iteration
                    self._reg = comm.register(self._mv)
                    self._dest = self._reg
                else:
                    self._dest = self._mv
            self._mode = "recv"

    @property
    def active(self) -> bool:
        return self._active is not None and not self._active.done

    def start(self) -> "PersistentRequest":
        if self.active:
            raise RuntimeError(
                "persistent request already active; wait() before "
                "restarting")
        if self.kind == "send":
            if self._mode == "pool":
                self._active = self._comm.isend(self.peer, self._payload,
                                                self.tag)
            elif self._mode == "staged":
                # claim-aware persistent plan: a matchbox hit writes the
                # user buffer straight into the receiver's posted
                # destination (one copy, stager untouched); a miss
                # refills the persistent stager in place — either way,
                # no arena churn per iteration
                self._active = self._comm.isend(
                    self.peer, self._mv, self.tag,
                    _prestaged=self._stager)
            else:
                self._active = self._comm.isend(self.peer, self._mv,
                                                self.tag)
        else:
            self._active = self._comm.irecv_into(self.peer, self._dest,
                                                 self.tag)
        self.started += 1
        return self

    def test(self) -> bool:
        if self._active is None:
            raise RuntimeError("persistent request not started")
        return self._active.test()

    def wait(self, timeout: float | None = 30.0) -> int:
        if self._active is None:
            raise RuntimeError("persistent request not started")
        self._active.wait(timeout)
        return self._active.nbytes

    def free(self) -> None:
        if self.active:
            raise RuntimeError("cannot free an active persistent request")
        if self._stager is not None:
            self._stager.free()
            self._stager = None
        if self._reg is not None:
            self._reg.free()
            self._reg = None


def startall(reqs: list[PersistentRequest]) -> list[PersistentRequest]:
    """MPI_Startall: start every persistent request in order."""
    for r in reqs:
        r.start()
    return reqs


class Comm(Communicator):
    """First-class cMPI communicator (the v2 public API)."""

    def __init__(self, arena: Arena, rank: int, size: int, *,
                 cell_size: int = DEFAULT_CELL_SIZE, n_cells: int = 8,
                 eager_threshold: int | str | None = None,
                 mb_slots: int = DEFAULT_MB_SLOTS,
                 name: str = "world", open_timeout: float = 30.0):
        auto = eager_threshold == "auto"
        super().__init__(arena, rank, size, cell_size=cell_size,
                         n_cells=n_cells,
                         eager_threshold=None if auto else eager_threshold,
                         mb_slots=mb_slots,
                         name=name, open_timeout=open_timeout)
        self._derived_seq = 0
        self._hier_cache: dict[int, tuple["Comm", "Comm"]] = {}
        self._rounds = _RoundPool(self)
        self._resident_ok: Optional[bool] = None
        # sub-rank -> parent-comm rank (identity for a root communicator)
        self.parent_ranks: tuple[int, ...] = tuple(range(size))
        self.probed_crossover: Optional[int] = None
        if auto:
            self.eager_threshold = self._probe_eager_threshold()

    # ------------------------------------------------------------------
    # auto-tuned eager threshold (one-shot micro-probe)
    # ------------------------------------------------------------------
    def _probe_eager_threshold(self, reps: int = 3) -> int:
        """Measure the eager (per-cell chunk copies) vs rendezvous
        (arena create + one stage + one bulk read + destroy) cost locally
        and return the crossover: the largest probed size at which eager
        still wins. Per-rank and one-shot; thresholds may legitimately
        differ across ranks (the protocol is self-describing per
        message, so asymmetric thresholds are safe)."""
        v = self.arena.view
        cell = self.cell_size
        sizes = [max(64, cell // 4), cell, 2 * cell, 4 * cell, 8 * cell]
        scratch = memoryview(bytearray(sizes[-1]))
        h = self.arena.create(f"prb:{self.name}:{self.rank}",
                              _RNDV_CTRL + sizes[-1])

        def eager_cost(s: int) -> float:
            t0 = time.perf_counter()
            for _ in range(reps):
                for off in range(0, s, cell):
                    chunk = scratch[off:off + min(cell, s - off)]
                    v.write_release(h.offset + _RNDV_CTRL, chunk)
                    v.read_acquire_into(h.offset + _RNDV_CTRL, chunk)
            return (time.perf_counter() - t0) / reps

        def rndv_cost(s: int) -> float:
            t0 = time.perf_counter()
            for i in range(reps):
                hh = self.arena.create(
                    f"prv:{self.name}:{self.rank}:{i}", _RNDV_CTRL + s)
                v.write_release(hh.offset + _RNDV_CTRL, scratch[:s])
                v.read_acquire_into(hh.offset + _RNDV_CTRL, scratch[:s])
                self.arena.destroy(hh)
            return (time.perf_counter() - t0) / reps

        try:
            eager_cost(sizes[0])                 # warm the path once
            rndv_cost(sizes[0])
            threshold = sizes[-1]                # eager everywhere probed
            for i, s in enumerate(sizes):
                if rndv_cost(s) <= eager_cost(s):
                    self.probed_crossover = s
                    threshold = sizes[i - 1] if i else max(64, s // 2)
                    break
        finally:
            self.arena.destroy(h)
        return threshold

    # ------------------------------------------------------------------
    # sub-communicators
    # ------------------------------------------------------------------
    def split(self, color: int | None, key: int = 0) -> Optional["Comm"]:
        """MPI_Comm_split: collective over this comm. Ranks supplying the
        same ``color`` form a new communicator (ranked by ``(key, parent
        rank)``) over the same arena with its own namespaced queue matrix
        — tag spaces of parent and siblings are disjoint by construction.
        ``color=None`` (MPI_UNDEFINED) participates but receives None."""
        seq = self._derived_seq
        self._derived_seq += 1
        if color is not None and int(color) < 0:
            raise ValueError("color must be a non-negative int or None")
        c = -1 if color is None else int(color)
        mine = np.array([c, int(key), self.rank], np.int64)
        table = _coll.allgather_ring(self, mine).reshape(self.size, 3)
        if color is None:
            return None
        members = sorted((int(k), int(r)) for cc, k, r in table if cc == c)
        ranks = [r for _, r in members]
        sub = Comm(self.arena, ranks.index(self.rank), len(ranks),
                   cell_size=self.cell_size, n_cells=self.n_cells,
                   eager_threshold=self.eager_threshold,
                   mb_slots=self.mb_slots,
                   name=_derived_name(self.name, f"s{seq}.{c}"))
        sub.parent_ranks = tuple(ranks)
        return sub

    def dup(self) -> "Comm":
        """MPI_Comm_dup: a congruent communicator (same group, same rank
        order) with an independent queue matrix, hence a fully disjoint
        tag/message space."""
        seq = self._derived_seq
        self._derived_seq += 1
        sub = Comm(self.arena, self.rank, self.size,
                   cell_size=self.cell_size, n_cells=self.n_cells,
                   eager_threshold=self.eager_threshold,
                   mb_slots=self.mb_slots,
                   name=_derived_name(self.name, f"d{seq}"))
        sub.parent_ranks = self.parent_ranks
        return sub

    def free(self) -> None:
        """Collective MPI_Comm_free: every rank calls it. Frees cached
        hierarchical sub-communicators (each a collective free over its
        own group), releases the persistent round buffers, retracts this
        rank's matchbox postings, fences, and finally destroys the queue
        matrix / barrier / matchbox / publication arena objects (rank 0,
        after the fence — no rank is still draining them). Idempotent on
        every rank; the communicator is unusable afterwards."""
        if self._freed:
            return
        for intra, inter in self._hier_cache.values():
            if intra is not None:
                intra.free()
            if inter is not None:
                inter.free()
        self._hier_cache.clear()
        self._rounds.free_all()
        super().free()

    # ------------------------------------------------------------------
    # persistent requests (MPI-4)
    # ------------------------------------------------------------------
    def send_init(self, dest: int, buf, tag: int = 0) -> PersistentRequest:
        return PersistentRequest(self, "send", dest, buf, tag)

    def recv_init(self, src: int, buf, tag: int = ANY_TAG
                  ) -> PersistentRequest:
        return PersistentRequest(self, "recv", src, buf, tag)

    # ------------------------------------------------------------------
    # pool-resident collective machinery
    # ------------------------------------------------------------------
    @property
    def _resident(self) -> bool:
        """True when round buffers can be aliased as raw numpy views:
        memory-backed pool AND hardware-coherent mode. Otherwise the
        methods fall back to the protocol-correct view-based algorithms."""
        if self._resident_ok is None:
            ok = self.arena.view.mode == "coherent"
            if ok:
                try:
                    self.arena.pool.memview(0, 1)
                except TypeError:
                    ok = False
            self._resident_ok = ok
        return self._resident_ok

    def _use_resident(self, nbytes: int) -> bool:
        # small payloads stay on the eager cell path — a descriptor
        # round-trip per round would cost more than it saves
        return self._resident and self.size > 1 \
            and nbytes > self.eager_threshold

    # ------------------------------------------------------------------
    # method collectives
    # ------------------------------------------------------------------
    def barrier(self) -> None:          # inherited seq-number barrier;
        super().barrier()               # restated here as part of the API

    def bcast(self, arr: np.ndarray | None, root: int = 0) -> np.ndarray:
        """Binomial-tree broadcast; non-root ranks pass ``arr=None``.
        Large payloads land once in a resident round buffer and are
        forwarded to every child with zero sender-side copies."""
        n, r = self.size, self.rank
        if n == 1:
            return np.asarray(arr).copy()
        vr = (r - root) % n
        # each rank picks its own forwarding path (the wire protocol is
        # self-describing per message): resident ranks land the payload
        # in a round buffer once and forward it as zero-copy PoolViews
        if vr == 0:
            a = np.ascontiguousarray(arr)
            resident = self._use_resident(a.nbytes)
            if resident:
                pb, buf = self._rounds.array(0, (a.nbytes,), np.uint8)
                np.copyto(buf, a.reshape(-1).view(np.uint8))
            # ';' separator: dtype.str itself may contain '|' (e.g. "|u1")
            meta = (f"{a.dtype.str};"
                    f"{','.join(map(str, a.shape))}").encode()
            out = a
        else:
            k = 1
            while k * 2 <= vr:
                k *= 2
            parent = (vr - k + root) % n
            meta, _ = self.recv(parent, tag=_T + 16)
            dts, shs = meta.decode().split(";")
            dtype = np.dtype(dts)
            shape = tuple(int(x) for x in shs.split(",") if x)
            nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
            # a leaf (no children to forward to) gains nothing from
            # landing in a round buffer — it would just pay an extra
            # pool->user copy; receive straight into user memory instead
            kk = 1
            while kk <= vr:
                kk *= 2
            has_child = vr + kk < n
            resident = has_child and self._use_resident(nbytes)
            if resident:
                pb, buf = self._rounds.array(0, (nbytes,), np.uint8)
                self.recv_into(parent, pb.slice(0, nbytes), tag=_T + 17)
                out = buf.view(dtype).reshape(shape)
            else:
                out = np.empty(shape, dtype)
                self.recv_into(parent, out, tag=_T + 17)
        payload = pb.slice(0, out.nbytes) if resident else out
        k = 1
        while k < n:
            if vr < k and vr + k < n:
                child = (vr + k + root) % n
                self.send(child, meta, tag=_T + 16)
                self.send(child, payload, tag=_T + 17)
            k *= 2
        return np.array(out) if (resident or vr == 0) else out

    def reduce(self, arr: np.ndarray, op=np.add, root: int = 0
               ) -> np.ndarray | None:
        arr = np.ascontiguousarray(arr)
        if self.size == 1:
            return arr.copy()
        if not self._use_resident(arr.nbytes):
            return _coll.reduce(self, arr, op, root)
        n, r = self.size, self.rank
        vr = (r - root) % n
        pb, acc = self._rounds.array(0, arr.shape, arr.dtype)
        np.copyto(acc, arr)
        pb_t, tmp = self._rounds.array(1, arr.shape, arr.dtype)
        k = 1
        while k < n:
            if vr % (2 * k) == 0:
                if vr + k < n:
                    # pool-resident destination: posted rendezvous lets
                    # the child write its partial straight into tmp
                    self.recv_into((vr + k + root) % n,
                                   pb_t.slice(0, arr.nbytes), tag=_T + 32)
                    acc[...] = op(acc, tmp)
            elif vr % (2 * k) == k:
                self.send((vr - k + root) % n, pb.slice(0, arr.nbytes),
                          tag=_T + 32)
                return None
            k *= 2
        return np.array(acc) if r == root else None

    def allreduce(self, arr: np.ndarray, op=np.add, algo: str = "auto",
                  group_size: int | None = None) -> np.ndarray:
        """allreduce with automatic algorithm selection:
        recursive doubling (small, pow2 sizes), hierarchical (large
        payloads on composite sizes — intra-group ring + inter-group
        recursive doubling over split() sub-communicators), ring
        reduce-scatter + allgather otherwise."""
        arr = np.ascontiguousarray(arr)
        n = self.size
        if n == 1:
            return arr.copy()
        if algo == "auto":
            if _is_pow2(n) and arr.size < 4096:
                algo = "rd"
            elif n >= 4 and _best_group(n) >= 2 and arr.size >= 4096:
                algo = "hier"
            else:
                algo = "ring"
        if algo == "hier":
            return self._allreduce_hier(arr, op, group_size)
        if algo == "rd":
            return self._allreduce_rd(arr, op)
        return self._allreduce_ring(arr, op)

    def _allreduce_rd(self, arr: np.ndarray, op=np.add) -> np.ndarray:
        n, r = self.size, self.rank
        assert _is_pow2(n), "recursive doubling needs power-of-two size"
        if not self._use_resident(arr.nbytes):
            return _coll.allreduce_rd(self, arr, op)
        pb, acc = self._rounds.array(0, arr.shape, arr.dtype)
        np.copyto(acc, arr)
        pb_o, other = self._rounds.array(1, arr.shape, arr.dtype)
        k = 1
        rnd = 0
        while k < n:
            peer = r ^ k
            # pre-post the incoming block, THEN send: the peer's payload
            # can land in ``other`` with one copy and no drain
            rreq = self.irecv_into(peer, pb_o.slice(0, arr.nbytes),
                                   tag=_T + 64 + rnd)
            sreq = self.isend(peer, pb.slice(0, arr.nbytes),
                              tag=_T + 64 + rnd)
            rreq.wait()
            sreq.wait()                 # ack: peer drained our buffer
            acc[...] = op(acc, other)
            k <<= 1
            rnd += 1
        return np.array(acc)

    def _allreduce_ring(self, arr: np.ndarray, op=np.add) -> np.ndarray:
        """Ring allreduce composed from reduce_scatter + allgather (the
        same decomposition as the free-function path, chunk reorder
        included). Each stage independently picks its resident or
        fallback form — the two are wire-compatible (same tags, round
        indices and sizes), so ranks whose eager thresholds or pool
        capabilities differ still interoperate. On the resident path
        every round ships a PoolView chunk (no staging) and pays one
        pool->pool copy — ~2(n-1)/n of the payload per rank, half the
        staged free-function cost."""
        shard = self.reduce_scatter(arr, op)
        flat = shards_to_chunk_order(self.allgather(shard, algo="ring"),
                                     self.size)
        return flat[:arr.size].reshape(arr.shape).astype(arr.dtype,
                                                         copy=False)

    def _hier_comms(self, g: int) -> tuple["Comm", "Comm"]:
        cached = self._hier_cache.get(g)
        if cached is None:
            intra = self.split(self.rank // g, key=self.rank)
            inter = self.split(self.rank % g, key=self.rank)
            cached = (intra, inter)
            self._hier_cache[g] = cached
        return cached

    def _allreduce_hier(self, arr: np.ndarray, op=np.add,
                        group_size: int | None = None) -> np.ndarray:
        """Hierarchical allreduce over split() sub-communicators:
        intra-group ring reduce-scatter -> inter-group allreduce on the
        shards (recursive doubling when the group count is pow2) ->
        intra-group ring allgather. Groups are contiguous rank blocks of
        ``group_size`` (default: largest divisor <= sqrt(n))."""
        n = self.size
        g = group_size if group_size is not None else _best_group(n)
        if g < 2 or n % g != 0:
            return self._allreduce_ring(arr, op)
        intra, inter = self._hier_comms(g)
        shard = intra.reduce_scatter(arr, op)
        shard = inter.allreduce(
            shard, op, algo="rd" if _is_pow2(inter.size) else "ring")
        flat = shards_to_chunk_order(intra.allgather(shard), g)
        return flat[:arr.size].reshape(arr.shape).astype(arr.dtype,
                                                         copy=False)

    def reduce_scatter(self, arr: np.ndarray, op=np.add) -> np.ndarray:
        """Ring reduce-scatter; returns this rank's reduced shard (chunk
        ``(rank+1) % size`` of the zero-padded flat payload)."""
        arr = np.ascontiguousarray(arr)
        n, r = self.size, self.rank
        if n == 1:
            return arr.reshape(-1).copy()
        if not self._use_resident(arr.nbytes):
            return _coll.reduce_scatter_ring(self, arr, op)
        flat = arr.reshape(-1)
        per = -(-flat.size // n)
        pb, work = self._rounds.array(0, (n, per), arr.dtype)
        wf = work.reshape(-1)
        wf[:flat.size] = flat
        if per * n > flat.size:
            wf[flat.size:] = 0
        pb_i, inc = self._rounds.array(1, (per,), arr.dtype)
        right, left = (r + 1) % n, (r - 1) % n
        cb = per * arr.dtype.itemsize
        for step in range(n - 1):
            send_idx = (r - step) % n
            recv_idx = (r - step - 1) % n
            rreq = self.irecv_into(left, pb_i.slice(0, cb),
                                   tag=_T + 128 + step)
            sreq = self.isend(right, pb.slice(send_idx * cb, cb),
                              tag=_T + 128 + step)
            rreq.wait()
            sreq.wait()
            work[recv_idx] = op(work[recv_idx], inc)
        return np.array(work[(r + 1) % n])

    def allgather(self, shard: np.ndarray, algo: str = "auto"
                  ) -> np.ndarray:
        """All-gather; returns the flat concatenation in rank order.
        ``algo``: ring | bruck | auto (ring for few ranks, Bruck's
        ceil(log2 n) rounds beyond that)."""
        shard = np.ascontiguousarray(shard)
        n, r = self.size, self.rank
        if n == 1:
            return shard.reshape(-1).copy()
        if algo == "auto":
            algo = "bruck" if n >= 8 else "ring"
        if not self._use_resident(shard.nbytes * n):
            f = (_coll.allgather_bruck if algo == "bruck"
                 else _coll.allgather_ring)
            return f(self, shard).reshape(-1)
        per = shard.size
        sb = shard.nbytes
        pb, work = self._rounds.array(0, (n, per), shard.dtype)
        if algo == "bruck":
            # blocks accumulate CONTIGUOUSLY in bruck order, so each
            # round ships one PoolView over blocks[:count] — the
            # packing concat of the non-resident path disappears
            work[0] = shard.reshape(-1)
            k = 1
            have = 1
            rnd = 0
            while k < n:
                count = min(k, n - k)
                rreq = self.irecv_into((r + k) % n,
                                       pb.slice(have * sb, count * sb),
                                       tag=_T + 512 + rnd)
                sreq = self.isend((r - k) % n, pb.slice(0, count * sb),
                                  tag=_T + 512 + rnd)
                rreq.wait()
                sreq.wait()
                have += count
                k <<= 1
                rnd += 1
            # work[i] holds rank (r+i) % n's shard — rotate to rank order
            out = np.empty((n, per), shard.dtype)
            for i in range(n):
                out[(r + i) % n] = work[i]
            return out.reshape(-1)
        work[r] = shard.reshape(-1)
        right, left = (r + 1) % n, (r - 1) % n
        for step in range(n - 1):
            send_idx = (r - step) % n
            recv_idx = (r - step - 1) % n
            rreq = self.irecv_into(left, pb.slice(recv_idx * sb, sb),
                                   tag=_T + 256 + step)
            sreq = self.isend(right, pb.slice(send_idx * sb, sb),
                              tag=_T + 256 + step)
            rreq.wait()
            sreq.wait()
        return np.array(work).reshape(-1)

    def alltoall(self, blocks: list[np.ndarray]) -> list[np.ndarray]:
        """Pairwise exchange; ``blocks[i]`` goes to rank i. Resident
        path: one persistent round-buffer lane per peer, so all n-1
        sends are outstanding zero-copy PoolViews at once."""
        n, r = self.size, self.rank
        assert len(blocks) == n
        same = all(b.shape == blocks[0].shape and b.dtype == blocks[0].dtype
                   for b in blocks)
        total = sum(b.nbytes for b in blocks)
        if n == 1:
            return [blocks[0].copy()]
        if not (same and self._use_resident(total)):
            return _coll.alltoall(self, blocks)
        out: list[np.ndarray | None] = [None] * n
        out[r] = blocks[r].copy()
        reqs = []
        for off in range(1, n):
            dst = (r + off) % n
            pb, lane = self._rounds.array(1 + off, blocks[dst].shape,
                                          blocks[dst].dtype)
            np.copyto(lane, blocks[dst])
            reqs.append(self.isend(dst, pb.slice(0, blocks[dst].nbytes),
                                   tag=_T + 1024 + off))
        for off in range(1, n):
            src = (r - off) % n
            out[src] = np.empty(blocks[src].shape, blocks[src].dtype)
            self.recv_into(src, out[src], tag=_T + 1024 + off)
        self.waitall(reqs)
        return out
