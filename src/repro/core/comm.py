"""Comm: the cMPI v2 user-facing communicator facade.

The paper presents cMPI as an MPI library; this module is that library's
public surface. ``Comm`` subclasses the pt2pt engine (``Communicator``)
and adds everything an MPI application expects from a first-class
communicator object:

* **Method collectives** — ``comm.bcast / reduce / allreduce / allgather
  / reduce_scatter / alltoall / barrier``. Large payloads are routed
  through a per-comm pool of persistent pool-resident ROUND BUFFERS
  (``_RoundPool``): every ring/Bruck round sends a ``PoolView`` slice of
  a resident buffer, so exchanges ride the zero-sender-copy rendezvous
  path instead of re-staging into a fresh arena object each round (the
  foMPI lesson: route bulk transfers through window/pool-resident
  memory). On pools without raw views (incoherent mode) the methods fall
  back to the protocol-correct view-based algorithms in
  ``core/collectives``.

* **Sub-communicators** — ``comm.split(color, key)`` and ``comm.dup()``
  derive new communicators over the SAME arena with namespaced queue
  matrices and remapped ranks (``sub.parent_ranks`` maps sub-rank ->
  parent rank). Tag spaces are disjoint by construction: each derived
  comm owns its own SPSC queue matrix.

* **Hierarchical allreduce** — ``comm.ihier_allreduce`` compiles
  intra-group ring reduce-scatter -> inter-group recursive doubling ->
  intra-group ring allgather into ONE fused schedule over the parent
  communicator (no sub-comm phase barriers), auto-selected by
  ``allreduce``/``iallreduce`` for large payloads on hier-shaped
  sizes. ``chunk_bytes`` (int or ``"auto"``) additionally pipelines
  every large round at chunk granularity — see ``core/sched.py``.

* **Persistent requests** (MPI-4 style) — ``comm.send_init`` /
  ``comm.recv_init`` return a ``PersistentRequest`` whose
  ``start()/wait()`` pair can be reused across iterations. The wire plan
  (eager vs staged vs pool-resident) is decided ONCE at init; a staged
  persistent send allocates its staging object once and reuses it every
  ``start()`` — no arena create/destroy churn in steady state.

* **Auto-tuned eager threshold** — ``eager_threshold="auto"`` runs a
  one-shot micro-probe at init measuring the eager cell path against the
  rendezvous staging path on this host and records the measured
  crossover (``comm.probed_crossover``).

The pre-v2 surface (free-function collectives, the ``Communicator``
name) remains importable from ``repro.core`` as deprecation shims.
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Optional

import numpy as np

from repro.core import collectives as _coll
from repro.core import profile as _profile
from repro.core.arena import Arena, _hash_name
from repro.core.collectives import _is_pow2
from repro.core.pool import Registration, as_u8
from repro.core.progress import (CollRequest, _DEFAULT_TIMEOUT, _HeapBufs,
                                 _ResidentBufs, _SchedExec)
from repro.core.pt2pt import (ANY_TAG, DEFAULT_MB_SLOTS, Communicator,
                              PoolBuffer, PoolView, Request, _RNDV_CTRL)
from repro.core.ringqueue import DEFAULT_CELL_SIZE
from repro.core.sched import compile_schedule

_T = 0x7F000000          # collectives tag space (shared with collectives.py)
_NAME_BUDGET = 24        # derived comm names are hashed beyond this length


def _derived_name(parent: str, suffix: str) -> str:
    """Deterministic (rank-independent) name for a derived communicator,
    kept short enough that pb:/rv: object names stay under NAME_MAX."""
    name = f"{parent}.{suffix}"
    if len(name) > _NAME_BUDGET:
        name = f"c{_hash_name(name.encode(), 0):016x}"
    return name


def _hier_group(n: int, group_size: int | None = None,
                ratio: float | None = None) -> int | None:
    """Group size for the FUSED hierarchical allreduce schedule: must
    divide n with a power-of-two group COUNT (the inter phase is
    recursive doubling), 2 <= g < n. Auto picks the valid divisor
    closest to sqrt(n) — or, when a measured intra/inter tier bandwidth
    ``ratio`` is supplied (machine profile, ``tuning="auto"``), closest
    to sqrt(n * ratio): a faster intra tier carries proportionally more
    of the work, so groups grow with the measured advantage instead of
    assuming the tiers are equal. None when no valid grouping exists
    (primes, odd composites without a power-of-two cofactor, or an
    explicit ``group_size`` the fused schedule cannot honor) — those
    cases run single-level."""
    if group_size is not None:
        g = int(group_size)
        if g < 2 or g >= n or n % g or not _is_pow2(n // g):
            return None
        return g
    cands = [g for g in range(2, n) if n % g == 0 and _is_pow2(n // g)]
    if not cands:
        return None
    target = (n * max(1.0, float(ratio))) ** 0.5 if ratio else n ** 0.5
    return min(cands, key=lambda g: abs(g - target))


class _RoundPool:
    """Per-comm pool of persistent pool-resident round buffers.

    Two allocation styles share it:

    * ``buf``/``array`` — role-indexed buffers (0 = working buffer,
      1 = incoming block, 2.. = per-peer alltoall lanes), the PR 2
      surface still used by ``alltoall``.
    * ``lease``/``release`` — whole SLOT SETS for schedule executions:
      a leased set maps a schedule's slot indices to PoolBuffers and is
      returned to the free list when the execution finalizes, so
      back-to-back collectives reuse one set (flat arena footprint)
      while overlapping collectives (``iallreduce`` alongside an
      ``iallgather``) each hold their own.

    Capacity grows to the high-water mark (rounded to a power of two)
    and is then REUSED — steady-state iterative workloads do zero arena
    create/destroy work.
    """

    def __init__(self, comm: "Comm"):
        self._comm = comm
        self._bufs: dict[int, PoolBuffer] = {}
        self._free_sets: list[dict[int, PoolBuffer]] = []

    def _grow(self, bufs: dict[int, PoolBuffer], idx: int,
              nbytes: int) -> PoolBuffer:
        pb = bufs.get(idx)
        if pb is None or pb.nbytes < nbytes:
            if pb is not None:
                pb.free()
            cap = 1 << max(6, (max(nbytes, 1) - 1).bit_length())
            pb = self._comm.alloc_buffer(cap)
            bufs[idx] = pb
        return pb

    def buf(self, idx: int, nbytes: int) -> PoolBuffer:
        return self._grow(self._bufs, idx, nbytes)

    def array(self, idx: int, shape, dtype) -> tuple[PoolBuffer, np.ndarray]:
        """A numpy array aliasing pool memory (coherent pools only) plus
        its backing buffer — fills and op-applications write straight
        into pool-resident memory, so sends need no staging copy."""
        shape = tuple(shape)
        nbytes = int(np.prod(shape)) * np.dtype(dtype).itemsize
        pb = self.buf(idx, nbytes)
        arr = np.frombuffer(pb.view()[:nbytes], dtype=dtype).reshape(shape)
        return pb, arr

    def lease(self, slot_sizes: dict[int, int]
              ) -> tuple[dict[int, PoolBuffer], Any]:
        """Borrow a slot set sized for ``slot_sizes``; returns
        ``(bufs, release)`` where calling ``release()`` puts the set
        back on the free list."""
        bufs = self._free_sets.pop() if self._free_sets else {}
        for idx, sz in slot_sizes.items():
            self._grow(bufs, idx, sz)

        def release(_b=bufs):
            self._free_sets.append(_b)
        return bufs, release

    def free_all(self) -> None:
        for bufs in [self._bufs] + self._free_sets:
            for pb in bufs.values():
                try:
                    pb.free()
                except FileNotFoundError:
                    pass
            bufs.clear()
        self._free_sets.clear()


class PersistentRequest:
    """MPI-4-style persistent communication request.

    Created by ``Comm.send_init`` / ``Comm.recv_init``; ``start()``
    launches one operation over the pre-planned wire layout, ``wait()``
    (or ``test()``) completes it, and the pair may be repeated any number
    of times. The buffer handed to ``*_init`` is captured as a live view:
    mutate it between iterations, never replace it.

    Send plans, fixed at init time:
      eager   payload <= eager_threshold: chunk through queue cells
      staged  payload  > threshold: ONE persistent pool staging buffer,
              refilled (one counted copy) and re-sent each start() — the
              per-iteration arena create/destroy of a plain ``isend`` is
              gone, so the arena slot count stays constant across
              iterations
      pool    a PoolBuffer/PoolView source: zero sender-side copies
    """

    def __init__(self, comm: "Comm", kind: str, peer: int, buf,
                 tag: int):
        self._comm = comm
        self.kind = kind
        self.peer = peer
        self.tag = tag
        self.started = 0
        self._active: Optional[Request] = None
        self._stager: Optional[PoolBuffer] = None
        self._reg: Optional[Registration] = None
        if kind == "send":
            if isinstance(buf, (PoolBuffer, PoolView)):
                self._mode = "pool"
                self._payload = buf
                self._mv = None
            else:
                self._mv = as_u8(buf)
                if len(self._mv) > comm.eager_threshold:
                    self._mode = "staged"
                    self._stager = comm.alloc_buffer(len(self._mv))
                else:
                    self._mode = "eager"
        else:
            if isinstance(buf, (PoolBuffer, PoolView, Registration)):
                # pool-addressable destination: every start() re-arms a
                # matchbox entry pointing straight at it
                self._dest = buf
                self._mv = None
            else:
                self._mv = as_u8(buf)
                if self._mv.readonly:
                    raise ValueError("recv_init needs a writable buffer")
                if len(self._mv) > comm.eager_threshold \
                        and comm._mb is not None:
                    # pre-post pinning: register the user buffer ONCE so
                    # each start() re-arms the same shadow-backed entry —
                    # flat arena footprint, one receiver-side copy
                    # (shadow -> user) per iteration
                    self._reg = comm.register(self._mv)
                    self._dest = self._reg
                else:
                    self._dest = self._mv
            self._mode = "recv"

    @property
    def active(self) -> bool:
        return self._active is not None and not self._active.done

    def start(self) -> "PersistentRequest":
        if self.active:
            raise RuntimeError(
                "persistent request already active; wait() before "
                "restarting")
        if self.kind == "send":
            if self._mode == "pool":
                self._active = self._comm.isend(self.peer, self._payload,
                                                self.tag)
            elif self._mode == "staged":
                # claim-aware persistent plan: a matchbox hit writes the
                # user buffer straight into the receiver's posted
                # destination (one copy, stager untouched); a miss
                # refills the persistent stager in place — either way,
                # no arena churn per iteration
                self._active = self._comm.isend(
                    self.peer, self._mv, self.tag,
                    _prestaged=self._stager)
            else:
                self._active = self._comm.isend(self.peer, self._mv,
                                                self.tag)
        else:
            self._active = self._comm.irecv_into(self.peer, self._dest,
                                                 self.tag)
        self.started += 1
        return self

    def test(self) -> bool:
        if self._active is None:
            raise RuntimeError("persistent request not started")
        return self._active.test()

    def wait(self, timeout: float | None = 30.0) -> int:
        if self._active is None:
            raise RuntimeError("persistent request not started")
        self._active.wait(timeout)
        return self._active.nbytes

    def cancel(self) -> None:
        """MPI_Cancel on the active iteration (receives only): retracts
        any live matchbox posting and unlinks the posted receive, after
        which ``free()`` is legal.  Best-effort like ``Request.cancel``
        — a receive already draining an eager message completes
        normally.  No-op when idle or on sends."""
        if self._active is not None:
            self._active.cancel()

    def free(self) -> None:
        if self.active:
            raise RuntimeError("cannot free an active persistent request")
        if self._stager is not None:
            self._stager.free()
            self._stager = None
        if self._reg is not None:
            self._reg.free()
            self._reg = None


def startall(reqs: list) -> list:
    """MPI_Startall: start every persistent request in order (pt2pt and
    collective persistent requests may be mixed)."""
    for r in reqs:
        r.start()
    return reqs


class PersistentCollRequest:
    """MPI-4 persistent collective (``comm.allreduce_init(...)``,
    ``comm.bcast_init(...)``, ``comm.allgather_init(...)``).

    The schedule is compiled ONCE at init; buffers are dedicated,
    DOUBLE-BUFFERED pool-resident sets (parity = iteration mod 2); and
    every iteration's receives are posted one iteration AHEAD — the
    round-synchronized pre-post handshake that turns PR 3's
    opportunistic matchbox hits into deterministic ones:

    * ``*_init`` (collective) posts iteration 0's receives on every
      rank, then barriers — entries exist before any rank can
      ``start()``.
    * ``start(k)`` posts iteration k+1's receives (parity-swapped
      buffers, parity-salted tags) BEFORE issuing any iteration-k send.

    For CYCLIC schedules — allreduce, ring allgather — a peer can only
    reach its iteration-k+1 sends after its ``wait(k)``, which requires
    receiving data this rank sent in iteration k, i.e. after this
    rank's ``start(k)`` pre-posts. So every rendezvous send of every
    iteration finds its posted entry: a 100% posted-hit rate, asserted
    in ``fig5_8_osu --smoke``. A persistent BCAST has no such cycle
    (the root never receives, so it can outrun a slow subtree by more
    than one iteration); its pre-posting is best-effort — correctness
    is untouched (per-pair FIFO keeps iterations ordered; overruns fall
    back to the staged path), only the hit rate is opportunistic.

    Cross-iteration buffer safety: an iteration-k+1 entry may only be
    claimed by a peer already executing iteration k+1, and any send of
    ours that SOURCES the same parity buffer completed in iteration
    k-1 (its payload left the buffer at stage/claim time before the
    receive that unblocked the peer completed).

    Sizing: full determinism needs ``matchbox_slots >= 2 *
    max-receives-per-peer`` (two iterations' entries coexist) —
    exposed as ``.matchbox_demand``; shallower strips spill postings to
    the per-pair overflow list and promote them FIFO (misses only when
    a payload outruns its promotion, counted in
    ``ProtocolStats.mb_capacity_misses``).

    The bound array is captured as a live view: refill it between
    iterations, never replace it. ``wait()`` returns the collective's
    result (the reduced array / ``arr`` / the flat gathered payload).
    """

    def __init__(self, comm: "Comm", arr: np.ndarray, op=np.add,
                 algo: str = "auto", *, kind: str = "allreduce",
                 root: int = 0, chunk_bytes=None):
        self._comm = comm
        if not (isinstance(arr, np.ndarray) and arr.flags.c_contiguous):
            # a list or strided array would silently bind a one-time
            # SNAPSHOT — the per-iteration refills the live-view
            # contract promises would never be seen
            raise ValueError(f"{kind}_init needs a C-contiguous "
                             "ndarray (it is re-read on every start())")
        self._arr = arr
        self.kind = kind
        self.op = op
        self.root = root
        n = comm.size
        rank = comm.rank
        if kind == "allreduce":
            if algo == "auto":
                # same cutoff as every other allreduce surface;
                # recursive doubling additionally doubles the dedicated
                # buffer memory here, so large persistent payloads ride
                # the ring
                algo = _coll.auto_allreduce_algo(n, arr.size)
            sched_kind = ("allreduce_rd" if algo == "rd"
                          else "allreduce_ring")
        elif kind == "allgather":
            if algo == "auto":
                algo = "bruck" if n >= 8 else "ring"
            sched_kind = ("allgather_bruck" if algo == "bruck"
                          else "allgather_ring")
        elif kind == "bcast":
            algo = "binomial"
            sched_kind = "bcast"
        else:
            raise ValueError(f"unknown persistent collective: {kind}")
        self.algo = algo
        self.started = 0
        self._iter = 0
        self._active: Optional[CollRequest] = None
        self.matchbox_demand = 0
        if n == 1:
            self._sched = None
            return
        self._sched = compile_schedule(
            comm, sched_kind, arr.nbytes, arr.dtype.itemsize, root=root,
            chunk_bytes=_coll._resolve_chunk(comm, chunk_bytes,
                                             arr.nbytes))
        # two iterations' postings coexist (double-buffered slots), so
        # demand is twice the schedule's own per-peer pre-post depth
        self.matchbox_demand = 2 * self._sched.required_matchbox_depth()
        # per-iteration fill + finalize, fixed at init like the wire plan
        sched = self._sched
        shape, dtype, count = arr.shape, arr.dtype, arr.size
        if kind == "allreduce":
            self._fill = lambda b: b.fill(       # noqa: E731
                0, arr, pad_to=sched.slot_sizes[0])

            def fin(b):
                flat = b.ndview(sched.result, dtype)[:count]
                return np.array(flat).reshape(shape)
        elif kind == "allgather":
            per_b = arr.nbytes
            off = 0 if algo == "bruck" else rank * per_b
            self._fill = lambda b: b.fill_at(0, off, arr)  # noqa: E731
            if algo == "bruck":
                def fin(b):
                    work = np.array(b.ndview(sched.result, dtype)) \
                        .reshape(n, count)
                    return _coll.bruck_to_rank_order(work, rank, n)
            else:
                fin = lambda b: np.array(          # noqa: E731
                    b.ndview(sched.result, dtype))
        else:                                # bcast
            u8 = arr.reshape(-1).view(np.uint8)
            self._fill = ((lambda b: b.fill(0, arr)) if rank == root
                          else (lambda b: None))

            def fin(b):
                if rank != root:
                    u8[:] = b.ndview(sched.result, np.uint8)
                return arr
        self._fin = fin
        self._resident = comm._resident
        # CYCLIC schedules (allreduce, allgather) make the pre-post
        # handshake a guarantee: the matching posting always exists by
        # the time a send looks for it, possibly still spilled behind a
        # depth-capped strip. Such sends WAIT for promotion instead of
        # burning the one-copy path — that is what keeps the posted-hit
        # rate deterministically 100% at any matchbox depth. Bcast has
        # no cycle (the root can outrun a slow subtree), so its sends
        # keep the opportunistic claim-or-stage behavior.
        self._await_claim = (5.0 if self._resident and kind != "bcast"
                             else 0.0)
        # parity-salted tag windows: both iterations' receives are
        # posted concurrently, so their tags must differ
        self._bases = (comm._alloc_coll_tags(persistent=True),
                       comm._alloc_coll_tags(persistent=True))
        # dedicated double-buffered slot sets (never shared with the
        # round pool: they must stay stable across iterations)
        self._sets: list[dict] = []
        for _ in range(2):
            if self._resident:
                self._sets.append({
                    i: comm.alloc_buffer(sz)
                    for i, sz in self._sched.slot_sizes.items()})
            else:
                self._sets.append({
                    i: np.zeros(sz, np.uint8)
                    for i, sz in self._sched.slot_sizes.items()})
        # iteration 0's receives, posted before the init barrier: every
        # rank's entries exist before any rank can start()
        self._next_recvs = self._post_recvs(0)
        comm.barrier()

    def _post_recvs(self, it: int) -> dict[int, Request]:
        """Post every RecvOp of iteration ``it`` (parity buffers,
        parity tags). Pool-resident destinations publish matchbox
        entries immediately."""
        p = it % 2
        base = self._bases[p]
        slots = self._sets[p]
        reqs: dict[int, Request] = {}
        for nd in self._sched.recv_nodes():
            if self._resident:
                dst = slots[nd.buf.slot].slice(nd.buf.off, nd.buf.nbytes)
            else:
                dst = slots[nd.buf.slot][nd.buf.off:
                                         nd.buf.off + nd.buf.nbytes]
            reqs[nd.idx] = self._comm.irecv_into(nd.peer, dst,
                                                 tag=base + nd.round,
                                                 _internal=True)
        return reqs

    @property
    def active(self) -> bool:
        """In flight: started, not finished, and not failed — an
        errored iteration leaves the request inactive so it can be
        restarted or freed (the failed exec already cancelled its
        receives)."""
        return (self._active is not None and not self._active.done
                and self._active.error is None)

    def start(self) -> "PersistentCollRequest":
        if self.active:
            raise RuntimeError("persistent collective already active; "
                               "wait() before restarting")
        comm = self._comm
        if self._sched is None:          # size-1 communicator
            result = (self._arr if self.kind == "bcast"
                      else self._arr.reshape(-1).copy()
                      if self.kind == "allgather" else self._arr.copy())
            self._active = _coll.immediate(comm, result)
            self.started += 1
            return self
        k = self._iter
        self._iter += 1
        p = k % 2
        # THE HANDSHAKE: iteration k+1's receives go up before any
        # iteration-k send is issued (the exec below is what issues
        # sends), so peers that finish k and race into k+1 always find
        # posted entries
        cur = self._next_recvs
        self._next_recvs = self._post_recvs(k + 1)
        slots = self._sets[p]
        bufs = (_ResidentBufs(slots) if self._resident
                else _HeapBufs.from_slots(slots))
        self._fill(bufs)
        ex = _SchedExec(comm, self._sched, bufs, self._bases[p],
                        dtype=self._arr.dtype, op=self.op,
                        finalize=self._fin, bound_recvs=cur,
                        await_claim=self._await_claim)
        comm._engine.add_coll(ex)
        self._active = CollRequest(comm, ex)
        self.started += 1
        return self

    def test(self) -> bool:
        if self._active is None:
            raise RuntimeError("persistent collective not started")
        return self._active.test()

    def wait(self, timeout=_DEFAULT_TIMEOUT) -> np.ndarray:
        """Default timeout matches CollRequest: 30 s per schedule
        round; pass ``None`` to wait forever."""
        if self._active is None:
            raise RuntimeError("persistent collective not started")
        return self._active.wait(timeout)

    def free(self) -> None:
        """Cancel the pre-posted next-iteration receives (retracting
        their matchbox entries) and release the dedicated buffers.
        Local — but every rank should free before the communicator
        dies."""
        if self.active:
            raise RuntimeError("cannot free an active persistent "
                               "collective")
        if self._sched is None:
            return
        for req in self._next_recvs.values():
            req.cancel()
        self._next_recvs = {}
        if self._resident:
            for slots in self._sets:
                for pb in slots.values():
                    try:
                        pb.free()
                    except FileNotFoundError:
                        pass
        self._sets = []


class Comm(Communicator):
    """First-class cMPI communicator (the v2 public API).

    One-sided surface (RMA v2): ``win_allocate(name, win_size)``
    returns a comm-bound :class:`repro.core.rma.Window` exposing
    blocking put/get/accumulate, request-based ``rput``/``rget``
    (engine-pumped ``CollRequest``s that mix with pt2pt requests in
    ``waitall``), notified access (``put_notify``/``wait_notify`` —
    zero receiver-side payload copies), passive-target
    ``lock``/``lock_all``/``flush``, and the schedule-compiled window
    collectives ``Window.allgather``/``Window.bcast``.

    ``tuning="auto"`` reaches the one-sided path too: the agreed chunk
    floor drives ``chunk_bytes="auto"`` on ``rput``/``rget`` exactly as
    it drives two-sided collective chunking, and window collectives
    share this communicator's tag sequence — issue them in the same
    order on every rank, interleaved with ``Comm`` collectives or not.
    Accounting: every RMA byte lands in
    ``arena.view.stats.path_copied_bytes["rma_put" | "rma_get" |
    "rma_notify" | "rma_coll"]`` (put-like, get-like, notified-put
    payload, window-collective Put/Get nodes respectively)."""

    def __init__(self, arena: Arena, rank: int, size: int, *,
                 cell_size: int = DEFAULT_CELL_SIZE, n_cells: int = 8,
                 eager_threshold: int | str | None = None,
                 mb_slots: int = DEFAULT_MB_SLOTS,
                 matchbox_slots: int | None = None,
                 name: str = "world", open_timeout: float = 30.0,
                 tuning: str | None = None,
                 profile_path: str | None = None,
                 trace=None,
                 _inherit: Optional[dict] = None):
        if tuning not in (None, "auto"):
            raise ValueError(f"tuning must be None or 'auto', "
                             f"got {tuning!r}")
        auto = eager_threshold == "auto"
        self.tuning = tuning
        self._profile_path = profile_path
        # ``tuning="auto"``: load the measured machine profile
        # (benchmarks/roofline.py --profile) and derive every tuned
        # constant from it — eager threshold, chunk floor, hier group
        # ratio, matchbox depth. Missing/stale profiles warn (in
        # load_profile_info) and fall back to the heuristic policies;
        # the rejection REASON is kept (``tuning_status``,
        # ``trace_report()``) so a long-lived process can see why it is
        # running untuned and ``retune()`` after refreshing the profile.
        # Derived comms (split/dup) inherit the parent's state instead.
        prof, prof_reason = (
            _profile.load_profile_info(profile_path)
            if tuning == "auto" and _inherit is None else (None, None))
        if (_inherit is None and prof is not None
                and matchbox_slots is None
                and mb_slots == DEFAULT_MB_SLOTS):
            # matchbox depth from measured strip-scan vs spill-promote
            # cost. The depth sizes the SHARED region before any
            # collective agreement is possible, so it comes
            # deterministically from the shared profile file; the
            # agreement check below hard-fails if ranks diverged (a
            # depth mismatch is a region-layout mismatch).
            matchbox_slots = prof.mb_depth
        super().__init__(arena, rank, size, cell_size=cell_size,
                         n_cells=n_cells,
                         eager_threshold=None if auto else eager_threshold,
                         mb_slots=mb_slots, matchbox_slots=matchbox_slots,
                         name=name, open_timeout=open_timeout, trace=trace)
        self._derived_seq = 0
        self._rounds = _RoundPool(self)
        self._resident_ok: Optional[bool] = None
        self._chunk_base: Optional[int] = None
        # sub-rank -> parent-comm rank (identity for a root communicator)
        self.parent_ranks: tuple[int, ...] = tuple(range(size))
        self.probed_crossover: Optional[int] = None
        self.probe_mode: Optional[str] = None
        self.profile = prof
        self._tuned: Optional[dict] = None
        # ``retune()`` may re-derive the eager threshold from a fresh
        # profile only when the caller did not pin one explicitly
        self._eager_pinned = not (auto or eager_threshold is None)
        if _inherit is not None:
            # sub-communicators never re-probe or re-agree: the parent
            # already measured (or loaded) the crossover and agreed the
            # wire-shaping values, and the child group is a subset of
            # the ranks that agreed
            self.profile = _inherit.get("profile")
            self.probed_crossover = _inherit.get("probed_crossover")
            self.probe_mode = "inherited"
            self._chunk_base = _inherit.get("chunk_base")
            self._tuned = _inherit.get("tuned")
            self._set_tuning_status(_inherit.get("tuning_reason"))
            return
        if prof is not None:
            # the profile REPLACES the init-time ping-pong probe
            self.probe_mode = "profile"
            self.probed_crossover = prof.eager_crossover
            if auto or eager_threshold is None:
                self.eager_threshold = prof.eager_threshold
        elif auto:
            self.eager_threshold = self._probe_eager_threshold()
        if tuning == "auto":
            self._agree_tuning(prof)
        self._set_tuning_status(prof_reason)

    def _lease_round_bufs(self, slot_sizes: dict[int, int]):
        """Schedule-execution hook (core/collectives launch layer):
        borrow a pool-resident slot set from the round pool."""
        return self._rounds.lease(slot_sizes)

    def _chunk_probe_base(self) -> int:
        """Rank-AGREED basis for ``chunk_bytes="auto"``: the communicator
        maximum of each rank's probed crossover (or eager threshold).
        Per-rank probes may measure different crossovers, but chunk
        counts become sub-round wire tags, so every rank must derive
        the SAME chunk size. Resolved by a tiny max-allreduce the first
        time any collective resolves "auto" — a collective call itself,
        so every rank reaches it together (the MPI calling convention)
        — then cached for the communicator's lifetime."""
        if self._chunk_base is None:
            mine = float(self.probed_crossover or self.eager_threshold)
            if self.size == 1:
                self._chunk_base = int(mine)
            else:
                agreed = _coll.icoll_allreduce(
                    self, np.array([mine]), op=np.maximum,
                    algo="ring").wait()
                self._chunk_base = int(agreed[0])
        return self._chunk_base

    def _agree_tuning(self, prof) -> None:
        """Rank-agree the profile-derived tuning at init (the
        ``_chunk_probe_base`` idiom, run eagerly): one max-allreduce of
        [crossover, chunk_floor, tier_ratio*1024, mb_depth, -mb_depth].
        Chunk size and matchbox depth shape the wire (sub-round tags /
        shared-region layout), so every rank must hold the SAME values.
        The +depth/-depth pair detects divergence in one max-allreduce
        (max(-d) = -min(d)); a depth mismatch means the shared matchbox
        region was sized differently per rank — unrecoverable, so it
        raises. Ranks whose profile load failed contribute zeros and
        adopt the agreed values, keeping the collective rank-symmetric
        (no deadlock when profile visibility diverges)."""
        vec = np.array([
            float(prof.eager_crossover) if prof else 0.0,
            float(prof.chunk_floor) if prof else 0.0,
            prof.tier_ratio * 1024.0 if prof else 0.0,
            float(self.mb_slots), -float(self.mb_slots)], np.float64)
        if self.size > 1:
            vec = _coll.icoll_allreduce(self, vec, op=np.maximum,
                                        algo="ring").wait()
        if vec[3] != -vec[4]:
            raise RuntimeError(
                f"matchbox depth diverged across ranks under "
                f"tuning='auto' (saw depths {int(-vec[4])}..{int(vec[3])})"
                f": the shared strip region layout is inconsistent — "
                f"regenerate artifacts/bench/machine_profile.json or "
                f"pass matchbox_slots explicitly")
        if vec[0] <= 0:
            return                       # no rank had a fresh profile
        self._tuned = {"crossover": int(vec[0]),
                       "chunk_floor": int(vec[1]),
                       "tier_ratio": float(vec[2]) / 1024.0,
                       "mb_depth": int(vec[3])}
        # pre-seed the chunk-agreement base: no later lazy collective
        self._chunk_base = int(vec[0])

    def _set_tuning_status(self, reason: Optional[str]) -> None:
        """Record WHY this communicator is tuned the way it is — the
        state a stale profile used to leave behind only as one
        RuntimeWarning. ``tuning_status["mode"]``:

          off        tuning=None (heuristics by choice)
          profile    fresh machine profile loaded on this rank
          agreed     no local profile, but a peer had one — the agreed
                     wire-shaping values were adopted
          heuristic  tuning="auto" but no rank had a fresh profile
                     (``reason`` says why: missing / stale / unreadable)

        Also mirrored into the Metrics registry (``trace_report()``):
        the ``tuning_profile_loaded`` gauge and, on fallback, the
        ``tuning_heuristic_fallback`` counter."""
        if self.tuning != "auto":
            mode = "off"
        elif self.profile is not None:
            mode = "profile"
        elif self._tuned is not None:
            mode = "agreed"
        else:
            mode = "heuristic"
        self.tuning_status = {"mode": mode, "reason": reason}
        m = self.tracer.metrics
        m.gauge("tuning_profile_loaded",
                1.0 if self.profile is not None else 0.0)
        if mode == "heuristic":
            m.counter("tuning_heuristic_fallback")

    def retune(self, profile_path: str | None = None) -> dict:
        """Collective: re-load the machine profile and re-agree the
        tuned constants — the explicit re-profile path for long-lived
        (serving) processes whose ``Comm(tuning="auto")`` init found a
        stale profile and fell back to heuristics. Run
        ``python -m benchmarks.roofline --profile`` (any time after
        init), then call ``retune()`` on EVERY rank of this
        communicator, in the same order relative to other collectives.

        Re-derives the eager threshold (unless one was pinned at init)
        and re-agrees crossover / chunk floor / tier ratio. The
        matchbox DEPTH cannot change — the shared strip region was
        sized at init — and does not need to: depth only shapes the
        region layout, which stays valid; the agreement check still
        verifies all ranks hold the same depth. Returns the new
        ``tuning_status``."""
        if self.tuning != "auto":
            raise RuntimeError(
                "retune() is only meaningful on a Comm(tuning='auto') "
                "communicator")
        prof, reason = _profile.load_profile_info(
            profile_path if profile_path is not None
            else self._profile_path)
        self.profile = prof
        self._tuned = None
        self._chunk_base = None
        if prof is not None:
            self.probe_mode = "profile"
            self.probed_crossover = prof.eager_crossover
            if not self._eager_pinned:
                self.eager_threshold = prof.eager_threshold
        self._agree_tuning(prof)
        self._set_tuning_status(reason)
        return dict(self.tuning_status)

    def _inherit_state(self) -> dict:
        """Tuning state handed to split()/dup() children: the agreed
        values stay valid on any subset of the agreeing ranks."""
        return {"profile": self.profile,
                "probed_crossover": self.probed_crossover,
                "chunk_base": self._chunk_base,
                "tuned": self._tuned,
                "tuning_reason": getattr(self, "tuning_status",
                                         {}).get("reason")}

    @property
    def _hier_ratio(self) -> Optional[float]:
        """Measured intra/inter tier bandwidth ratio (None untuned)."""
        return self._tuned["tier_ratio"] if self._tuned else None

    # ------------------------------------------------------------------
    # auto-tuned eager threshold (one-shot init-time micro-probe)
    # ------------------------------------------------------------------
    def _probe_eager_threshold(self, reps: int = 3) -> int:
        """Measure the eager/rendezvous crossover and return the largest
        probed size at which eager still wins.

        With a real peer up (size >= 2), adjacent rank pairs (2i, 2i+1)
        ping-pong each probe size over the ACTUAL wire paths — the eager
        cell walk against the posted-rendezvous matchbox path — so the
        crossover reflects end-to-end cost (descriptor round trip, entry
        scan, claim) rather than the local staging model. The odd rank
        of an odd-sized communicator, and size-1 communicators, fall
        back to the local model. Per-rank and one-shot; thresholds may
        legitimately differ across ranks (the protocol is
        self-describing per message, so asymmetric thresholds are
        safe)."""
        if self.size >= 2 and self.rank < self.size - (self.size % 2):
            self.probe_mode = "peer"
            return self._probe_threshold_peer(reps)
        self.probe_mode = "local"
        return self._probe_threshold_local(reps)

    def _probe_threshold_peer(self, reps: int) -> int:
        """Real-peer probe: for each size, time an eager exchange and a
        posted-rendezvous exchange with the pair partner. The receive is
        posted (pool-resident destination, matchbox entry) BEFORE the
        zero-byte credit that releases the partner's send, so the
        rendezvous leg deterministically measures the posted path when
        the matchbox is enabled."""
        peer = self.rank ^ 1
        cell = self.cell_size
        sizes = [max(64, cell // 4), cell, 2 * cell, 4 * cell, 8 * cell]
        saved = self.eager_threshold
        scratch = memoryview(bytearray(sizes[-1]))
        dst = self.alloc_buffer(sizes[-1]) if self._pool_aliasable() \
            else bytearray(sizes[-1])
        _PRB = _T + 0x4000           # reserved probe tag window

        def exchange(s: int) -> None:
            rreq = self.irecv_into(peer, dst, tag=_PRB + 1,
                                   _internal=True)
            self.send(peer, b"", tag=_PRB + 2, _internal=True)  # credit
            self.recv(peer, tag=_PRB + 2, _internal=True)
            sreq = self.isend(peer, scratch[:s], tag=_PRB + 1,
                              _internal=True)
            rreq.wait()
            sreq.wait()

        def timed(s: int, threshold: int) -> float:
            self.eager_threshold = threshold
            exchange(s)                                  # warm / sync
            t0 = time.perf_counter()
            for _ in range(reps):
                exchange(s)
            return (time.perf_counter() - t0) / reps

        try:
            # probe EVERY size on both ranks (a rank must not stop early
            # — its partner would hang mid-sweep), then decide locally
            timings = [(timed(s, 1 << 40), timed(s, 0)) for s in sizes]
        finally:
            self.eager_threshold = saved
            if isinstance(dst, PoolBuffer):
                dst.free()
        threshold = sizes[-1]            # eager everywhere probed
        for i, (te, tr) in enumerate(timings):
            if tr <= te:
                self.probed_crossover = sizes[i]
                threshold = sizes[i - 1] if i else max(64, sizes[i] // 2)
                break
        return threshold

    def _probe_threshold_local(self, reps: int = 3) -> int:
        """Local staging model: eager (per-cell chunk copies) vs
        rendezvous (arena create + one stage + one bulk read + destroy)
        against this rank's own pool view."""
        v = self.arena.view
        cell = self.cell_size
        sizes = [max(64, cell // 4), cell, 2 * cell, 4 * cell, 8 * cell]
        scratch = memoryview(bytearray(sizes[-1]))
        h = self.arena.create(f"prb:{self.name}:{self.rank}",
                              _RNDV_CTRL + sizes[-1])

        def eager_cost(s: int) -> float:
            t0 = time.perf_counter()
            for _ in range(reps):
                for off in range(0, s, cell):
                    chunk = scratch[off:off + min(cell, s - off)]
                    v.write_release(h.offset + _RNDV_CTRL, chunk)
                    v.read_acquire_into(h.offset + _RNDV_CTRL, chunk)
            return (time.perf_counter() - t0) / reps

        def rndv_cost(s: int) -> float:
            t0 = time.perf_counter()
            for i in range(reps):
                hh = self.arena.create(
                    f"prv:{self.name}:{self.rank}:{i}", _RNDV_CTRL + s)
                v.write_release(hh.offset + _RNDV_CTRL, scratch[:s])
                v.read_acquire_into(hh.offset + _RNDV_CTRL, scratch[:s])
                self.arena.destroy(hh)
            return (time.perf_counter() - t0) / reps

        try:
            eager_cost(sizes[0])                 # warm the path once
            rndv_cost(sizes[0])
            threshold = sizes[-1]                # eager everywhere probed
            for i, s in enumerate(sizes):
                if rndv_cost(s) <= eager_cost(s):
                    self.probed_crossover = s
                    threshold = sizes[i - 1] if i else max(64, s // 2)
                    break
        finally:
            self.arena.destroy(h)
        return threshold

    # ------------------------------------------------------------------
    # sub-communicators
    # ------------------------------------------------------------------
    def split(self, color: int | None, key: int = 0) -> Optional["Comm"]:
        """MPI_Comm_split: collective over this comm. Ranks supplying the
        same ``color`` form a new communicator (ranked by ``(key, parent
        rank)``) over the same arena with its own namespaced queue matrix
        — tag spaces of parent and siblings are disjoint by construction.
        ``color=None`` (MPI_UNDEFINED) participates but receives None."""
        seq = self._derived_seq
        self._derived_seq += 1
        if color is not None and int(color) < 0:
            raise ValueError("color must be a non-negative int or None")
        c = -1 if color is None else int(color)
        mine = np.array([c, int(key), self.rank], np.int64)
        table = _coll.allgather_ring(self, mine).reshape(self.size, 3)
        if color is None:
            return None
        members = sorted((int(k), int(r)) for cc, k, r in table if cc == c)
        ranks = [r for _, r in members]
        sub = Comm(self.arena, ranks.index(self.rank), len(ranks),
                   cell_size=self.cell_size, n_cells=self.n_cells,
                   eager_threshold=self.eager_threshold,
                   mb_slots=self.mb_slots,
                   name=_derived_name(self.name, f"s{seq}.{c}"),
                   tuning=self.tuning, trace=self.tracer,
                   _inherit=self._inherit_state())
        sub.parent_ranks = tuple(ranks)
        return sub

    def dup(self) -> "Comm":
        """MPI_Comm_dup: a congruent communicator (same group, same rank
        order) with an independent queue matrix, hence a fully disjoint
        tag/message space."""
        seq = self._derived_seq
        self._derived_seq += 1
        sub = Comm(self.arena, self.rank, self.size,
                   cell_size=self.cell_size, n_cells=self.n_cells,
                   eager_threshold=self.eager_threshold,
                   mb_slots=self.mb_slots,
                   name=_derived_name(self.name, f"d{seq}"),
                   tuning=self.tuning, trace=self.tracer,
                   _inherit=self._inherit_state())
        sub.parent_ranks = self.parent_ranks
        return sub

    def free(self) -> None:
        """Collective MPI_Comm_free: every rank calls it. Releases the
        persistent round buffers, retracts this rank's matchbox postings
        (spilled ones are unlinked first), fences, and finally destroys
        the queue matrix / barrier / matchbox / publication arena
        objects (rank 0, after the fence — no rank is still draining
        them). Idempotent on every rank; the communicator is unusable
        afterwards."""
        if self._freed:
            return
        self._rounds.free_all()
        super().free()

    # ------------------------------------------------------------------
    # observability (core/trace.py)
    # ------------------------------------------------------------------
    def trace_report(self) -> dict:
        """Unified observability view for this rank: flight-recorder
        event counters, the live latency histograms (engine-tick
        duration, posted-rendezvous hit latency, ``wait_notify`` spin),
        registry metrics and the aggregate ``ProtocolStats`` snapshot.
        Meaningful content requires ``Comm(trace=True)`` (or an int
        capacity / injected ``Tracer``); a disabled tracer reports
        zeroes. The ``tuning`` section is always present: mode
        (profile / agreed / heuristic / off) and, on fallback, the
        reason the machine profile was rejected — so an untuned
        long-lived process is visible, not just one init-time
        warning."""
        out = self.tracer.report(stats=self.arena.view.stats)
        out["tuning"] = dict(self.tuning_status)
        return out

    def trace_dump(self, path) -> str:
        """Write this rank's flight-recorder ring + report as a JSON
        dump for ``python -m repro.trace merge|summarize``. Returns the
        written path. Each rank dumps its own file; the CLI stitches
        them into one Chrome/Perfetto timeline (CLOCK_MONOTONIC is
        shared across processes on one host, so no clock alignment is
        needed)."""
        return self.tracer.dump(path, stats=self.arena.view.stats)

    # ------------------------------------------------------------------
    # persistent requests (MPI-4)
    # ------------------------------------------------------------------
    def send_init(self, dest: int, buf, tag: int = 0) -> PersistentRequest:
        return PersistentRequest(self, "send", dest, buf, tag)

    def recv_init(self, src: int, buf, tag: int = ANY_TAG
                  ) -> PersistentRequest:
        return PersistentRequest(self, "recv", src, buf, tag)

    def allreduce_init(self, arr: np.ndarray, op=np.add,
                       algo: str = "auto",
                       chunk_bytes=None) -> PersistentCollRequest:
        """MPI_Allreduce_init: a persistent allreduce over dedicated
        double-buffered round buffers whose receives are pre-posted one
        iteration ahead (deterministic posted-rendezvous hits — see
        ``PersistentCollRequest``). ``chunk_bytes`` (int or "auto")
        pipelines each round at chunk granularity; with the pre-posted
        entries, chunk sends stay on the one-copy path even when a peer
        is late — the receiver reduces each chunk as it lands instead
        of idling until the whole payload arrived. Collective: every
        rank must call it, in the same order relative to other
        collectives. For guaranteed 100% hits size the communicator's
        matchbox to the schedule:
        ``Comm(matchbox_slots=req.matchbox_demand)``."""
        return PersistentCollRequest(self, arr, op, algo,
                                     chunk_bytes=chunk_bytes)

    def bcast_init(self, arr: np.ndarray, root: int = 0
                   ) -> PersistentCollRequest:
        """MPI_Bcast_init: persistent binomial-tree broadcast over the
        same double-buffered pre-posting machinery as
        ``allreduce_init``. ``arr`` must be a C-contiguous ndarray of
        identical shape/dtype on every rank; the root refills it
        between iterations, non-roots receive into it in place
        (``wait()`` returns it). Collective."""
        return PersistentCollRequest(self, arr, kind="bcast", root=root)

    def allgather_init(self, shard: np.ndarray, algo: str = "auto"
                       ) -> PersistentCollRequest:
        """MPI_Allgather_init: persistent all-gather (``algo``: ring |
        bruck | auto). Refill ``shard`` between iterations; ``wait()``
        returns the flat rank-ordered concatenation. The ring flavour
        is cyclic, so its one-iteration-ahead pre-posting gives the
        same deterministic posted-hit rate as ``allreduce_init``.
        Collective."""
        return PersistentCollRequest(self, shard, algo=algo,
                                     kind="allgather")

    # ------------------------------------------------------------------
    # pool-resident collective machinery
    # ------------------------------------------------------------------
    @property
    def _resident(self) -> bool:
        """True when round buffers can be aliased as raw numpy views:
        memory-backed pool AND hardware-coherent mode. Otherwise the
        methods fall back to the protocol-correct view-based algorithms."""
        if self._resident_ok is None:
            ok = self.arena.view.mode == "coherent"
            if ok:
                try:
                    self.arena.pool.memview(0, 1)
                except TypeError:
                    ok = False
            self._resident_ok = ok
        return self._resident_ok

    def _use_resident(self, nbytes: int) -> bool:
        # small payloads stay on the eager cell path — a descriptor
        # round-trip per round would cost more than it saves
        return self._resident and self.size > 1 \
            and nbytes > self.eager_threshold

    # ------------------------------------------------------------------
    # method collectives: blocking = i*(...).wait() over the SAME
    # compiled schedules (core/sched.py) the non-blocking forms use;
    # the hand-rolled per-round loops of PR 2/3 are gone
    # ------------------------------------------------------------------
    def barrier(self) -> None:          # inherited seq-number barrier;
        super().barrier()               # restated here as part of the API

    def ibarrier(self) -> CollRequest:
        """Non-blocking dissemination barrier (zero-byte message
        rounds through the schedule engine — the seq-number barrier
        cannot be tested incrementally)."""
        return _coll.icoll_barrier(self)

    def bcast(self, arr: np.ndarray | None, root: int = 0) -> np.ndarray:
        """Binomial-tree broadcast; non-root ranks pass ``arr=None``
        (shape/dtype travel in a fixed-size metadata round). Large
        payloads land once in a resident round buffer and are forwarded
        to every child with zero sender-side copies."""
        return _coll._bcast_impl(self, arr, root,
                                 use_resident=self._use_resident)

    def ibcast(self, arr: np.ndarray, root: int = 0,
               chunk_bytes=None) -> CollRequest:
        """Non-blocking broadcast; ``arr`` must be a C-contiguous
        ndarray present with the SAME shape/dtype on every rank (MPI
        ibcast semantics) and is overwritten in place on non-roots
        (non-contiguous buffers are rejected — a silent copy would
        break the in-place contract). ``chunk_bytes`` pipelines the
        binomial tree: interior ranks forward each chunk as it lands.
        ``wait()`` returns ``arr``."""
        return _coll.icoll_bcast_known(
            self, arr, root,
            resident=self._use_resident(np.asarray(arr).nbytes),
            chunk_bytes=chunk_bytes)

    def reduce(self, arr: np.ndarray, op=np.add, root: int = 0
               ) -> np.ndarray | None:
        arr = np.ascontiguousarray(arr)
        return _coll.icoll_reduce(
            self, arr, op, root,
            resident=self._use_resident(arr.nbytes)).wait()

    def allreduce(self, arr: np.ndarray, op=np.add, algo: str = "auto",
                  group_size: int | None = None,
                  chunk_bytes=None) -> np.ndarray:
        """allreduce with automatic algorithm selection: recursive
        doubling (small, pow2 sizes), the fused hierarchical schedule
        (large payloads on hier-shaped sizes), fused ring reduce-scatter
        + allgather otherwise. ``group_size`` applies to ``algo="hier"``;
        ``chunk_bytes`` (int or "auto") pipelines large payloads at
        chunk granularity."""
        arr = np.ascontiguousarray(arr)
        if self.size == 1:
            return arr.copy()
        if algo == "hier" or (algo == "auto" and group_size is not None):
            # an explicit grouping is a hier request: honoring it under
            # "auto" matches the pre-fused behavior, where auto-selected
            # hier used the caller's group_size
            return self.ihier_allreduce(
                arr, op, group_size=group_size,
                chunk_bytes=chunk_bytes).wait()
        return self.iallreduce(arr, op, algo,
                               chunk_bytes=chunk_bytes).wait()

    def iallreduce(self, arr: np.ndarray, op=np.add, algo: str = "auto",
                   chunk_bytes=None) -> CollRequest:
        """Non-blocking allreduce: returns a ``CollRequest`` whose
        ``wait()`` yields the reduced array. Inject compute between
        start and wait — sprinkle ``comm.progress()`` ticks through it
        — and the schedule engine overlaps the round exchanges with it
        (``benchmarks/fig5_8_osu.py`` measures the overlap efficiency).
        ``algo``: rd | ring | hier | auto — auto selects the fused
        hierarchical schedule on hier-shaped comms (n >= 4 with a
        power-of-two group count available) for large payloads.
        ``chunk_bytes`` (int or "auto") re-cuts the schedule so every
        round's payload pipelines in chunks — "auto" derives the chunk
        from the init-time eager/posted crossover probe."""
        arr = np.ascontiguousarray(arr)
        if algo == "auto":
            if self.size >= 4 and arr.size >= 4096 \
                    and _hier_group(self.size,
                                    ratio=self._hier_ratio) is not None:
                algo = "hier"
            else:
                algo = _coll.auto_allreduce_algo(self.size, arr.size)
        if algo == "hier":
            return self.ihier_allreduce(arr, op, chunk_bytes=chunk_bytes)
        return _coll.icoll_allreduce(
            self, arr, op, algo,
            resident=self._use_resident(arr.nbytes),
            chunk_bytes=chunk_bytes)

    def ihier_allreduce(self, arr: np.ndarray, op=np.add,
                        group_size: int | None = None,
                        chunk_bytes=None) -> CollRequest:
        """Non-blocking HIERARCHICAL allreduce as one fused schedule:
        intra-group ring reduce-scatter -> inter-group recursive
        doubling on the shards -> intra-group ring allgather, all in a
        single DAG over the parent communicator (the blocking sub-comm
        composition of PR 2 serialized the three phases; here a rank's
        allgather rounds overlap its neighbours' inter-group rounds,
        and chunking pipelines within each phase too). Groups are
        contiguous rank blocks of ``group_size`` (auto: the divisor of
        n closest to sqrt(n) with a power-of-two group count — the
        recursive-doubling requirement). A ``group_size`` the fused
        schedule cannot honor, and sizes with no valid grouping, fall
        back to the single-level fused ring (with a warning when the
        grouping was explicit — the pre-fused sub-comm path accepted
        any divisor)."""
        arr = np.ascontiguousarray(arr)
        g = _hier_group(self.size, group_size, ratio=self._hier_ratio)
        if g is None:
            if group_size is not None:
                warnings.warn(
                    f"hier group_size {group_size} needs 2 <= g < n, "
                    f"g | n and a power-of-two group count (n="
                    f"{self.size}); falling back to the single-level "
                    f"fused ring", UserWarning, stacklevel=2)
            return _coll.icoll_allreduce(
                self, arr, op, "ring",
                resident=self._use_resident(arr.nbytes),
                chunk_bytes=chunk_bytes)
        return _coll.icoll_allreduce_hier(
            self, arr, op, group=g,
            resident=self._use_resident(arr.nbytes),
            chunk_bytes=chunk_bytes)

    def reduce_scatter(self, arr: np.ndarray, op=np.add,
                       chunk_bytes=None) -> np.ndarray:
        """Ring reduce-scatter; returns this rank's reduced shard (chunk
        ``(rank+1) % size`` of the zero-padded flat payload)."""
        return self.ireduce_scatter(arr, op,
                                    chunk_bytes=chunk_bytes).wait()

    def ireduce_scatter(self, arr: np.ndarray, op=np.add,
                        chunk_bytes=None) -> CollRequest:
        """Non-blocking ring reduce-scatter."""
        arr = np.ascontiguousarray(arr)
        return _coll.icoll_reduce_scatter(
            self, arr, op, resident=self._use_resident(arr.nbytes),
            chunk_bytes=chunk_bytes)

    def allgather(self, shard: np.ndarray, algo: str = "auto",
                  chunk_bytes=None) -> np.ndarray:
        """All-gather; returns the flat concatenation in rank order.
        ``algo``: ring | bruck | auto (ring for few ranks, Bruck's
        ceil(log2 n) rounds beyond that)."""
        return self.iallgather(shard, algo,
                               chunk_bytes=chunk_bytes).wait()

    def iallgather(self, shard: np.ndarray, algo: str = "auto",
                   chunk_bytes=None) -> CollRequest:
        """Non-blocking all-gather; ``wait()`` returns the flat
        rank-ordered concatenation."""
        shard = np.ascontiguousarray(shard)
        if algo == "auto":
            algo = "bruck" if self.size >= 8 else "ring"
        return _coll.icoll_allgather(
            self, shard, algo,
            resident=self._use_resident(shard.nbytes * self.size),
            chunk_bytes=chunk_bytes)

    def alltoall(self, blocks: list[np.ndarray]) -> list[np.ndarray]:
        """Pairwise exchange; ``blocks[i]`` goes to rank i. Resident
        path: one persistent round-buffer lane per peer, so all n-1
        sends are outstanding zero-copy PoolViews at once."""
        n, r = self.size, self.rank
        assert len(blocks) == n
        same = all(b.shape == blocks[0].shape and b.dtype == blocks[0].dtype
                   for b in blocks)
        total = sum(b.nbytes for b in blocks)
        if n == 1:
            return [blocks[0].copy()]
        if not (same and self._use_resident(total)):
            return _coll.alltoall(self, blocks)
        out: list[np.ndarray | None] = [None] * n
        out[r] = blocks[r].copy()
        reqs = []
        for off in range(1, n):
            dst = (r + off) % n
            pb, lane = self._rounds.array(1 + off, blocks[dst].shape,
                                          blocks[dst].dtype)
            np.copyto(lane, blocks[dst])
            reqs.append(self.isend(dst, pb.slice(0, blocks[dst].nbytes),
                                   tag=_T + 1024 + off, _internal=True))
        for off in range(1, n):
            src = (r - off) % n
            out[src] = np.empty(blocks[src].shape, blocks[src].dtype)
            self.recv_into(src, out[src], tag=_T + 1024 + off,
                           _internal=True)
        self.waitall(reqs)
        return out
