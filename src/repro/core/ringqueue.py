"""SPSC message ring queues + the pairwise queue matrix (paper §3.3).

CXL pooled memory cannot provide cross-host atomic RMW, so MPICH's MPSC /
MPMC lock-free queues (CAS-based) do not work. The paper's fix: one
Single-Producer Single-Consumer ring queue PER (sender, receiver) PAIR.
Enqueue is executed only by the producer (owns ``tail``), dequeue only by
the consumer (owns ``head``) — every control word has exactly one writer,
so plain stores + the coherence protocol suffice.

Queue region layout (cacheline-separated control words to avoid false
sharing; control words use non-temporal access per §3.5):

  0:8     tail   (producer-owned: next cell to fill)
  64:72   head   (consumer-owned: next cell to drain)
  128:    cells  n_cells x cell_stride
            cell: [len u32 | flags u32 | payload cell_size]

Messages larger than ``cell_size`` are split into cell-sized chunks sent
sequentially (paper §4.3 studies the cell-size threshold; default 16 KB,
optimal 64 KB — reproduced in benchmarks/fig9_cellsize.py).

Zero-copy framing: ``try_enqueue_parts`` gathers a header plus any number
of buffer-protocol slices straight into the cell (no intermediate bytes
concatenation), and ``try_dequeue_into`` drains a cell's payload directly
into a caller buffer. ``FLAG_RNDV`` marks a cell that carries a rendezvous
control descriptor instead of payload (see core/pt2pt.py): large messages
bypass the cell pipeline entirely via a pool-resident staging object.
"""
from __future__ import annotations

import time

from repro.core.coherence import CoherentView
from repro.core.pool import CACHELINE, as_u8

_T_TAIL = 0
_T_HEAD = 64
_CELLS = 128

FLAG_FIRST = 1      # first chunk of a message (payload starts with header)
FLAG_LAST = 2
FLAG_RNDV = 4       # cell holds a rendezvous descriptor, not payload
FLAG_POSTED = 8     # rendezvous payload already sits in a RECEIVER-posted
                    # buffer (matchbox entry); descriptor names the entry

DEFAULT_CELL_SIZE = 16 * 1024      # MPICH default (paper §4.3)
OPTIMAL_CELL_SIZE = 64 * 1024      # paper's tuned value

# tags at or above this value are RESERVED for internal traffic (the
# canonical definition — ``repro.core.pt2pt`` re-exports it with the
# full tag-space map; it lives here, in the wire framing layer, so the
# queue's own user-facing send surface can validate without importing
# the communicator above it)
TAG_RESERVED_BASE = 0x7E000000


def cell_stride(cell_size: int) -> int:
    s = 8 + cell_size
    return s + (-s) % CACHELINE


def queue_bytes(cell_size: int, n_cells: int) -> int:
    return _CELLS + n_cells * cell_stride(cell_size)


class SPSCQueue:
    """One direction of one (sender, receiver) pair.

    The producer instantiates with ``producer=True`` and only enqueues; the
    consumer with ``producer=False`` and only dequeues. Both sides may be
    instantiated in different processes mapping the same pool region.
    """

    def __init__(self, view: CoherentView, base: int, cell_size: int,
                 n_cells: int, *, producer: bool, initialize: bool = False):
        self.view = view
        self.base = base
        self.cell_size = cell_size
        self.n_cells = n_cells
        self.stride = cell_stride(cell_size)
        self.producer = producer
        if initialize:
            view.nt_store_u64(base + _T_TAIL, 0)
            view.nt_store_u64(base + _T_HEAD, 0)
        # the owned index is cached locally (single writer => local copy is
        # authoritative); the foreign index is always nt-loaded.
        self._local_idx = view.nt_load_u64(
            base + (_T_TAIL if producer else _T_HEAD))

    # ---------------- producer ----------------
    def try_enqueue_parts(self, parts, flags: int = 0) -> bool:
        """Gather-enqueue: write each buffer-protocol part straight into
        the cell back-to-back — framing never concatenates into an
        intermediate ``bytes``. The tail is published only after every
        part is flushed (store-release ordering preserved)."""
        assert self.producer
        views = [as_u8(p) for p in parts]
        n = sum(len(v) for v in views)
        assert n <= self.cell_size
        tail = self._local_idx
        head = self.view.nt_load_u64(self.base + _T_HEAD)
        if tail - head >= self.n_cells:
            return False                       # full
        cell = self.base + _CELLS + (tail % self.n_cells) * self.stride
        self.view.write_release_gather(
            cell,
            (n.to_bytes(4, "little") + flags.to_bytes(4, "little"), *views))
        # publish AFTER the cell is flushed (store-release ordering)
        self._local_idx = tail + 1
        self.view.nt_store_u64(self.base + _T_TAIL, tail + 1)
        return True

    def try_enqueue(self, payload, flags: int = 0) -> bool:
        return self.try_enqueue_parts((payload,), flags)

    def enqueue(self, payload, flags: int = 0,
                timeout: float | None = None) -> None:
        self.enqueue_parts((payload,), flags, timeout=timeout)

    def enqueue_parts(self, parts, flags: int = 0,
                      timeout: float | None = None) -> None:
        t0 = time.monotonic()
        while not self.try_enqueue_parts(parts, flags):
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError("SPSC enqueue timed out")
            time.sleep(0)

    # ---------------- consumer ----------------
    def try_dequeue(self) -> tuple[bytes, int] | None:
        assert not self.producer
        head = self._local_idx
        tail = self.view.nt_load_u64(self.base + _T_TAIL)
        if head >= tail:
            return None                        # empty
        cell = self.base + _CELLS + (head % self.n_cells) * self.stride
        hdr = self.view.read_acquire(cell, 8)
        n = int.from_bytes(hdr[:4], "little")
        flags = int.from_bytes(hdr[4:], "little")
        payload = self.view.read_acquire(cell + 8, n) if n else b""
        self._local_idx = head + 1
        self.view.nt_store_u64(self.base + _T_HEAD, head + 1)
        return payload, flags

    def try_dequeue_into(self, dst) -> tuple[int, int] | None:
        """Drain one cell's payload straight into ``dst`` (writable
        buffer). Returns (nbytes, flags), or None if the queue is empty.
        Raises ValueError if the cell's payload exceeds ``dst``."""
        assert not self.producer
        head = self._local_idx
        tail = self.view.nt_load_u64(self.base + _T_TAIL)
        if head >= tail:
            return None                        # empty
        cell = self.base + _CELLS + (head % self.n_cells) * self.stride
        hdr = self.view.read_acquire(cell, 8)
        n = int.from_bytes(hdr[:4], "little")
        flags = int.from_bytes(hdr[4:], "little")
        d = as_u8(dst)
        if n > len(d):
            raise ValueError(f"dequeue_into: cell holds {n}B but dst "
                             f"has room for {len(d)}B")
        if n:
            self.view.read_acquire_into(cell + 8, d[:n])
        self._local_idx = head + 1
        self.view.nt_store_u64(self.base + _T_HEAD, head + 1)
        return n, flags

    def dequeue(self, timeout: float | None = None) -> tuple[bytes, int]:
        t0 = time.monotonic()
        while True:
            out = self.try_dequeue()
            if out is not None:
                return out
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError("SPSC dequeue timed out")
            time.sleep(0)

    def dequeue_into(self, dst, timeout: float | None = None
                     ) -> tuple[int, int]:
        t0 = time.monotonic()
        while True:
            out = self.try_dequeue_into(dst)
            if out is not None:
                return out
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError("SPSC dequeue timed out")
            time.sleep(0)

    # ---------------- message framing (chunked, paper §4.3) ----------------
    # first chunk payload: [total_len u64 | tag u64 | data...]
    _MSG_HDR = 16

    def plan_message(self, mv: memoryview, tag: int = 0):
        """Yield one (parts, flags) tuple per cell for framing ``mv`` —
        the single source of truth for the wire layout, shared by
        ``send_message`` and the communicator's eager send generator."""
        total = len(mv)
        first_room = self.cell_size - self._MSG_HDR
        hdr = (total.to_bytes(8, "little") + int(tag).to_bytes(8, "little"))
        yield ((hdr, mv[:first_room]),
               FLAG_FIRST | (FLAG_LAST if total <= first_room else 0))
        for i in range(first_room, total, self.cell_size):
            yield ((mv[i:i + self.cell_size],),
                   FLAG_LAST if i + self.cell_size >= total else 0)

    def send_message(self, data, tag: int = 0,
                     timeout: float | None = None) -> int:
        """Chunk ``data`` (any buffer-protocol object) into cells via
        zero-copy views; returns number of cells used. User-facing:
        reserved tags are rejected (internal traffic frames through
        ``plan_message`` + ``enqueue_parts`` directly)."""
        if int(tag) >= TAG_RESERVED_BASE:
            raise ValueError(f"tag {tag:#x} is in the reserved internal "
                             f"range (>= {TAG_RESERVED_BASE:#x})")
        cells = 0
        for parts, flags in self.plan_message(as_u8(data), tag):
            self.enqueue_parts(parts, flags, timeout=timeout)
            cells += 1
        return cells

    def recv_message(self, timeout: float | None = None) -> tuple[bytes, int]:
        payload, flags = self.dequeue(timeout=timeout)
        if not flags & FLAG_FIRST:
            raise RuntimeError("SPSC framing error: expected FIRST chunk")
        total = int.from_bytes(payload[:8], "little")
        tag = int.from_bytes(payload[8:16], "little")
        out = bytearray(total)
        mv = memoryview(out)
        got = min(len(payload) - 16, total)
        mv[:got] = payload[16:16 + got]
        self.view.count_copy(got)
        while got < total:
            n, _fl = self.dequeue_into(mv[got:], timeout=timeout)
            got += n
        return bytes(out), tag

    def recv_message_into(self, dst, timeout: float | None = None
                          ) -> tuple[int, int]:
        """Receive the next message straight into ``dst``; returns
        (nbytes, tag). Raises ValueError if ``dst`` is too small."""
        payload, flags = self.dequeue(timeout=timeout)
        if not flags & FLAG_FIRST:
            raise RuntimeError("SPSC framing error: expected FIRST chunk")
        total = int.from_bytes(payload[:8], "little")
        tag = int.from_bytes(payload[8:16], "little")
        d = as_u8(dst)
        if total > len(d):
            raise ValueError(f"recv_message_into: message of {total}B "
                             f"exceeds buffer of {len(d)}B")
        got = min(len(payload) - 16, total)
        d[:got] = payload[16:16 + got]
        self.view.count_copy(got)
        while got < total:
            n, _fl = self.dequeue_into(d[got:total], timeout=timeout)
            got += n
        return total, tag


class QueueMatrix:
    """n x n SPSC queues in one contiguous region (paper Fig: message queue
    matrix indexed by [receiver][sender]).

    Rank r's RECEIVE queues are row r (r consumes); its SEND queue toward
    rank d is (d, r) (r produces). Any rank locates any queue by address
    arithmetic — the Arena lesson: no data motion, just layout."""

    def __init__(self, view: CoherentView, base: int, n_ranks: int, rank: int,
                 cell_size: int = DEFAULT_CELL_SIZE, n_cells: int = 8,
                 *, initialize: bool = False):
        self.view = view
        self.base = base
        self.n = n_ranks
        self.rank = rank
        self.cell_size = cell_size
        self.n_cells = n_cells
        self.qb = queue_bytes(cell_size, n_cells)
        if initialize:
            for recv in range(n_ranks):
                for send in range(n_ranks):
                    b = self._qbase(recv, send)
                    view.nt_store_u64(b + _T_TAIL, 0)
                    view.nt_store_u64(b + _T_HEAD, 0)
        self._send: dict[int, SPSCQueue] = {}
        self._recv: dict[int, SPSCQueue] = {}

    @staticmethod
    def region_bytes(n_ranks: int, cell_size: int, n_cells: int) -> int:
        return n_ranks * n_ranks * queue_bytes(cell_size, n_cells)

    def _qbase(self, recv: int, send: int) -> int:
        return self.base + (recv * self.n + send) * self.qb

    def send_queue(self, dest: int) -> SPSCQueue:
        q = self._send.get(dest)
        if q is None:
            q = SPSCQueue(self.view, self._qbase(dest, self.rank),
                          self.cell_size, self.n_cells, producer=True)
            self._send[dest] = q
        return q

    def recv_queue(self, src: int) -> SPSCQueue:
        q = self._recv.get(src)
        if q is None:
            q = SPSCQueue(self.view, self._qbase(self.rank, src),
                          self.cell_size, self.n_cells, producer=False)
            self._recv[src] = q
        return q
