"""SPSC message ring queues + the pairwise queue matrix (paper §3.3).

CXL pooled memory cannot provide cross-host atomic RMW, so MPICH's MPSC /
MPMC lock-free queues (CAS-based) do not work. The paper's fix: one
Single-Producer Single-Consumer ring queue PER (sender, receiver) PAIR.
Enqueue is executed only by the producer (owns ``tail``), dequeue only by
the consumer (owns ``head``) — every control word has exactly one writer,
so plain stores + the coherence protocol suffice.

Queue region layout (cacheline-separated control words to avoid false
sharing; control words use non-temporal access per §3.5):

  0:8     tail   (producer-owned: next cell to fill)
  64:72   head   (consumer-owned: next cell to drain)
  128:    cells  n_cells x cell_stride
            cell: [len u32 | flags u32 | payload cell_size]

Messages larger than ``cell_size`` are split into cell-sized chunks sent
sequentially (paper §4.3 studies the cell-size threshold; default 16 KB,
optimal 64 KB — reproduced in benchmarks/fig9_cellsize.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.coherence import CoherentView
from repro.core.pool import CACHELINE

_T_TAIL = 0
_T_HEAD = 64
_CELLS = 128

FLAG_FIRST = 1      # first chunk of a message (payload starts with header)
FLAG_LAST = 2

DEFAULT_CELL_SIZE = 16 * 1024      # MPICH default (paper §4.3)
OPTIMAL_CELL_SIZE = 64 * 1024      # paper's tuned value


def cell_stride(cell_size: int) -> int:
    s = 8 + cell_size
    return s + (-s) % CACHELINE


def queue_bytes(cell_size: int, n_cells: int) -> int:
    return _CELLS + n_cells * cell_stride(cell_size)


class SPSCQueue:
    """One direction of one (sender, receiver) pair.

    The producer instantiates with ``producer=True`` and only enqueues; the
    consumer with ``producer=False`` and only dequeues. Both sides may be
    instantiated in different processes mapping the same pool region.
    """

    def __init__(self, view: CoherentView, base: int, cell_size: int,
                 n_cells: int, *, producer: bool, initialize: bool = False):
        self.view = view
        self.base = base
        self.cell_size = cell_size
        self.n_cells = n_cells
        self.stride = cell_stride(cell_size)
        self.producer = producer
        if initialize:
            view.nt_store_u64(base + _T_TAIL, 0)
            view.nt_store_u64(base + _T_HEAD, 0)
        # the owned index is cached locally (single writer => local copy is
        # authoritative); the foreign index is always nt-loaded.
        self._local_idx = view.nt_load_u64(
            base + (_T_TAIL if producer else _T_HEAD))

    # ---------------- producer ----------------
    def try_enqueue(self, payload: bytes, flags: int = 0) -> bool:
        assert self.producer and len(payload) <= self.cell_size
        tail = self._local_idx
        head = self.view.nt_load_u64(self.base + _T_HEAD)
        if tail - head >= self.n_cells:
            return False                       # full
        cell = self.base + _CELLS + (tail % self.n_cells) * self.stride
        hdr = len(payload).to_bytes(4, "little") + flags.to_bytes(4, "little")
        self.view.write_release(cell, hdr + payload)
        # publish AFTER the cell is flushed (store-release ordering)
        self._local_idx = tail + 1
        self.view.nt_store_u64(self.base + _T_TAIL, tail + 1)
        return True

    def enqueue(self, payload: bytes, flags: int = 0,
                timeout: float | None = None) -> None:
        t0 = time.monotonic()
        while not self.try_enqueue(payload, flags):
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError("SPSC enqueue timed out")
            time.sleep(0)

    # ---------------- consumer ----------------
    def try_dequeue(self) -> tuple[bytes, int] | None:
        assert not self.producer
        head = self._local_idx
        tail = self.view.nt_load_u64(self.base + _T_TAIL)
        if head >= tail:
            return None                        # empty
        cell = self.base + _CELLS + (head % self.n_cells) * self.stride
        hdr = self.view.read_acquire(cell, 8)
        n = int.from_bytes(hdr[:4], "little")
        flags = int.from_bytes(hdr[4:], "little")
        payload = self.view.read_acquire(cell + 8, n) if n else b""
        self._local_idx = head + 1
        self.view.nt_store_u64(self.base + _T_HEAD, head + 1)
        return payload, flags

    def dequeue(self, timeout: float | None = None) -> tuple[bytes, int]:
        t0 = time.monotonic()
        while True:
            out = self.try_dequeue()
            if out is not None:
                return out
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError("SPSC dequeue timed out")
            time.sleep(0)

    # ---------------- message framing (chunked, paper §4.3) ----------------
    # first chunk payload: [total_len u64 | tag u64 | data...]
    _MSG_HDR = 16

    def send_message(self, data: bytes, tag: int = 0,
                     timeout: float | None = None) -> int:
        """Chunk ``data`` into cells; returns number of cells used."""
        first_room = self.cell_size - self._MSG_HDR
        head = (len(data).to_bytes(8, "little")
                + int(tag).to_bytes(8, "little") + data[:first_room])
        rest = data[first_room:]
        chunks = [head]
        for i in range(0, len(rest), self.cell_size):
            chunks.append(rest[i:i + self.cell_size])
        for i, ch in enumerate(chunks):
            flags = (FLAG_FIRST if i == 0 else 0) | \
                    (FLAG_LAST if i == len(chunks) - 1 else 0)
            self.enqueue(ch, flags, timeout=timeout)
        return len(chunks)

    def recv_message(self, timeout: float | None = None) -> tuple[bytes, int]:
        payload, flags = self.dequeue(timeout=timeout)
        if not flags & FLAG_FIRST:
            raise RuntimeError("SPSC framing error: expected FIRST chunk")
        total = int.from_bytes(payload[:8], "little")
        tag = int.from_bytes(payload[8:16], "little")
        parts = [payload[16:]]
        got = len(payload) - 16
        while got < total:
            p, fl = self.dequeue(timeout=timeout)
            parts.append(p)
            got += len(p)
        return b"".join(parts)[:total], tag


class QueueMatrix:
    """n x n SPSC queues in one contiguous region (paper Fig: message queue
    matrix indexed by [receiver][sender]).

    Rank r's RECEIVE queues are row r (r consumes); its SEND queue toward
    rank d is (d, r) (r produces). Any rank locates any queue by address
    arithmetic — the Arena lesson: no data motion, just layout."""

    def __init__(self, view: CoherentView, base: int, n_ranks: int, rank: int,
                 cell_size: int = DEFAULT_CELL_SIZE, n_cells: int = 8,
                 *, initialize: bool = False):
        self.view = view
        self.base = base
        self.n = n_ranks
        self.rank = rank
        self.cell_size = cell_size
        self.n_cells = n_cells
        self.qb = queue_bytes(cell_size, n_cells)
        if initialize:
            for recv in range(n_ranks):
                for send in range(n_ranks):
                    b = self._qbase(recv, send)
                    view.nt_store_u64(b + _T_TAIL, 0)
                    view.nt_store_u64(b + _T_HEAD, 0)
        self._send: dict[int, SPSCQueue] = {}
        self._recv: dict[int, SPSCQueue] = {}

    @staticmethod
    def region_bytes(n_ranks: int, cell_size: int, n_cells: int) -> int:
        return n_ranks * n_ranks * queue_bytes(cell_size, n_cells)

    def _qbase(self, recv: int, send: int) -> int:
        return self.base + (recv * self.n + send) * self.qb

    def send_queue(self, dest: int) -> SPSCQueue:
        q = self._send.get(dest)
        if q is None:
            q = SPSCQueue(self.view, self._qbase(dest, self.rank),
                          self.cell_size, self.n_cells, producer=True)
            self._send[dest] = q
        return q

    def recv_queue(self, src: int) -> SPSCQueue:
        q = self._recv.get(src)
        if q is None:
            q = SPSCQueue(self.view, self._qbase(self.rank, src),
                          self.cell_size, self.n_cells, producer=False)
            self._recv[src] = q
        return q
