"""Collective schedules: a DAG IR compiled once per (op, size, topology).

The blocking collectives of PR 2/3 were hand-rolled round loops — each
round did ``irecv_into; isend; wait; wait; reduce`` and the CPU idled at
every ``wait``. This module factors the ALGORITHM out of the execution:
a collective is compiled into a small dependency DAG of four node kinds

  SendOp    ship a buffer region to a peer (one message, one round tag)
  RecvOp    receive a peer's message into a buffer region
  ReduceOp  dst[...] = op(dst, src) over two regions (local compute)
  CopyOp    dst[...] = src (local data movement)

plus two ONE-SIDED node kinds for schedules bound to an RMA window
(``repro.core.rma.Window``):

  PutOp     store a local buffer region into rank ``target``'s window
            segment at byte displacement ``disp`` (write_release — no
            target-side involvement, no wire message, no tag)
  GetOp     load rank ``target``'s window segment at ``disp`` into a
            local buffer region (read_acquire)

Put/Get are LOCAL nodes to the progress engine (the window is shared
memory — the store IS the transfer); cross-rank ordering in RMA-based
collectives comes from zero-byte Send/Recv token pairs, which keeps the
one-sided schedules inside the same verified matching/deadlock/hazard
discipline as the two-sided ones.

over SYMBOLIC buffer slots (``BufRef``): the IR names `(slot, offset,
nbytes)` regions, never concrete memory, so one compiled schedule serves
the pool-resident backend (PoolBuffer round buffers, posted-rendezvous
receives), the plain-heap backend (numpy scratch, eager/staged wire) and
the persistent double-buffered backend alike. Compilation is pure —
``compile_schedule`` depends only on (kind, algo, n, rank, nbytes,
itemsize, root) — and cached per communicator, so iterative workloads
pay the DAG construction once.

Execution lives in ``repro.core.progress``: the shared progress engine
issues every node whose dependencies have completed, which is what turns
``comm.iallreduce(x)`` + user compute + ``wait()`` into actual
communication/computation overlap, and what lets MPI-4 persistent
collectives pre-post every round's matchbox entry before any sender
needs it (the round-synchronized pre-post handshake).

Dependency discipline (why each edge exists):

* a SendOp sourcing region R depends on the node that produced R's
  final-for-this-send value (a ReduceOp, RecvOp or the initial fill);
* consecutive SendOps from the same slot are chained — a ``PoolBuffer``
  has ONE drain-ack word, so at most one send per underlying buffer may
  be in flight (the heap backend keeps the same order for wire parity);
* a ReduceOp that writes the accumulator depends on the SendOp that
  last sourced it (a staged-rendezvous peer reads our memory until it
  acks — mutating the region earlier would corrupt the wire);
* RecvOps into private regions carry NO deps: the engine pre-posts them
  all at start, which is what primes the matchbox.

Tags: every node carries a ROUND index; the executor adds a per-launch
``tag_base`` from the communicator's collective sequence number, so
concurrent collectives (an ``iallreduce`` overlapping an ``ibarrier``)
never cross-match. Ranks must issue collectives in the same order —
the MPI calling convention — for the sequence numbers to agree.

Chunking (``compile_schedule(..., chunk_bytes=...)``): a compiled
schedule can be re-cut at CHUNK granularity — every Send/Recv/Reduce/
Copy node whose payload exceeds ``chunk_bytes`` is split into a chain
of per-chunk sub-nodes, and dependencies are mapped CHUNK-WISE wherever
the dependency is about the same buffer region (a send of chunk c waits
only for the reduce that produced chunk c, a pipelined bcast forwards
chunk c the moment it arrived). That converts the engine from
message-granular to chunk-granular progress: round k+1's receive for
chunk c is in flight while round k is still reducing chunk c+1 — the
intra-round overlap that takes large-payload collectives to peak
shared-pool bandwidth (CXL-CCL's pipelining lesson). Each sub-message
gets its own sub-round (hence its own wire tag), so ``Schedule.rounds``
counts SUB-rounds after chunking — timeout scaling and tag windows stay
correct automatically. ``chunk_bytes`` is widened as needed so the
sub-round count never exceeds ``MAX_ROUNDS``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["BufRef", "SendOp", "RecvOp", "ReduceOp", "CopyOp",
           "PutOp", "GetOp", "Schedule", "ScheduleInvariantError",
           "compile_schedule", "chunk_schedule", "MAX_ROUNDS"]

# rounds per schedule are capped so per-launch tag windows stay disjoint
MAX_ROUNDS = 256


class ScheduleInvariantError(ValueError):
    """A compiled schedule violates a structural invariant.

    Raised by ``Schedule.validate()`` (and reused by the cross-rank
    verifier in ``repro.analysis.verify``) instead of ``assert`` so the
    checks survive ``python -O``. Carries enough context — kind, rank,
    offending node index and its deps — to locate the bad node without
    a debugger."""

    def __init__(self, message: str, *, kind: str | None = None,
                 rank: int | None = None, node: int | None = None,
                 deps: tuple[int, ...] | None = None):
        where = []
        if kind is not None:
            where.append(f"kind={kind}")
        if rank is not None:
            where.append(f"rank={rank}")
        if node is not None:
            where.append(f"node={node}")
        if deps is not None:
            where.append(f"deps={deps}")
        if where:
            message = f"{message} [{', '.join(where)}]"
        super().__init__(message)
        self.kind = kind
        self.rank = rank
        self.node = node
        self.deps = deps


@dataclass(frozen=True)
class BufRef:
    """A symbolic buffer region: ``nbytes`` at ``off`` inside slot
    ``slot``. Slot 0 is the working/accumulator buffer by convention;
    higher slots hold per-round incoming blocks."""
    slot: int
    off: int
    nbytes: int


@dataclass
class _Node:
    idx: int = field(init=False, default=-1)
    deps: tuple[int, ...] = ()


@dataclass
class SendOp(_Node):
    peer: int = -1
    buf: BufRef = None
    round: int = 0


@dataclass
class RecvOp(_Node):
    peer: int = -1
    buf: BufRef = None
    round: int = 0


@dataclass
class ReduceOp(_Node):
    dst: BufRef = None
    src: BufRef = None


@dataclass
class CopyOp(_Node):
    dst: BufRef = None
    src: BufRef = None


@dataclass
class PutOp(_Node):
    """One-sided store: local region ``buf`` -> rank ``target``'s window
    segment at byte displacement ``disp`` (plus the execution's
    ``win_disp`` base). Local to the engine — no wire message, no tag;
    ``round`` is informational only."""
    target: int = -1
    buf: BufRef = None
    disp: int = 0
    round: int = 0


@dataclass
class GetOp(_Node):
    """One-sided load: rank ``target``'s window segment at ``disp`` ->
    local region ``buf``. Local to the engine, like PutOp."""
    target: int = -1
    buf: BufRef = None
    disp: int = 0
    round: int = 0


@dataclass
class Schedule:
    """A compiled collective for ONE rank of an n-rank communicator."""
    kind: str
    n: int
    rank: int
    nodes: list = field(default_factory=list)
    slot_sizes: dict = field(default_factory=dict)   # slot -> bytes
    rounds: int = 0                                  # tag span (SUB-rounds
    #                                                  once chunked)
    result: BufRef | None = None
    chunk_bytes: int | None = None     # None = message-granular

    def _add(self, node) -> int:
        node.idx = len(self.nodes)
        self.nodes.append(node)
        for s in self._refs(node):
            need = s.off + s.nbytes
            if need > self.slot_sizes.setdefault(s.slot, 0):
                self.slot_sizes[s.slot] = need
        return node.idx

    @staticmethod
    def _refs(node):
        if isinstance(node, (SendOp, RecvOp, PutOp, GetOp)):
            return (node.buf,)
        return (node.dst, node.src)

    # ------------------------------------------------------------------
    # derived metadata
    # ------------------------------------------------------------------
    def recv_nodes(self) -> list[RecvOp]:
        return [nd for nd in self.nodes if isinstance(nd, RecvOp)]

    def required_matchbox_depth(self, peer: int | None = None) -> int:
        """Matchbox depth a FULLY pre-posted execution of this schedule
        needs toward ``peer``: the number of RecvOps whose postings can
        coexist (the engine pre-posts every receive at start, so that is
        simply the per-peer receive count). ``peer=None`` returns the
        max over all peers. This is the single source of truth for the
        matchbox-demand derivation in ``comm.py`` and for the resource-
        bound check in ``repro.analysis.verify``."""
        per: dict[int, int] = {}
        for nd in self.recv_nodes():
            per[nd.peer] = per.get(nd.peer, 0) + 1
        if peer is not None:
            return per.get(peer, 0)
        return max(per.values(), default=0)

    def max_recvs_per_peer(self) -> int:
        """Largest number of receives this schedule posts toward one
        peer (persistent mode needs twice this: two iterations' entries
        coexist). Alias of ``required_matchbox_depth()``."""
        return self.required_matchbox_depth()

    def validate(self) -> None:
        """Compile-time sanity: deps in range and strictly backward
        (construction order is a topological order), rounds in span.

        Raises ``ScheduleInvariantError`` — not ``assert`` — so the
        checks hold under ``python -O`` too."""
        for nd in self.nodes:
            if not all(0 <= d < nd.idx for d in nd.deps):
                raise ScheduleInvariantError(
                    "forward/self/negative dep", kind=self.kind,
                    rank=self.rank, node=nd.idx, deps=nd.deps)
            if isinstance(nd, (SendOp, RecvOp)):
                if not 0 <= nd.round < self.rounds:
                    raise ScheduleInvariantError(
                        f"round {nd.round} outside span {self.rounds}",
                        kind=self.kind, rank=self.rank, node=nd.idx)


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


# --------------------------------------------------------------------------
# schedule-level chunking (post-pass over any compiled schedule)
# --------------------------------------------------------------------------

def _n_chunks(nbytes: int, chunk_bytes: int) -> int:
    return max(1, -(-nbytes // chunk_bytes))


def _sub_region(ref: BufRef, c: int, chunk_bytes: int) -> BufRef:
    off = c * chunk_bytes
    return BufRef(ref.slot, ref.off + off, min(chunk_bytes,
                                               ref.nbytes - off))


def chunk_schedule(base: Schedule, chunk_bytes: int) -> Schedule:
    """Re-cut ``base`` at chunk granularity: every node whose payload
    exceeds ``chunk_bytes`` becomes a chain of per-chunk sub-nodes.

    Dependency mapping:

    * CHUNK-WISE when the dep shares a buffer region with the node and
      splits into the same number of pieces — sub-node c depends only on
      the dep's sub-node c. This is what pipelines: the producer/anti-
      hazard edges of the compilers above are all about one region, so
      chunk c of a round is independent of chunk c+1 (a ring send of
      chunk c starts while chunk c+1 is still being reduced; a binomial
      bcast forwards chunk c the moment it landed).
    * CONSERVATIVE otherwise (disjoint regions or different piece
      counts, e.g. Bruck's growing blocks): every sub-node depends on
      every piece of the dep — exactly the base schedule's semantics.
    * SendOps sourcing the same slot are additionally chained globally
      (one drain-ack word per underlying PoolBuffer: at most one send
      per slot in flight), which also serializes a node's own sub-sends.

    Each sub-message takes its own SUB-round — its own wire tag — so
    per-pair matching never depends on claim-order luck and
    ``Schedule.rounds`` (tag span, timeout scaling) counts the real
    message count. Sub-round numbering must agree ACROSS ranks (a
    sender's sub-round is the receiver's), but a rank only sees its own
    nodes — and e.g. a binomial-tree leaf participates in a strict
    subset of the rounds. So every base round gets one UNIFORM window
    of ``ceil(max message size / chunk_bytes)`` sub-rounds: the largest
    message size is a pure function of (kind, n, nbytes) — identical on
    every rank for every compiler above — which makes the numbering
    rank-independent by construction. Dependency-free receives stay
    dependency-free per chunk: a chunked execution PRE-POSTS every
    sub-receive (the matchbox overflow spill keeps postings FIFO
    beyond strip capacity)."""
    s = Schedule(base.kind, base.n, base.rank, chunk_bytes=chunk_bytes)
    span = max((_n_chunks(nd.buf.nbytes, chunk_bytes)
                for nd in base.nodes if isinstance(nd, (SendOp, RecvOp))),
               default=1)
    round_off = {r: r * span for r in range(base.rounds)}
    acc = base.rounds * span
    pieces: dict[int, list[int]] = {}       # base idx -> sub-node idxs
    last_send_in_slot: dict[int, int] = {}  # slot -> last sub-SendOp idx

    def refs(nd):
        return [b for b in Schedule._refs(nd) if b is not None]

    def map_deps(nd, m: int, c: int) -> tuple[int, ...]:
        out = []
        mine = set(refs(nd))
        for d in nd.deps:
            dep = base.nodes[d]
            if len(pieces[d]) == m and mine & set(refs(dep)):
                out.append(pieces[d][c])
            else:
                out.extend(pieces[d])
        return tuple(out)

    for nd in base.nodes:
        if isinstance(nd, (SendOp, RecvOp)):
            m = _n_chunks(nd.buf.nbytes, chunk_bytes)
            subs = []
            for c in range(m):
                buf = _sub_region(nd.buf, c, chunk_bytes)
                rnd = round_off[nd.round] + c
                deps = map_deps(nd, m, c)
                if isinstance(nd, SendOp):
                    prev = last_send_in_slot.get(buf.slot)
                    if prev is not None and prev not in deps:
                        deps = deps + (prev,)
                    idx = s._add(SendOp(deps=deps, peer=nd.peer,
                                        buf=buf, round=rnd))
                    last_send_in_slot[buf.slot] = idx
                else:
                    idx = s._add(RecvOp(deps=deps, peer=nd.peer,
                                        buf=buf, round=rnd))
                subs.append(idx)
            pieces[nd.idx] = subs
        elif isinstance(nd, (PutOp, GetOp)):
            # one-sided: no wire tag, so no sub-round — the local buf
            # region AND the window displacement split in lockstep
            m = _n_chunks(nd.buf.nbytes, chunk_bytes)
            subs = []
            cls = PutOp if isinstance(nd, PutOp) else GetOp
            for c in range(m):
                buf = _sub_region(nd.buf, c, chunk_bytes)
                deps = map_deps(nd, m, c)
                subs.append(s._add(cls(deps=deps, target=nd.target,
                                       buf=buf,
                                       disp=nd.disp + c * chunk_bytes,
                                       round=nd.round)))
            pieces[nd.idx] = subs
        else:                                # ReduceOp / CopyOp
            m = _n_chunks(nd.dst.nbytes, chunk_bytes)
            subs = []
            for c in range(m):
                dst = _sub_region(nd.dst, c, chunk_bytes)
                src = _sub_region(nd.src, c, chunk_bytes)
                deps = map_deps(nd, m, c)
                cls = ReduceOp if isinstance(nd, ReduceOp) else CopyOp
                subs.append(s._add(cls(deps=deps, dst=dst, src=src)))
            pieces[nd.idx] = subs
    s.slot_sizes = dict(base.slot_sizes)
    s.rounds = max(acc, 1)
    s.result = base.result
    s.validate()
    return s


# --------------------------------------------------------------------------
# compilers (one per collective kind; pure functions of the key)
# --------------------------------------------------------------------------

def _compile_allreduce_rd(n: int, rank: int, nbytes: int) -> Schedule:
    """Recursive doubling: log2(n) rounds, whole-payload exchanges.
    Round r peers with rank^2^r; each round's incoming block lands in
    its OWN slot so every receive pre-posts at start."""
    if not _is_pow2(n):
        raise ValueError("recursive doubling needs power-of-two size, "
                         f"got {n}")
    s = Schedule("allreduce_rd", n, rank)
    acc = BufRef(0, 0, nbytes)
    prev_send = prev_red = None
    r = 0
    k = 1
    while k < n:
        peer = rank ^ k
        inc = BufRef(1 + r, 0, nbytes)
        recv = s._add(RecvOp(deps=(), peer=peer, buf=inc, round=r))
        sdeps = tuple(d for d in (prev_red, prev_send) if d is not None)
        send = s._add(SendOp(deps=sdeps, peer=peer, buf=acc, round=r))
        rdeps = (recv, send) + ((prev_red,) if prev_red is not None
                                else ())
        prev_red = s._add(ReduceOp(deps=rdeps, dst=acc, src=inc))
        prev_send = send
        k <<= 1
        r += 1
    s.rounds = r
    s.result = acc
    s.validate()
    return s


def _compile_allreduce_ring(n: int, rank: int, nbytes: int,
                            itemsize: int) -> Schedule:
    """Fused ring reduce-scatter + allgather in ONE working buffer of n
    chunks: RS rounds reduce incoming blocks into their chunks, AG
    rounds receive final chunks IN PLACE (no re-pack, no reorder pass —
    at completion slot 0 holds the reduced payload in chunk order)."""
    count = nbytes // itemsize
    per = -(-count // n)
    per_b = per * itemsize
    s = Schedule("allreduce_ring", n, rank)
    right, left = (rank + 1) % n, (rank - 1) % n
    chunk = lambda c: BufRef(0, (c % n) * per_b, per_b)   # noqa: E731
    rs_send: list[int] = []
    rs_red: list[int] = []
    prev_send = None
    for st in range(n - 1):
        inc = BufRef(1 + st, 0, per_b)
        recv = s._add(RecvOp(deps=(), peer=left, buf=inc, round=st))
        sdeps = tuple(d for d in ((rs_red[-1] if st else None),
                                  prev_send) if d is not None)
        send = s._add(SendOp(deps=sdeps, peer=right,
                             buf=chunk(rank - st), round=st))
        red = s._add(ReduceOp(deps=(recv,), dst=chunk(rank - st - 1),
                              src=inc))
        rs_send.append(send)
        rs_red.append(red)
        prev_send = send
    prev_recv = None
    for st in range(n - 1):
        rnd = (n - 1) + st
        # the chunk being received was last SOURCED by RS send `st`
        recv = s._add(RecvOp(deps=(rs_send[st],), peer=left,
                             buf=chunk(rank - st), round=rnd))
        sdeps = ((rs_red[-1], prev_send) if st == 0
                 else (prev_recv, prev_send))
        send = s._add(SendOp(deps=tuple(sdeps), peer=right,
                             buf=chunk(rank + 1 - st), round=rnd))
        prev_recv, prev_send = recv, send
    s.rounds = 2 * (n - 1)
    s.result = BufRef(0, 0, n * per_b)
    s.validate()
    return s


def _compile_allreduce_hier(n: int, rank: int, nbytes: int,
                            itemsize: int, group: int) -> Schedule:
    """Hierarchical allreduce as ONE fused schedule (no sub-comm phase
    composition): contiguous groups of ``group`` ranks run an intra-group
    ring reduce-scatter over ``group`` chunks, ranks holding the same
    chunk across groups run an inter-group recursive doubling on their
    shard, and the intra-group ring allgather lands the final chunks in
    place. Because the three phases share one DAG, a rank's allgather
    traffic overlaps its neighbours' inter-group rounds — the blocking
    sub-comm version serialized the phases at every rank.

    Needs ``n % group == 0`` and a power-of-two group COUNT (the
    recursive-doubling requirement). Result: slot 0 in chunk order,
    like the fused ring."""
    g = group
    if g < 1 or n % g:
        raise ValueError(f"group size {g} must divide comm size {n}")
    m = n // g
    if not _is_pow2(m):
        raise ValueError(f"hier needs a power-of-two group count, "
                         f"got {m} groups")
    count = nbytes // itemsize
    per = -(-count // g)
    per_b = per * itemsize
    s = Schedule("allreduce_hier", n, rank)
    grp, l = divmod(rank, g)
    right = grp * g + (l + 1) % g
    left = grp * g + (l - 1) % g
    chunk = lambda c: BufRef(0, (c % g) * per_b, per_b)   # noqa: E731
    rs_send: list[int] = []
    rs_red: list[int] = []
    prev_send = None
    rnd = 0
    for st in range(g - 1):                  # intra ring reduce-scatter
        inc = BufRef(1 + st, 0, per_b)
        recv = s._add(RecvOp(deps=(), peer=left, buf=inc, round=rnd))
        sdeps = tuple(d for d in ((rs_red[-1] if st else None),
                                  prev_send) if d is not None)
        send = s._add(SendOp(deps=sdeps, peer=right,
                             buf=chunk(l - st), round=rnd))
        rs_red.append(s._add(ReduceOp(deps=(recv,),
                                      dst=chunk(l - st - 1), src=inc)))
        rs_send.append(send)
        prev_send = send
        rnd += 1
    shard = chunk(l + 1)                     # this rank's reduced shard
    last_red = rs_red[-1] if rs_red else None
    slot = g                                 # RS used slots 1..g-1
    k = 1
    while k < m:                             # inter recursive doubling
        peer = (grp ^ k) * g + l
        inc = BufRef(slot, 0, per_b)
        slot += 1
        recv = s._add(RecvOp(deps=(), peer=peer, buf=inc, round=rnd))
        sdeps = tuple(d for d in (last_red, prev_send) if d is not None)
        send = s._add(SendOp(deps=sdeps, peer=peer, buf=shard,
                             round=rnd))
        rdeps = (recv, send) + ((last_red,) if last_red is not None
                                else ())
        last_red = s._add(ReduceOp(deps=rdeps, dst=shard, src=inc))
        prev_send = send
        k <<= 1
        rnd += 1
    prev_recv = None
    for st in range(g - 1):                  # intra ring allgather
        # the chunk being received was last SOURCED by RS send `st`
        # (the inter phase only touches this rank's own shard)
        recv = s._add(RecvOp(deps=(rs_send[st],), peer=left,
                             buf=chunk(l - st), round=rnd))
        sdeps = ((last_red, prev_send) if st == 0
                 else (prev_recv, prev_send))
        send = s._add(SendOp(deps=tuple(d for d in sdeps
                                        if d is not None),
                             peer=right, buf=chunk(l + 1 - st),
                             round=rnd))
        prev_recv, prev_send = recv, send
        rnd += 1
    s.slot_sizes[0] = max(s.slot_sizes.get(0, 0), g * per_b)
    s.rounds = max(rnd, 1)
    s.result = BufRef(0, 0, g * per_b)
    s.validate()
    return s


def _compile_reduce_scatter_ring(n: int, rank: int, nbytes: int,
                                 itemsize: int) -> Schedule:
    """The RS phase alone; the result is this rank's reduced shard,
    chunk ``(rank+1) % n`` of the zero-padded payload."""
    count = nbytes // itemsize
    per = -(-count // n)
    per_b = per * itemsize
    s = Schedule("reduce_scatter_ring", n, rank)
    right, left = (rank + 1) % n, (rank - 1) % n
    chunk = lambda c: BufRef(0, (c % n) * per_b, per_b)   # noqa: E731
    prev_send = prev_red = None
    for st in range(n - 1):
        inc = BufRef(1 + st, 0, per_b)
        recv = s._add(RecvOp(deps=(), peer=left, buf=inc, round=st))
        sdeps = tuple(d for d in (prev_red, prev_send) if d is not None)
        send = s._add(SendOp(deps=sdeps, peer=right,
                             buf=chunk(rank - st), round=st))
        prev_red = s._add(ReduceOp(deps=(recv,),
                                   dst=chunk(rank - st - 1), src=inc))
        prev_send = send
    s.rounds = max(n - 1, 1)
    s.result = chunk(rank + 1)
    s.validate()
    return s


def _compile_allgather_ring(n: int, rank: int, per_b: int) -> Schedule:
    """Ring allgather straight into the rank-ordered output buffer;
    every receive targets a private chunk, so ALL of them pre-post."""
    s = Schedule("allgather_ring", n, rank)
    right, left = (rank + 1) % n, (rank - 1) % n
    chunk = lambda c: BufRef(0, (c % n) * per_b, per_b)   # noqa: E731
    prev_send = prev_recv = None
    for st in range(n - 1):
        recv = s._add(RecvOp(deps=(), peer=left,
                             buf=chunk(rank - st - 1), round=st))
        sdeps = tuple(d for d in (prev_recv, prev_send) if d is not None)
        s._add(SendOp(deps=sdeps, peer=right, buf=chunk(rank - st),
                      round=st))
        prev_send = s.nodes[-1].idx
        prev_recv = recv
    s.rounds = max(n - 1, 1)
    s.result = BufRef(0, 0, n * per_b)
    s.validate()
    return s


def _compile_allgather_bruck(n: int, rank: int, per_b: int) -> Schedule:
    """Bruck allgather: ceil(log2 n) rounds, blocks accumulate
    contiguously in bruck order (the executor's finalizer rotates to
    rank order). Receives land in fresh regions — all pre-postable."""
    s = Schedule("allgather_bruck", n, rank)
    prev_send = prev_recv = None
    k = 1
    have = 1
    rnd = 0
    while k < n:
        count = min(k, n - k)
        recv = s._add(RecvOp(deps=(), peer=(rank + k) % n,
                             buf=BufRef(0, have * per_b, count * per_b),
                             round=rnd))
        sdeps = tuple(d for d in (prev_recv, prev_send) if d is not None)
        s._add(SendOp(deps=sdeps, peer=(rank - k) % n,
                      buf=BufRef(0, 0, count * per_b), round=rnd))
        prev_send = s.nodes[-1].idx
        prev_recv = recv
        have += count
        k <<= 1
        rnd += 1
    s.slot_sizes[0] = max(s.slot_sizes.get(0, 0), n * per_b)
    s.rounds = max(rnd, 1)
    s.result = BufRef(0, 0, n * per_b)
    s.validate()
    return s


def _compile_bcast(n: int, rank: int, root: int, nbytes: int) -> Schedule:
    """Binomial tree: one receive from the parent, then forwards to
    every child (chained — one ack slot per buffer)."""
    s = Schedule("bcast", n, rank)
    buf = BufRef(0, 0, nbytes)
    vr = (rank - root) % n
    recv = None
    if vr:
        k = 1
        while k * 2 <= vr:
            k *= 2
        recv = s._add(RecvOp(deps=(), peer=(vr - k + root) % n,
                             buf=buf, round=0))
    prev_send = None
    k = 1
    while k < n:
        if vr < k and vr + k < n:
            deps = tuple(d for d in (recv, prev_send) if d is not None)
            prev_send = s._add(SendOp(deps=deps,
                                      peer=(vr + k + root) % n,
                                      buf=buf, round=0))
        k *= 2
    s.slot_sizes[0] = max(s.slot_sizes.get(0, 0), nbytes)
    s.rounds = 1
    s.result = buf
    s.validate()
    return s


def _compile_reduce(n: int, rank: int, root: int, nbytes: int) -> Schedule:
    """Binomial tree, op applied bottom-up; each incoming partial gets
    its own slot so the receives pre-post."""
    s = Schedule("reduce", n, rank)
    acc = BufRef(0, 0, nbytes)
    vr = (rank - root) % n
    prev_red = None
    j = 0
    k = 1
    r = 0
    while k < n:
        if vr % (2 * k) == 0:
            if vr + k < n:
                inc = BufRef(1 + j, 0, nbytes)
                recv = s._add(RecvOp(deps=(), peer=(vr + k + root) % n,
                                     buf=inc, round=r))
                rdeps = (recv,) + ((prev_red,) if prev_red is not None
                                   else ())
                prev_red = s._add(ReduceOp(deps=rdeps, dst=acc, src=inc))
                j += 1
        elif vr % (2 * k) == k:
            deps = (prev_red,) if prev_red is not None else ()
            s._add(SendOp(deps=deps, peer=(vr - k + root) % n, buf=acc,
                          round=r))
            break
        k *= 2
        r += 1
    s.slot_sizes[0] = max(s.slot_sizes.get(0, 0), nbytes)
    # FULL tree depth on every rank (a leaf breaks out early, but
    # rounds must be rank-UNIFORM: chunking derives its widening and
    # sub-round layout from it, and ranks must agree on wire tags)
    s.rounds = max((n - 1).bit_length(), 1)
    s.result = acc if rank == root else None
    s.validate()
    return s


def _compile_barrier(n: int, rank: int) -> Schedule:
    """Dissemination barrier as zero-byte messages: round r talks to
    ranks +-2^r; a round's send waits for the previous round's recv."""
    s = Schedule("barrier", n, rank)
    empty = BufRef(0, 0, 0)
    prev_recv = None
    r = 0
    k = 1
    while k < n:
        deps = (prev_recv,) if prev_recv is not None else ()
        s._add(SendOp(deps=deps, peer=(rank + k) % n, buf=empty,
                      round=r))
        prev_recv = s._add(RecvOp(deps=(), peer=(rank - k) % n,
                                  buf=empty, round=r))
        k <<= 1
        r += 1
    s.rounds = max(r, 1)
    s.result = None
    s.validate()
    return s


# --------------------------------------------------------------------------
# one-sided (RMA window) kinds — executed by a window-bound _SchedExec
# --------------------------------------------------------------------------

def _compile_rput(n: int, rank: int, nbytes: int, target: int) -> Schedule:
    """Request-based put: one PutOp of the whole payload; the chunking
    post-pass splits it into a per-chunk chain the engine pumps
    incrementally (local-completion semantics: the request completes
    when the last chunk left the source buffer)."""
    s = Schedule("rput", n, rank)
    s._add(PutOp(deps=(), target=target, buf=BufRef(0, 0, nbytes),
                 disp=0))
    s.rounds = 1
    s.result = None
    s.validate()
    return s


def _compile_rget(n: int, rank: int, nbytes: int, target: int) -> Schedule:
    """Request-based get: one GetOp, chunked like ``rput``."""
    s = Schedule("rget", n, rank)
    s._add(GetOp(deps=(), target=target, buf=BufRef(0, 0, nbytes),
                 disp=0))
    s.rounds = 1
    s.result = BufRef(0, 0, nbytes)
    s.validate()
    return s


def _compile_raccumulate(n: int, rank: int, nbytes: int,
                         target: int) -> Schedule:
    """Request-based accumulate: GetOp the target region into a scratch
    slot, ReduceOp the local operand (slot 0) into it, PutOp the result
    back — the read-modify-write as a three-node chain the engine pumps
    like any other schedule. Chunked, each chunk's get/reduce/put chain
    is independent (the regions split in lockstep), so a large
    accumulate moves one chunk per tick instead of stalling the engine
    for the whole reduction. Atomicity is the CALLER's job: the window
    holds the exclusive lock across the request's lifetime (acquired at
    issue, released on completion — see ``Window.raccumulate``)."""
    s = Schedule("raccumulate", n, rank)
    operand = BufRef(0, 0, nbytes)
    acc = BufRef(1, 0, nbytes)
    get = s._add(GetOp(deps=(), target=target, buf=acc, disp=0))
    red = s._add(ReduceOp(deps=(get,), dst=acc, src=operand))
    s._add(PutOp(deps=(red,), target=target, buf=acc, disp=0))
    s.rounds = 1
    s.result = None
    s.validate()
    return s


def _compile_allgather_get(n: int, rank: int, per_b: int) -> Schedule:
    """Get-based allgather over a window: each rank PUBLISHES its block
    into its OWN window segment (a self-put), announces readiness to
    every peer with a zero-byte token (round 0), then GETS every peer's
    block straight into the rank-ordered output slot the moment that
    peer's token arrives. A closing zero-byte token (round 1) tells each
    peer its segment has been read, so the collective is safe to repeat
    on the same window immediately. Data never rides the wire — only
    2(n-1) empty tokens do."""
    s = Schedule("allgather_get", n, rank)
    empty = BufRef(0, 0, 0)
    chunk = lambda t: BufRef(0, (t % n) * per_b, per_b)   # noqa: E731
    pub = s._add(PutOp(deps=(), target=rank, buf=chunk(rank), disp=0))
    for off in range(1, n):
        t = (rank + off) % n
        s._add(SendOp(deps=(pub,), peer=t, buf=empty, round=0))
    for off in range(1, n):
        t = (rank + off) % n
        rdy = s._add(RecvOp(deps=(), peer=t, buf=empty, round=0))
        get = s._add(GetOp(deps=(rdy,), target=t, buf=chunk(t), disp=0))
        s._add(SendOp(deps=(get,), peer=t, buf=empty, round=1))
    for off in range(1, n):
        t = (rank + off) % n
        s._add(RecvOp(deps=(), peer=t, buf=empty, round=1))
    s.slot_sizes[0] = max(s.slot_sizes.get(0, 0), n * per_b)
    s.rounds = 2
    s.result = BufRef(0, 0, n * per_b)
    s.validate()
    return s


def _compile_bcast_put(n: int, rank: int, root: int,
                       nbytes: int) -> Schedule:
    """Put-based binomial-tree bcast: the parent PUTS the payload into
    this rank's own window segment and follows with a zero-byte token;
    on token arrival the rank GETS the payload from its own segment into
    slot 0 (the landing copy), forwards by putting into each child's
    segment, and finally acks the parent (round 1) so the parent's
    completion implies its subtree no longer reads any segment it wrote
    — back-to-back bcasts on one window cannot overwrite in-flight
    data."""
    s = Schedule("bcast_put", n, rank)
    buf = BufRef(0, 0, nbytes)
    empty = BufRef(0, 0, 0)
    vr = (rank - root) % n
    land = None
    parent = None
    if vr:
        k = 1
        while k * 2 <= vr:
            k *= 2
        parent = (vr - k + root) % n
        tok = s._add(RecvOp(deps=(), peer=parent, buf=empty, round=0))
        land = s._add(GetOp(deps=(tok,), target=rank, buf=buf, disp=0))
    prev_send = None
    acks = []
    k = 1
    while k < n:
        if vr < k and vr + k < n:
            child = (vr + k + root) % n
            deps = tuple(d for d in (land, prev_send) if d is not None)
            put = s._add(PutOp(deps=deps, target=child, buf=buf, disp=0))
            prev_send = s._add(SendOp(deps=(put,), peer=child, buf=empty,
                                      round=0))
            acks.append(s._add(RecvOp(deps=(), peer=child, buf=empty,
                                      round=1)))
        k *= 2
    if parent is not None:
        deps = (land,) + (tuple(acks) if acks else ())
        s._add(SendOp(deps=deps, peer=parent, buf=empty, round=1))
    s.slot_sizes[0] = max(s.slot_sizes.get(0, 0), nbytes)
    s.rounds = 2
    s.result = buf
    s.validate()
    return s


_COMPILERS = {
    "allreduce_rd": lambda n, rank, nbytes, itemsize, root, group:
        _compile_allreduce_rd(n, rank, nbytes),
    "allreduce_ring": lambda n, rank, nbytes, itemsize, root, group:
        _compile_allreduce_ring(n, rank, nbytes, itemsize),
    "allreduce_hier": lambda n, rank, nbytes, itemsize, root, group:
        _compile_allreduce_hier(n, rank, nbytes, itemsize, group),
    "reduce_scatter_ring": lambda n, rank, nbytes, itemsize, root, group:
        _compile_reduce_scatter_ring(n, rank, nbytes, itemsize),
    "allgather_ring": lambda n, rank, nbytes, itemsize, root, group:
        _compile_allgather_ring(n, rank, nbytes),
    "allgather_bruck": lambda n, rank, nbytes, itemsize, root, group:
        _compile_allgather_bruck(n, rank, nbytes),
    "bcast": lambda n, rank, nbytes, itemsize, root, group:
        _compile_bcast(n, rank, root, nbytes),
    "reduce": lambda n, rank, nbytes, itemsize, root, group:
        _compile_reduce(n, rank, root, nbytes),
    "barrier": lambda n, rank, nbytes, itemsize, root, group:
        _compile_barrier(n, rank),
    # one-sided kinds: ``root`` carries the TARGET rank for rput/rget
    # (the schedule is per-(nbytes, target) and cached like any other)
    "rput": lambda n, rank, nbytes, itemsize, root, group:
        _compile_rput(n, rank, nbytes, root),
    "rget": lambda n, rank, nbytes, itemsize, root, group:
        _compile_rget(n, rank, nbytes, root),
    "raccumulate": lambda n, rank, nbytes, itemsize, root, group:
        _compile_raccumulate(n, rank, nbytes, root),
    "allgather_get": lambda n, rank, nbytes, itemsize, root, group:
        _compile_allgather_get(n, rank, nbytes),
    "bcast_put": lambda n, rank, nbytes, itemsize, root, group:
        _compile_bcast_put(n, rank, root, nbytes),
}


def compile_schedule(comm, kind: str, nbytes: int = 0, itemsize: int = 1,
                     root: int = 0, *, group: int = 0,
                     chunk_bytes: int | None = None,
                     verify: bool = False) -> Schedule:
    """Compile (or fetch from the communicator's cache) the schedule for
    ``kind`` at this (size, rank, payload) — the once-per-(op, size,
    topology) contract. ``nbytes`` is the slot-0 payload for whole-
    buffer ops, the per-shard size for allgather kinds. ``group`` is
    the intra-group size for ``allreduce_hier``. ``chunk_bytes`` re-cuts
    the schedule at chunk granularity (see ``chunk_schedule``); it is
    widened — never narrowed — until the sub-round count fits the
    per-launch tag window, and the widened value is what the returned
    schedule's ``chunk_bytes`` reports.

    ``verify=True`` (debug hook) additionally runs the cross-rank
    static verifier over this config — compiling ALL ranks' schedules
    and checking send/recv matching, deadlock freedom, buffer hazards
    and resource bounds — and raises ``ScheduleInvariantError`` on any
    finding. Costs O(size) compilations; meant for tests and bring-up
    of new compilers, not hot paths."""
    if verify:
        from repro.analysis import verify as _verify
        _verify.verify_config(kind, comm.size, nbytes=nbytes,
                              itemsize=itemsize, root=root, group=group,
                              chunk_bytes=chunk_bytes).raise_if_failed()
    if chunk_bytes is not None:
        # itemsize-align so no ReduceOp sub-region splits an element
        chunk_bytes = max(itemsize, chunk_bytes - chunk_bytes % itemsize)
    key = (kind, nbytes, itemsize, root, group, chunk_bytes)
    cache = comm._sched_cache
    sched = cache.get(key)
    if sched is None:
        sched = _COMPILERS[kind](comm.size, comm.rank, nbytes, itemsize,
                                 root, group)
        if sched.rounds > MAX_ROUNDS:
            raise ValueError(
                f"{kind} at size {comm.size} needs {sched.rounds} rounds"
                f" > MAX_ROUNDS={MAX_ROUNDS}")
        if chunk_bytes is not None:
            chunked = chunk_schedule(sched, chunk_bytes)
            if chunked.rounds > MAX_ROUNDS:
                # widen by the MINIMAL integer factor that fits the tag
                # window (sub-rounds scale ~1/chunk, so start at the
                # ceiling ratio and step by one base unit): doubling
                # here could overshoot a knee-derived chunk by nearly
                # 2x, pushing tuned sub-messages out of the cache tier
                # the profile chose them to fit
                base_cb = chunk_bytes
                factor = -(-chunked.rounds // MAX_ROUNDS)
                chunked = chunk_schedule(sched, base_cb * factor)
                while chunked.rounds > MAX_ROUNDS:
                    factor += 1
                    chunked = chunk_schedule(sched, base_cb * factor)
            sched = chunked
        cache[key] = sched
    return sched
