"""cMPI core: the paper's contribution as a library.

  pool        — CXL-pool stand-ins (local / real shared memory / incoherent)
  coherence   — software cache-coherence protocol (§3.5)
  arena       — CXL SHM Arena: multi-level-hash named objects (§3.1)
  ringqueue   — SPSC queue matrix for two-sided pt2pt (§3.3)
  rma         — one-sided windows, put/get, PSCW/lock/fence sync (§3.2, §3.4)
  pt2pt       — Communicator: send/recv/isend/irecv over the queue matrix
  collectives — recursive-doubling / ring / Bruck collectives over pt2pt
  runtime     — thread and process runtimes for multi-rank execution
"""
from repro.core.arena import Arena, ArenaFullError, ObjHandle, PAPER_ARENA
from repro.core.coherence import CoherentView
from repro.core.collectives import (allgather_bruck, allgather_ring,
                                    allreduce, alltoall,
                                    barrier_dissemination, bcast, reduce,
                                    reduce_scatter_ring)
from repro.core.pool import (CACHELINE, IncoherentPool, LocalPool, Pool,
                             RankCache, SharedMemoryPool)
from repro.core.pt2pt import ANY_TAG, Communicator, Request
from repro.core.ringqueue import (DEFAULT_CELL_SIZE, OPTIMAL_CELL_SIZE,
                                  QueueMatrix, SPSCQueue)
from repro.core.rma import Window
from repro.core.runtime import RankEnv, run_processes, run_threads
from repro.core.sync import PSCW, BakeryLock, RWLock, SeqBarrier
