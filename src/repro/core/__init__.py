"""cMPI core: the paper's contribution as a library.

  pool        — CXL-pool stand-ins (local / real shared memory / incoherent);
                buffer-protocol native (write accepts views, readinto fills
                caller buffers, memview exposes pool-resident windows)
  coherence   — software cache-coherence protocol (§3.5); ProtocolStats
                counts payload copies (copies / copied_bytes) — the CXL
                messaging cost model — and read_acquire_into gives the
                single-copy pool -> destination load
  arena       — CXL SHM Arena: multi-level-hash named objects (§3.1)
  ringqueue   — SPSC queue matrix for two-sided pt2pt (§3.3); zero-copy
                framing via gather-enqueue (try_enqueue_parts) and
                dequeue_into
  rma         — one-sided windows, put/get (+ put_from/get_into buffer
                variants), PSCW/lock/fence sync (§3.2, §3.4)
  pt2pt       — the pt2pt ENGINE: send/recv/isend/irecv over the queue
                matrix, eager/rendezvous protocol selection, PoolBuffer /
                PoolView zero-sender-copy sends
  comm        — ``Comm``, the v2 public API: method collectives over
                persistent pool-resident round buffers, split()/dup()
                sub-communicators, MPI-4 persistent requests
                (send_init/recv_init), eager_threshold="auto"
  sched       — collective schedule IR: Send/Recv/Reduce/Copy DAGs
                compiled once per (op, size, topology) and cached
  progress    — the SHARED PROGRESS CORE: one cooperative engine per
                communicator owning pt2pt FIFOs, stager reclaim and
                every active collective schedule; CollRequest handles
                for comm.iallreduce/ibcast/iallgather/ireduce_scatter/
                ibarrier and MPI-4 persistent collectives
                (comm.allreduce_init — pre-posted matchbox rounds)
  collectives — the collective launch layer over the schedule engine
                (recursive doubling / fused ring / Bruck); the
                free-function surface is deprecated in favor of Comm
                methods but routes through the same schedules
  runtime     — thread and process runtimes for multi-rank execution
  trace       — flight recorder + metrics registry: off-by-default ring
                of binary events across engine/pt2pt/matchbox/RMA hot
                paths (``Comm(trace=True)``), exported as Chrome-trace
                timelines via ``python -m repro.trace``

Deprecated (import still works, emits DeprecationWarning): the
``Communicator`` name (use ``Comm``) and the free-function collectives
``bcast(comm, ...)``-style surface (use ``comm.bcast(...)`` methods).
"""
import warnings as _warnings
from importlib import import_module as _import_module

from repro.core.arena import Arena, ArenaFullError, ObjHandle, PAPER_ARENA
from repro.core.coherence import CoherentView, ProtocolStats
from repro.core.comm import (Comm, PersistentCollRequest, PersistentRequest,
                             startall)
from repro.core.pool import (CACHELINE, IncoherentPool, LocalPool, Pool,
                             RankCache, Registration, SharedMemoryPool,
                             as_u8)
from repro.core.progress import (CollRequest, ProgressEngine, testall,
                                 waitall, waitany)
from repro.core.pt2pt import (ANY_TAG, DEFAULT_MB_SLOTS, TAG_RESERVED_BASE,
                              Matchbox, PoolBuffer, PoolView, Request)
from repro.core.sched import (BufRef, CopyOp, RecvOp, ReduceOp, Schedule,
                              SendOp, compile_schedule)
from repro.core.ringqueue import (DEFAULT_CELL_SIZE, OPTIMAL_CELL_SIZE,
                                  QueueMatrix, SPSCQueue)
from repro.core.rma import DynamicWindow, Window
from repro.core.runtime import RankEnv, run_processes, run_threads
from repro.core.sync import PSCW, BakeryLock, RWLock, SeqBarrier
from repro.core.trace import (EV_NAMES, Histogram, Metrics, Tracer,
                              as_tracer, chrome_events, merge_dumps,
                              summarize_dumps)

# pre-v2 API surface: served lazily so each access emits a
# DeprecationWarning while old code keeps working unchanged
_DEPRECATED = {
    "Communicator": ("repro.core.pt2pt", "Communicator", "repro.core.Comm"),
    "bcast": ("repro.core.collectives", "bcast", "Comm.bcast"),
    "reduce": ("repro.core.collectives", "reduce", "Comm.reduce"),
    "allreduce": ("repro.core.collectives", "allreduce", "Comm.allreduce"),
    "allgather_ring": ("repro.core.collectives", "allgather_ring",
                       "Comm.allgather"),
    "allgather_bruck": ("repro.core.collectives", "allgather_bruck",
                        "Comm.allgather(algo='bruck')"),
    "reduce_scatter_ring": ("repro.core.collectives", "reduce_scatter_ring",
                            "Comm.reduce_scatter"),
    "alltoall": ("repro.core.collectives", "alltoall", "Comm.alltoall"),
    "barrier_dissemination": ("repro.core.collectives",
                              "barrier_dissemination", "Comm.barrier"),
}


def __getattr__(name: str):
    entry = _DEPRECATED.get(name)
    if entry is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    module, attr, replacement = entry
    _warnings.warn(
        f"repro.core.{name} is deprecated; use {replacement} instead "
        f"(the Comm API v2 facade)",
        DeprecationWarning, stacklevel=2)
    return getattr(_import_module(module), attr)


def __dir__():
    return sorted(list(globals()) + list(_DEPRECATED))
