"""cMPI core: the paper's contribution as a library.

  pool        — CXL-pool stand-ins (local / real shared memory / incoherent);
                buffer-protocol native (write accepts views, readinto fills
                caller buffers, memview exposes pool-resident windows)
  coherence   — software cache-coherence protocol (§3.5); ProtocolStats
                counts payload copies (copies / copied_bytes) — the CXL
                messaging cost model — and read_acquire_into gives the
                single-copy pool -> destination load
  arena       — CXL SHM Arena: multi-level-hash named objects (§3.1)
  ringqueue   — SPSC queue matrix for two-sided pt2pt (§3.3); zero-copy
                framing via gather-enqueue (try_enqueue_parts) and
                dequeue_into
  rma         — one-sided windows, put/get (+ put_from/get_into buffer
                variants), PSCW/lock/fence sync (§3.2, §3.4)
  pt2pt       — Communicator: send/recv/isend/irecv over the queue matrix.
                Two protocols per message: EAGER (<= eager_threshold,
                chunked through queue cells as views) and RENDEZVOUS
                (staged once in a pool object + control descriptor;
                PoolBuffer sends skip even the staging copy). recv_into /
                irecv_into deliver straight into caller buffers.
  collectives — recursive-doubling / ring / Bruck collectives over pt2pt,
                operating on ndarray views end to end
  runtime     — thread and process runtimes for multi-rank execution
"""
from repro.core.arena import Arena, ArenaFullError, ObjHandle, PAPER_ARENA
from repro.core.coherence import CoherentView, ProtocolStats
from repro.core.collectives import (allgather_bruck, allgather_ring,
                                    allreduce, alltoall,
                                    barrier_dissemination, bcast, reduce,
                                    reduce_scatter_ring)
from repro.core.pool import (CACHELINE, IncoherentPool, LocalPool, Pool,
                             RankCache, SharedMemoryPool, as_u8)
from repro.core.pt2pt import ANY_TAG, Communicator, PoolBuffer, Request
from repro.core.ringqueue import (DEFAULT_CELL_SIZE, OPTIMAL_CELL_SIZE,
                                  QueueMatrix, SPSCQueue)
from repro.core.rma import Window
from repro.core.runtime import RankEnv, run_processes, run_threads
from repro.core.sync import PSCW, BakeryLock, RWLock, SeqBarrier
