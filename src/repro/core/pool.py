"""Memory pools — the 'CXL pooled memory platform' stand-ins.

The paper's platform is an FPGA CXL pooled-memory box (Niagara 2.0) that
multiple hosts map via a dax device. Here a pool is a flat byte region with
three backends:

  * LocalPool        — in-process bytearray; unit tests, thread runtime.
  * SharedMemoryPool — multiprocessing.shared_memory; REAL inter-process
                       shared memory. On this host it plays the role CXL SHM
                       plays across hosts: a load/store fabric that bypasses
                       the network stack. The TCP transport benchmarked
                       against it goes through real localhost sockets.
  * IncoherentPool   — wraps another pool with per-rank write-back caches so
                       that, exactly like the paper's hardware, a store by
                       one rank is INVISIBLE to others until the writer
                       flushes and the reader invalidates. Used to prove the
                       software-coherence protocol necessary and sufficient.

All offsets are absolute byte offsets into the pool.

Data motion is buffer-protocol native: ``write`` accepts any object
exporting a C-contiguous buffer (bytes, bytearray, memoryview, numpy
array), ``readinto`` fills a caller-supplied writable buffer, and the
memory-backed pools expose raw ``memview`` windows so payloads can live
IN the pool (the MPI_Alloc_mem / CXL-resident-buffer story) — the basis
of the zero-copy rendezvous path in ``core/pt2pt.py``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from multiprocessing import shared_memory


def as_u8(buf) -> memoryview:
    """Flat uint8 view of any buffer-protocol object, zero-copy.

    Requires C-contiguity (callers pass np.ascontiguousarray first for
    strided arrays) — the same constraint real MPI datatypes place on
    the fast path."""
    mv = buf if isinstance(buf, memoryview) else memoryview(buf)
    if mv.ndim != 1 or mv.format != "B":
        mv = mv.cast("B")
    return mv


class Registration:
    """A user buffer PINNED for receiver-posted rendezvous (the cMPI
    analogue of MPI-3 memory registration; cf. foMPI registering
    window memory so remote writes can land without target-side work).

    ``Communicator.register`` pairs the user's writable view with a
    pool-resident SHADOW region. A receive posted on a registration
    advertises the shadow's offset in the matchbox, a claiming sender
    writes the payload straight into the shadow, and completion drains
    shadow -> user exactly once — no per-message staging object, flat
    arena footprint across iterations. Non-posted deliveries (eager,
    staged fallback) bypass the shadow and land in the user view
    directly. Free with ``.free()`` (or ``Communicator.unregister``);
    the pin is NOT released automatically.
    """

    __slots__ = ("mv", "nbytes", "shadow_off", "_handle", "_owner",
                 "closed")

    def __init__(self, mv: memoryview, shadow_off: int, handle, owner):
        self.mv = mv
        self.nbytes = len(mv)
        self.shadow_off = shadow_off
        self._handle = handle
        self._owner = owner
        self.closed = False

    def free(self) -> None:
        self._owner.unregister(self)


class Pool:
    """Flat byte region with read/write access."""

    size: int

    def read(self, off: int, n: int) -> bytes:
        raise NotImplementedError

    def write(self, off: int, data) -> None:
        raise NotImplementedError

    def readinto(self, off: int, dst) -> int:
        """Fill the writable buffer ``dst`` from [off, off+len(dst)).
        Subclasses override with a single-copy path."""
        d = as_u8(dst)
        d[:] = self.read(off, len(d))
        return len(d)

    def memview(self, off: int, n: int) -> memoryview:
        """Raw writable window into pool memory (only memory-backed,
        hardware-coherent pools can hand these out)."""
        raise TypeError(f"{type(self).__name__} is not memory-mappable")

    def close(self) -> None:
        pass

    def unlink(self) -> None:
        pass


class LocalPool(Pool):
    def __init__(self, size: int):
        self.size = size
        self.buf = bytearray(size)

    def read(self, off: int, n: int) -> bytes:
        if off < 0 or off + n > self.size:
            raise IndexError(f"pool read [{off}, {off + n}) out of bounds")
        return bytes(self.buf[off:off + n])

    def write(self, off: int, data) -> None:
        d = as_u8(data)
        if off < 0 or off + len(d) > self.size:
            raise IndexError(f"pool write [{off}, {off + len(d)}) "
                             f"out of bounds")
        self.buf[off:off + len(d)] = d

    def readinto(self, off: int, dst) -> int:
        d = as_u8(dst)
        n = len(d)
        if off < 0 or off + n > self.size:
            raise IndexError(f"pool read [{off}, {off + n}) out of bounds")
        d[:] = memoryview(self.buf)[off:off + n]
        return n

    def memview(self, off: int, n: int) -> memoryview:
        if off < 0 or off + n > self.size:
            raise IndexError(f"pool view [{off}, {off + n}) out of bounds")
        return memoryview(self.buf)[off:off + n]


class SharedMemoryPool(Pool):
    """Real shared memory between processes (CXL SHM host analogue)."""

    def __init__(self, size: int, name: str | None = None,
                 create: bool = True):
        if create:
            self.shm = shared_memory.SharedMemory(create=True, size=size,
                                                  name=name)
        else:
            self.shm = shared_memory.SharedMemory(name=name)
        self.size = self.shm.size
        self.name = self.shm.name
        self._created = create

    def read(self, off: int, n: int) -> bytes:
        return bytes(self.shm.buf[off:off + n])

    def write(self, off: int, data) -> None:
        d = as_u8(data)
        self.shm.buf[off:off + len(d)] = d

    def readinto(self, off: int, dst) -> int:
        d = as_u8(dst)
        n = len(d)
        d[:] = self.shm.buf[off:off + n]
        return n

    def memview(self, off: int, n: int) -> memoryview:
        if off < 0 or off + n > self.size:
            raise IndexError(f"pool view [{off}, {off + n}) out of bounds")
        return self.shm.buf[off:off + n]

    def close(self) -> None:
        self.shm.close()

    def unlink(self) -> None:
        try:
            self.shm.unlink()
        except FileNotFoundError:
            pass


# --------------------------------------------------------------------------
# incoherent pool: per-rank write-back caches
# --------------------------------------------------------------------------

CACHELINE = 64


@dataclass
class CacheStats:
    loads: int = 0
    stores: int = 0
    hits: int = 0
    misses: int = 0
    flushes: int = 0            # lines written back + invalidated
    invalidates: int = 0        # lines dropped (clean or forced)
    fences: int = 0
    flushed_bytes: int = 0


@dataclass
class _Line:
    data: bytearray
    dirty: bool = False


class RankCache:
    """A private write-back cache overlay for one rank over a backing pool.

    Fully-associative over line addresses (a dict) — associativity games are
    not the point; VISIBILITY is: dirty lines are invisible to other ranks
    until flushed, and stale clean lines hide remote updates until
    invalidated. That is exactly the hazard the paper's §3.5 protocol
    (flush+fence after write, fence+flush before read) exists to fix.
    """

    def __init__(self, backing: Pool):
        self.backing = backing
        self.lines: dict[int, _Line] = {}
        self.stats = CacheStats()
        self.lock = threading.Lock()   # protects this rank's own structures

    # -- internals ---------------------------------------------------------
    def _line(self, base: int) -> _Line:
        ln = self.lines.get(base)
        if ln is None:
            self.stats.misses += 1
            ln = _Line(bytearray(self.backing.read(base, CACHELINE)))
            self.lines[base] = ln
        else:
            self.stats.hits += 1
        return ln

    @staticmethod
    def _span(off: int, n: int):
        first = off - off % CACHELINE
        last = (off + n - 1) - (off + n - 1) % CACHELINE
        return range(first, last + 1, CACHELINE)

    # -- cached access -----------------------------------------------------
    def load(self, off: int, n: int) -> bytes:
        out = bytearray(n)
        self.load_into(off, out)
        return bytes(out)

    def load_into(self, off: int, dst) -> int:
        d = as_u8(dst)
        n = len(d)
        with self.lock:
            self.stats.loads += 1
            for base in self._span(off, n):
                ln = self._line(base)
                s = max(off, base)
                e = min(off + n, base + CACHELINE)
                d[s - off:e - off] = ln.data[s - base:e - base]
            return n

    def store(self, off: int, data) -> None:
        d = as_u8(data)
        with self.lock:
            self.stats.stores += 1
            n = len(d)
            for base in self._span(off, n):
                ln = self._line(base)
                s = max(off, base)
                e = min(off + n, base + CACHELINE)
                ln.data[s - base:e - base] = d[s - off:e - off]
                ln.dirty = True

    # -- coherence ops (the paper's clflush/clflushopt + fence model) ------
    def flush(self, off: int, n: int) -> int:
        """Write back + invalidate every line covering [off, off+n).
        Returns number of lines flushed (timing model input)."""
        with self.lock:
            count = 0
            for base in self._span(off, n):
                ln = self.lines.pop(base, None)
                if ln is not None:
                    if ln.dirty:
                        self.backing.write(base, bytes(ln.data))
                    count += 1
            self.stats.flushes += count
            self.stats.flushed_bytes += count * CACHELINE
            return count

    def invalidate(self, off: int, n: int) -> int:
        """Drop lines without write-back (reader-side 'flush' of clean
        data). A dirty line here would LOSE data — in the paper's protocol
        readers only invalidate regions they do not own for writing; we
        write back defensively and count it."""
        with self.lock:
            count = 0
            for base in self._span(off, n):
                ln = self.lines.pop(base, None)
                if ln is not None:
                    if ln.dirty:
                        self.backing.write(base, bytes(ln.data))
                    count += 1
            self.stats.invalidates += count
            return count

    def fence(self) -> None:
        self.stats.fences += 1


class IncoherentPool(Pool):
    """Per-rank view of a backing pool through that rank's private cache."""

    def __init__(self, backing: Pool, cache: RankCache):
        self.backing = backing
        self.cache = cache
        self.size = backing.size

    def read(self, off: int, n: int) -> bytes:
        return self.cache.load(off, n)

    def write(self, off: int, data) -> None:
        self.cache.store(off, data)

    def readinto(self, off: int, dst) -> int:
        return self.cache.load_into(off, dst)

    # coherence surface
    def flush(self, off: int, n: int) -> int:
        return self.cache.flush(off, n)

    def invalidate(self, off: int, n: int) -> int:
        return self.cache.invalidate(off, n)

    def fence(self) -> None:
        self.cache.fence()
