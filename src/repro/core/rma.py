"""One-sided communication over shared memory (paper §3.2, §3.4).

A window is ONE arena object sized ``n_ranks * win_size`` laid out
contiguously across ranks (rank i's segment = [i*win_size, (i+1)*win_size)),
exactly the MPI_Win_allocate_shared layout — so any rank computes any other
rank's window address from local information only (base + rank * win_size).

``MPI_Put`` is a plain write_release into the target segment; ``MPI_Get`` a
read_acquire from it. No network, no protocol stack, no target-side
involvement — the entire point of the paper.

The buffer variants ``put_from`` / ``get_into`` move payloads as
memoryviews with exactly one copy each way (the same primitives the
pt2pt rendezvous path is built on); ``put_array`` / ``get_array`` are
ndarray-view wrappers over them — no ``tobytes``/``frombuffer().copy()``.

Synchronization (paper §3.4) lives in a companion object created with the
window: PSCW flag matrices, a seq-number fence barrier, and an RW window
lock — all atomics-free.
"""
from __future__ import annotations

import numpy as np

from repro.core.arena import Arena, ObjHandle
from repro.core.pool import Registration, as_u8
from repro.core.sync import PSCW, RWLock, SeqBarrier


class Window:
    """cMPI RMA window for a communicator of ``n_ranks``."""

    def __init__(self, arena: Arena, name: str, n_ranks: int, rank: int,
                 win_size: int, *, create: bool):
        self.arena = arena
        self.name = name
        self.n = n_ranks
        self.rank = rank
        self.win_size = win_size
        sync_bytes = (SeqBarrier.region_bytes(n_ranks)
                      + PSCW.region_bytes(n_ranks)
                      + RWLock.region_bytes(n_ranks) + 192)
        if create:
            self.data: ObjHandle = arena.create(f"{name}:w", n_ranks * win_size)
            self.sync: ObjHandle = arena.create(f"{name}:s", sync_bytes)
        else:
            self.data = arena.open(f"{name}:w")
            self.sync = arena.open(f"{name}:s")
        v = arena.view
        b = self.sync.offset
        fence_off = b
        b += SeqBarrier.region_bytes(n_ranks)
        b += (-b) % 64
        pscw_off = b
        b += PSCW.region_bytes(n_ranks)
        b += (-b) % 64
        lock_off = b
        self._fence = SeqBarrier(v, fence_off, n_ranks, rank,
                                 initialize=create)
        self._pscw = PSCW(v, pscw_off, n_ranks, rank, initialize=create)
        self._lock = RWLock(v, lock_off, n_ranks, rank, initialize=create)
        self._freed = False

    # ------------------------------------------------------------------
    # address arithmetic (the MPI_Win_allocate_shared layout)
    # ------------------------------------------------------------------
    def _addr(self, target: int, disp: int, n: int) -> int:
        if not 0 <= target < self.n:
            raise IndexError(f"target {target}")
        if disp < 0 or disp + n > self.win_size:
            raise IndexError(f"displacement [{disp}, {disp + n}) beyond "
                             f"window of {self.win_size}")
        return self.data.offset + target * self.win_size + disp

    # ------------------------------------------------------------------
    # RMA operations
    # ------------------------------------------------------------------
    def put(self, target: int, disp: int, data) -> None:
        self.put_from(target, disp, data)

    def put_from(self, target: int, disp: int, buf) -> None:
        """MPI_Put from any C-contiguous buffer-protocol object — the
        payload moves user buffer -> window exactly once."""
        mv = as_u8(buf)
        self.arena.view.write_release(self._addr(target, disp, len(mv)), mv)

    def get(self, target: int, disp: int, n: int) -> bytes:
        return self.arena.view.read_acquire(self._addr(target, disp, n), n)

    def get_into(self, target: int, disp: int, dst) -> int:
        """MPI_Get straight into a writable caller buffer; returns bytes
        read. The payload moves window -> destination exactly once.

        ``dst`` accepts the same destination kinds the matchbox posting
        path does (the pt2pt reply-path reuse): a plain writable buffer,
        a ``PoolBuffer``/``PoolView`` (pool-resident reply buffer —
        window -> pool in one protocol copy), or a ``Registration``
        (pinned user buffer; the get bypasses the shadow since the
        window is locally addressable)."""
        from repro.core.pt2pt import PoolBuffer, PoolView  # lazy: cycle
        if isinstance(dst, PoolBuffer):
            dst = PoolView(dst, 0, dst.nbytes)
        if isinstance(dst, PoolView):
            off = dst.buffer.offset + dst.off
            n = dst.nbytes
            src_addr = self._addr(target, disp, n)
            try:
                alias = self.arena.pool.memview(off, n)
            except TypeError:
                # no raw views (incoherent pool): bounce once, protocol-
                # correct on both legs
                self.arena.view.write_release(
                    off, self.arena.view.read_acquire(src_addr, n))
                return n
            return self.arena.view.read_acquire_into(src_addr, alias)
        mv = dst.mv if isinstance(dst, Registration) else as_u8(dst)
        return self.arena.view.read_acquire_into(
            self._addr(target, disp, len(mv)), mv)

    def put_array(self, target: int, disp: int, arr: np.ndarray) -> None:
        self.put_from(target, disp, np.ascontiguousarray(arr))

    def get_array(self, target: int, disp: int, shape, dtype) -> np.ndarray:
        out = np.empty(shape, dtype)
        self.get_into(target, disp, out)
        return out

    def accumulate(self, target: int, disp: int, arr: np.ndarray,
                   op=np.add) -> None:
        """MPI_Accumulate. CXL pooled memory has no cross-host atomics, so
        atomicity comes from the window lock (paper §3.5 motivation)."""
        self._lock.acquire_excl()
        try:
            cur = self.get_array(target, disp, arr.shape, arr.dtype)
            self.put_array(target, disp, op(cur, arr))
        finally:
            self._lock.release_excl()

    # ------------------------------------------------------------------
    # synchronization (paper §3.4)
    # ------------------------------------------------------------------
    def fence(self) -> None:
        """Collective epoch separator (MPI_Win_fence)."""
        self._fence.wait()

    # PSCW
    def post(self, origins: list[int]) -> None:
        self._pscw.post(origins)

    def start(self, targets: list[int]) -> None:
        self._pscw.start(targets)

    def complete(self, targets: list[int]) -> None:
        self._pscw.complete(targets)

    def wait(self, origins: list[int]) -> None:
        self._pscw.wait(origins)

    # lock-unlock
    def lock(self, shared: bool = False) -> None:
        if shared:
            self._lock.acquire_shared()
        else:
            self._lock.acquire_excl()

    def unlock(self, shared: bool = False) -> None:
        if shared:
            self._lock.release_shared()
        else:
            self._lock.release_excl()

    def free(self) -> None:
        """Collective MPI_Win_free: every rank calls it. Fences first so
        no rank is still inside an access/exposure epoch when the backing
        objects go away, then rank 0 destroys them. Idempotent on every
        rank (a second call is a no-op), and safe for non-root ranks that
        were mid-epoch — the fence orders their last RMA op before the
        destroy. Note: the destroy itself happens after the final sync
        point, so do not re-create a window under the same name without
        an external barrier."""
        if self._freed:
            return
        self._freed = True
        self._fence.wait()
        if self.rank == 0:
            try:
                self.arena.destroy(self.data)
                self.arena.destroy(self.sync)
            except FileNotFoundError:
                pass
