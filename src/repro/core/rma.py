"""One-sided communication over shared memory (paper §3.2, §3.4) — v2,
rebuilt on the shared schedule/progress core.

A window is ONE arena object sized ``n_ranks * win_size`` laid out
contiguously across ranks (rank i's segment = [i*win_size, (i+1)*win_size)),
exactly the MPI_Win_allocate_shared layout — so any rank computes any other
rank's window address from local information only (base + rank * win_size).

``MPI_Put`` is a plain write_release into the target segment; ``MPI_Get`` a
read_acquire from it. No network, no protocol stack, no target-side
involvement — the entire point of the paper. Every RMA byte is attributed
to a ``ProtocolStats.path_copied_bytes`` bucket:

  ``rma_put``     blocking put/put_from/put_array, rput chunks,
                  the accumulate write-back
  ``rma_get``     blocking get/get_into/get_array, rget chunks,
                  the accumulate read
  ``rma_notify``  the payload of ``put_notify`` (the notified-access
                  fast path — zero receiver-side copies by construction)
  ``rma_coll``    Put/Get nodes of the window collectives
                  (``allgather``/``bcast`` compiled as Schedule DAGs)

Request-based RMA (the foMPI recipe, Gerstenberger et al.): ``rput`` /
``rget`` compile a one-node ``rput``/``rget`` schedule, re-cut by the
standard chunking post-pass (``Comm(tuning="auto")`` chunk policy via
``chunk_bytes="auto"``), and return an engine-pumped ``CollRequest`` —
one chunk moves per progress tick, so a large transfer overlaps the
caller's compute and mixes freely with pt2pt requests in ``waitall``.
Completion is LOCAL completion: the source (rput) or destination (rget)
buffer is free for reuse; because the window is shared memory and every
chunk is a ``write_release``, local completion here also implies the
data is globally visible (``flush`` is still the portable spelling).

Notified access (foMPI's ``MPI_Put_notify`` analogue): ``put_notify``
writes the payload into the target segment and bumps a per-(target,
origin) monotonic u64 notification counter — single-writer, SeqBarrier
discipline, non-temporal stores only. The target's ``wait_notify``
spins on an ``nt_load`` (no payload copy, no matchbox, no descriptor)
and then consumes the data IN PLACE via ``local_view`` — the receiver
side of the transfer copies exactly zero payload bytes.

Synchronization (paper §3.4) lives in a companion object created with the
window: PSCW flag matrices, a seq-number fence barrier, an RW window
lock — all atomics-free — plus the notify counter matrix. Passive-target
epochs come in both MPI flavors: ``lock``/``unlock`` (exclusive or
shared) and ``lock_all``/``unlock_all`` with ``flush``/``flush_local``
completing outstanding requests mid-epoch.

Epoch semantics cheat-sheet (docs/architecture.md has the long form):

  fence        collective; separates epochs for everyone at once
  PSCW         post/start/complete/wait — pairwise exposure/access epochs
  lock(_all)   passive target: the target does not participate at all
  flush        completes OUTSTANDING requests (rput/rget) — an epoch
               boundary for data, not for synchronization
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.arena import Arena, ObjHandle
from repro.core.pool import Registration, as_u8
from repro.core.progress import CollRequest, _HeapBufs, _SchedExec
from repro.core.sched import compile_schedule
from repro.core.sync import PSCW, RWLock, SeqBarrier
from repro.core.trace import (EV_RMA_FENCE_BEGIN, EV_RMA_FENCE_END,
                              EV_RMA_FLUSH_BEGIN, EV_RMA_FLUSH_END,
                              EV_RMA_GET, EV_RMA_LOCK_ALL, EV_RMA_NOTIFY,
                              EV_RMA_PUT, EV_RMA_UNLOCK_ALL,
                              EV_RMA_WAIT_BEGIN, EV_RMA_WAIT_END, Tracer)

# windows built without a communicator (direct construction) trace here
_NULL_TRACER = Tracer(capacity=1, enabled=False)


def _notify_bytes(n_ranks: int) -> int:
    """The notify counter matrix: one u64 per (target, origin) pair.
    Word (t, o) is written ONLY by origin o (monotonic increment) and
    read ONLY by target t — the same single-writer discipline as the
    SeqBarrier words, so no atomics are needed."""
    return 8 * n_ranks * n_ranks


class Window:
    """cMPI RMA window for a communicator of ``n_ranks``.

    Construct via ``comm.win_allocate(name, win_size)`` (collective;
    wires the communicator in so the request-based operations and the
    window collectives can use the shared progress engine), or directly
    when only the blocking put/get surface is needed. ``free()`` is
    collective and idempotent.
    """

    # DynamicWindow flips this: no backing ``{name}:w`` arena object —
    # displacements address ATTACHED pool regions instead of segments
    dynamic = False

    def __init__(self, arena: Arena, name: str, n_ranks: int, rank: int,
                 win_size: int, *, create: bool, comm=None):
        self.arena = arena
        self.name = name
        self.n = n_ranks
        self.rank = rank
        self.win_size = win_size
        self._comm = comm
        self._tr = getattr(comm, "tracer", None) or _NULL_TRACER
        sync_bytes = (SeqBarrier.region_bytes(n_ranks)
                      + PSCW.region_bytes(n_ranks)
                      + RWLock.region_bytes(n_ranks)
                      + _notify_bytes(n_ranks)
                      + self._extra_sync_bytes(n_ranks) + 256)
        if create:
            self.data: ObjHandle | None = (
                None if self.dynamic
                else arena.create(f"{name}:w", n_ranks * win_size))
            self.sync: ObjHandle = arena.create(f"{name}:s", sync_bytes)
        else:
            self.data = None if self.dynamic else arena.open(f"{name}:w")
            self.sync = arena.open(f"{name}:s")
        v = arena.view
        b = self.sync.offset
        fence_off = b
        b += SeqBarrier.region_bytes(n_ranks)
        b += (-b) % 64
        pscw_off = b
        b += PSCW.region_bytes(n_ranks)
        b += (-b) % 64
        lock_off = b
        b += RWLock.region_bytes(n_ranks)
        b += (-b) % 64
        self._notify_off = b
        # subclass region (DynamicWindow's attach table) directly after
        # the notify matrix — 8*n*n bytes keeps it u64-aligned
        self._extra_off = self._notify_off + _notify_bytes(n_ranks)
        self._fence = SeqBarrier(v, fence_off, n_ranks, rank,
                                 initialize=create)
        self._pscw = PSCW(v, pscw_off, n_ranks, rank, initialize=create)
        self._lock = RWLock(v, lock_off, n_ranks, rank, initialize=create)
        if create:
            for i in range(n_ranks * n_ranks):
                v.nt_store_u64(self._notify_off + 8 * i, 0)
        # local notification bookkeeping (single-writer counters):
        # _notify_sent[t] = how many notifies I pushed toward target t;
        # _notify_seen[o] = how many of origin o's notifies I consumed
        self._notify_sent = [0] * n_ranks
        self._notify_seen = [0] * n_ranks
        # outstanding request-based operations, for flush(): (target,
        # CollRequest) pairs, pruned opportunistically
        self._reqs: list = []
        self._freed = False

    def _extra_sync_bytes(self, n_ranks: int) -> int:
        """Bytes a subclass appends to the sync object (laid out at
        ``self._extra_off``); the base window appends none."""
        return 0

    # ------------------------------------------------------------------
    # address arithmetic (the MPI_Win_allocate_shared layout)
    # ------------------------------------------------------------------
    def _addr(self, target: int, disp: int, n: int) -> int:
        if not 0 <= target < self.n:
            raise IndexError(f"target {target}")
        if disp < 0 or disp + n > self.win_size:
            raise IndexError(f"displacement [{disp}, {disp + n}) beyond "
                             f"window of {self.win_size}")
        return self.data.offset + target * self.win_size + disp

    def _notify_word(self, target: int, origin: int) -> int:
        return self._notify_off + 8 * (target * self.n + origin)

    def _require_comm(self):
        if self._comm is None:
            raise RuntimeError(
                "this Window has no communicator attached — create it "
                "via comm.win_allocate() to use request-based RMA and "
                "window collectives")
        return self._comm

    # ------------------------------------------------------------------
    # engine hooks: how a window-bound _SchedExec executes Put/Get nodes
    # ------------------------------------------------------------------
    def _exec_put(self, target: int, disp: int, src,
                  path: str = "rma_coll") -> None:
        mv = as_u8(src)
        n = len(mv)
        self.arena.view.write_release(self._addr(target, disp, n), mv)
        self.arena.view.count_path(path, n)
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_RMA_PUT, target, n)

    def _exec_get(self, target: int, disp: int, dst,
                  path: str = "rma_coll") -> int:
        mv = as_u8(dst)
        n = self.arena.view.read_acquire_into(
            self._addr(target, disp, len(mv)), mv)
        self.arena.view.count_path(path, n)
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_RMA_GET, target, n)
        return n

    # ------------------------------------------------------------------
    # blocking RMA operations
    # ------------------------------------------------------------------
    def put(self, target: int, disp: int, data) -> None:
        """MPI_Put: store ``data`` into rank ``target``'s segment at
        byte displacement ``disp``. Blocking and remotely visible on
        return (write_release). Counts the payload under
        ``path_copied_bytes["rma_put"]``. Epoch precondition: inside
        any access epoch (fence/PSCW start/lock/lock_all) covering
        ``target``."""
        self.put_from(target, disp, data)

    def put_from(self, target: int, disp: int, buf) -> None:
        """``put`` from any C-contiguous buffer-protocol object — the
        payload moves user buffer -> window exactly once."""
        self._exec_put(target, disp, buf, path="rma_put")

    def get(self, target: int, disp: int, n: int) -> bytes:
        """MPI_Get: load ``n`` bytes from rank ``target``'s segment at
        ``disp``. Blocking; returns fresh ``bytes``. Counts under
        ``path_copied_bytes["rma_get"]``. Same epoch preconditions as
        ``put``."""
        out = self.arena.view.read_acquire(self._addr(target, disp, n), n)
        self.arena.view.count_path("rma_get", n)
        return out

    def get_into(self, target: int, disp: int, dst) -> int:
        """MPI_Get straight into a writable caller buffer; returns bytes
        read. The payload moves window -> destination exactly once.

        ``dst`` accepts the same destination kinds the matchbox posting
        path does (the pt2pt reply-path reuse): a plain writable buffer,
        a ``PoolBuffer``/``PoolView`` (pool-resident reply buffer —
        window -> pool in one protocol copy), or a ``Registration``
        (pinned user buffer; the get bypasses the shadow since the
        window is locally addressable). Counts the payload under
        ``path_copied_bytes["rma_get"]``."""
        from repro.core.pt2pt import PoolBuffer, PoolView  # lazy: cycle
        v = self.arena.view
        if isinstance(dst, PoolBuffer):
            dst = PoolView(dst, 0, dst.nbytes)
        if isinstance(dst, PoolView):
            off = dst.buffer.offset + dst.off
            n = dst.nbytes
            src_addr = self._addr(target, disp, n)
            try:
                alias = self.arena.pool.memview(off, n)
            except TypeError:
                # no raw views (incoherent pool): bounce once, protocol-
                # correct on both legs
                v.write_release(off, v.read_acquire(src_addr, n))
                v.count_path("rma_get", n)
                return n
            n = v.read_acquire_into(src_addr, alias)
            v.count_path("rma_get", n)
            return n
        mv = dst.mv if isinstance(dst, Registration) else as_u8(dst)
        return self._exec_get(target, disp, mv, path="rma_get")

    def put_array(self, target: int, disp: int, arr: np.ndarray) -> None:
        """``put`` an ndarray (made contiguous if needed)."""
        self.put_from(target, disp, np.ascontiguousarray(arr))

    def get_array(self, target: int, disp: int, shape, dtype) -> np.ndarray:
        """``get`` into a fresh ndarray of ``shape``/``dtype``."""
        out = np.empty(shape, dtype)
        self.get_into(target, disp, out)
        return out

    def accumulate(self, target: int, disp: int, arr: np.ndarray,
                   op=np.add) -> None:
        """MPI_Accumulate. CXL pooled memory has no cross-host atomics, so
        atomicity comes from the window lock (paper §3.5 motivation) —
        the read-op-write runs under the EXCLUSIVE window lock and is
        atomic against any other locked access. Counts one ``rma_get``
        plus one ``rma_put`` of the payload. Do not call while already
        holding the window lock (not reentrant).

        Thin blocking wrapper over :meth:`raccumulate` on comm-attached
        windows; a window built without a communicator falls back to
        the synchronous read-op-write (no engine to pump)."""
        if self._comm is None:
            self._lock.acquire_excl()
            try:
                cur = self.get_array(target, disp, arr.shape, arr.dtype)
                self.put_array(target, disp, op(cur, arr))
            finally:
                self._lock.release_excl()
            return
        self.raccumulate(target, disp, arr, op=op).wait()

    def raccumulate(self, target: int, disp: int, arr: np.ndarray,
                    op=np.add, *, chunk_bytes="auto") -> CollRequest:
        """Request-based MPI_Raccumulate: the engine-pumped spelling of
        ``accumulate``. Compiles a three-node ``raccumulate`` schedule
        (GetOp target region -> ReduceOp with the local operand -> PutOp
        the result back), re-cut by the standard chunking post-pass, and
        returns a ``CollRequest`` with the same local-completion/flush
        semantics as ``rput`` — one chunk's read-modify-write per engine
        tick, so a large accumulate overlaps the caller's compute
        instead of stalling the progress engine for the whole reduction.

        Atomicity: the EXCLUSIVE window lock is acquired when the
        request is issued and released when it completes, so the whole
        read-modify-write stays atomic against any other locked access —
        but the lock is held until the request finishes: complete it
        promptly (``wait()``/``flush``/engine pumping), and do not issue
        one while already holding the window lock (not reentrant, like
        ``accumulate``). Counts Get chunks under
        ``path_copied_bytes["rma_get"]`` and Put chunks under
        ``["rma_put"]`` — the same buckets as the blocking form. Do not
        modify ``arr`` before completion. Needs a comm-attached window
        (``comm.win_allocate``)."""
        comm = self._require_comm()
        from repro.core.collectives import _resolve_chunk  # lazy: cycle
        arr = np.ascontiguousarray(arr)
        u8 = arr.reshape(-1).view(np.uint8)
        nbytes = u8.size
        self._addr(target, disp, nbytes)     # bounds check BEFORE locking
        cb = _resolve_chunk(comm, chunk_bytes, nbytes)
        sched = compile_schedule(comm, "raccumulate", nbytes,
                                 itemsize=arr.dtype.itemsize,
                                 root=target, chunk_bytes=cb)
        bufs = _HeapBufs({1: sched.slot_sizes.get(1, nbytes)})
        bufs.alias(0, u8)
        self._lock.acquire_excl()

        def fin(_b, n=nbytes):
            # runs in _SchedExec._complete's try/finally after the last
            # node retired; every node is LOCAL (bounds pre-checked, no
            # wire requests), so abort-without-finalize cannot strand
            # the lock
            self._lock.release_excl()
            return n

        ex = _SchedExec(comm, sched, bufs, 0, dtype=arr.dtype, op=op,
                        win=self, win_disp=disp, rma_budget=1,
                        rma_path_put="rma_put", rma_path_get="rma_get",
                        finalize=fin)
        comm._engine.add_coll(ex)
        req = CollRequest(comm, ex)
        self._track(target, req)
        return req

    def local_view(self, disp: int, nbytes: int) -> memoryview:
        """Writable memoryview alias of THIS rank's own window segment
        — the in-place consumption path for notified access (read the
        payload where the origin's ``put_notify`` left it: zero
        receiver-side copies, and none counted). Raises ``TypeError``
        when the backing pool cannot hand out raw views (incoherent
        test pools) — fall back to ``get_into`` there."""
        return self.arena.pool.memview(self._addr(self.rank, disp,
                                                  nbytes), nbytes)

    # ------------------------------------------------------------------
    # request-based RMA (rput/rget — local-completion requests)
    # ------------------------------------------------------------------
    def rput(self, target: int, disp: int, src, *,
             chunk_bytes="auto") -> CollRequest:
        """Request-based put: returns an engine-pumped ``CollRequest``.

        The payload is compiled as a one-node ``rput`` schedule and
        re-cut by the standard chunking post-pass (``chunk_bytes="auto"``
        follows the communicator's tuned chunk policy; pass ``None`` to
        force one monolithic store, or an int byte size). One chunk
        moves per engine tick, so the transfer overlaps compute between
        ``rput`` and ``wait()`` and mixes with pt2pt requests in
        ``comm.waitall``. LOCAL completion: when the request is done the
        source buffer is reusable — and, window memory being shared, the
        data is also already visible at the target (``flush`` is the
        portable spelling of that guarantee). Do not modify ``src``
        before completion. Counts chunks under
        ``path_copied_bytes["rma_put"]``. Needs a comm-attached window
        (``comm.win_allocate``)."""
        comm = self._require_comm()
        from repro.core.collectives import _resolve_chunk  # lazy: cycle
        u8 = np.frombuffer(as_u8(src), np.uint8)
        nbytes = u8.size
        self._addr(target, disp, nbytes)     # bounds check up front
        cb = _resolve_chunk(comm, chunk_bytes, nbytes)
        sched = compile_schedule(comm, "rput", nbytes, root=target,
                                 chunk_bytes=cb)
        bufs = _HeapBufs({})
        bufs.alias(0, u8)
        ex = _SchedExec(comm, sched, bufs, 0, win=self, win_disp=disp,
                        rma_path="rma_put", rma_budget=1,
                        finalize=lambda b: nbytes)
        comm._engine.add_coll(ex)
        req = CollRequest(comm, ex)
        self._track(target, req)
        return req

    def rget(self, target: int, disp: int, dst, *,
             chunk_bytes="auto") -> CollRequest:
        """Request-based get into a writable buffer (ndarray, bytearray,
        memoryview or ``Registration``): the chunked mirror of ``rput``.
        On completion ``dst`` holds the data (``wait()`` also returns
        it). Counts chunks under ``path_copied_bytes["rma_get"]``."""
        comm = self._require_comm()
        from repro.core.collectives import _resolve_chunk  # lazy: cycle
        mv = dst.mv if isinstance(dst, Registration) else as_u8(dst)
        if mv.readonly:
            raise ValueError("rget needs a writable destination")
        u8 = np.frombuffer(mv, np.uint8)
        nbytes = u8.size
        self._addr(target, disp, nbytes)
        cb = _resolve_chunk(comm, chunk_bytes, nbytes)
        sched = compile_schedule(comm, "rget", nbytes, root=target,
                                 chunk_bytes=cb)
        bufs = _HeapBufs({})
        bufs.alias(0, u8)
        ex = _SchedExec(comm, sched, bufs, 0, win=self, win_disp=disp,
                        rma_path="rma_get", rma_budget=1,
                        finalize=lambda b: dst)
        comm._engine.add_coll(ex)
        req = CollRequest(comm, ex)
        self._track(target, req)
        return req

    def _track(self, target: int, req: CollRequest) -> None:
        self._reqs = [(t, r) for t, r in self._reqs if not r.done]
        self._reqs.append((target, req))

    # ------------------------------------------------------------------
    # notified access (foMPI's put_notify analogue)
    # ------------------------------------------------------------------
    def notify(self, target: int) -> None:
        """Bump this origin's notification counter at ``target`` (one
        non-temporal u64 store — no payload, no copies counted). Use
        after ``rput(...).wait()`` + data already in place, or let
        ``put_notify`` pair it with the payload write."""
        self._notify_sent[target] += 1
        self.arena.view.nt_store_u64(
            self._notify_word(target, self.rank),
            self._notify_sent[target])

    def put_notify(self, target: int, disp: int, data) -> None:
        """Notified put: store ``data`` into ``target``'s segment, then
        bump the (target, origin) notification counter the target's
        ``wait_notify`` spins on. The payload moves exactly once
        (origin -> window, counted under
        ``path_copied_bytes["rma_notify"]``); the target consumes it IN
        PLACE via ``local_view`` — the receiver side copies zero bytes,
        deterministically (no matchbox, no descriptor, no drain). The
        counter is monotonic and single-writer (only this origin writes
        this word), so back-to-back notifies queue naturally — but
        successive payloads to the SAME displacement overwrite, so wait
        for the consumer (e.g. a reply notify) before reusing a slot."""
        mv = as_u8(data)
        n = len(mv)
        self.arena.view.write_release(self._addr(target, disp, n), mv)
        self.arena.view.count_path("rma_notify", n)
        self.notify(target)
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_RMA_NOTIFY, target, n)

    def test_notify(self, origin: int) -> int:
        """Number of UNCONSUMED notifications from ``origin`` (does not
        consume; one nt_load)."""
        cur = self.arena.view.nt_load_u64(
            self._notify_word(self.rank, origin))
        return cur - self._notify_seen[origin]

    def wait_notify(self, origin: int, *, count: int = 1,
                    timeout: float | None = 30.0) -> int:
        """Block until ``count`` notifications from ``origin`` arrived;
        consumes and returns them. Spins on one non-temporal load —
        zero payload copies on this side — while pumping the attached
        communicator's progress engine (if any) so outstanding requests
        keep moving."""
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_RMA_WAIT_BEGIN, origin)
        t0 = time.monotonic()
        while True:
            pending = self.test_notify(origin)
            if pending >= count:
                self._notify_seen[origin] += count
                if tr.enabled:
                    tr.emit(EV_RMA_WAIT_END, origin)
                return count
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(
                    f"wait_notify: {pending}/{count} notifications "
                    f"from rank {origin}")
            if self._comm is not None:
                self._comm._progress()
            time.sleep(0)

    # ------------------------------------------------------------------
    # window collectives (RMA-based, compiled as Schedule DAGs)
    # ------------------------------------------------------------------
    def iallgather(self, shard: np.ndarray, *,
                   chunk_bytes=None) -> CollRequest:
        """Nonblocking get-based allgather over the window: each rank
        publishes its shard into its OWN segment (disp 0), then every
        rank GETS every other segment directly — payloads never ride
        the wire, only zero-byte ready/done tokens do (2(n-1) empty
        messages). ``wait()`` returns the rank-ordered flat array.
        Needs ``shard.nbytes <= win_size``; Put/Get bytes land in
        ``path_copied_bytes["rma_coll"]``. Collective: all ranks call
        with equal-size shards, in the same order relative to every
        other collective on this communicator (shared tag sequence)."""
        comm = self._require_comm()
        from repro.core.collectives import (_launch, _resolve_chunk,
                                            immediate)
        shard = np.ascontiguousarray(shard)
        per_b, dtype = shard.nbytes, shard.dtype
        if per_b > self.win_size:
            raise ValueError(f"shard of {per_b} B exceeds window "
                             f"segment of {self.win_size} B")
        if comm.size == 1:
            return immediate(comm, shard.reshape(-1).copy())
        cb = _resolve_chunk(comm, chunk_bytes, per_b)
        sched = compile_schedule(comm, "allgather_get", per_b,
                                 shard.dtype.itemsize, chunk_bytes=cb)
        bufs = _HeapBufs(sched.slot_sizes)
        bufs.fill_at(0, comm.rank * per_b, shard)
        fin = lambda b: np.array(b.ndview(sched.result, dtype))  # noqa: E731
        return _launch(comm, sched, bufs, dtype, None, fin, win=self)

    def allgather(self, shard: np.ndarray) -> np.ndarray:
        """Blocking wrapper over ``iallgather``."""
        return self.iallgather(shard).wait()

    def ibcast(self, arr: np.ndarray, root: int = 0, *,
               chunk_bytes=None) -> CollRequest:
        """Nonblocking put-based binomial-tree bcast over the window:
        each parent PUTS the payload into its child's own segment and
        follows with a zero-byte token; the child lands it from its
        segment into ``arr`` IN PLACE and forwards. ``arr`` must be a
        C-contiguous ndarray of identical shape/dtype on every rank
        (MPI bcast-known semantics). Chunked, a child forwards chunk c
        the moment chunk c landed — the pipelined tree. Needs
        ``arr.nbytes <= win_size``. Same calling-order contract as
        ``iallgather``."""
        comm = self._require_comm()
        from repro.core.collectives import (_launch, _resolve_chunk,
                                            immediate)
        if not (isinstance(arr, np.ndarray) and arr.flags.c_contiguous):
            raise ValueError("ibcast needs a C-contiguous ndarray "
                             "(the payload is delivered in place)")
        if arr.nbytes > self.win_size:
            raise ValueError(f"payload of {arr.nbytes} B exceeds window "
                             f"segment of {self.win_size} B")
        if comm.size == 1:
            return immediate(comm, arr)
        cb = _resolve_chunk(comm, chunk_bytes, arr.nbytes)
        sched = compile_schedule(comm, "bcast_put", arr.nbytes,
                                 arr.dtype.itemsize, root=root,
                                 chunk_bytes=cb)
        bufs = _HeapBufs({})                 # slot 0 IS the user array
        bufs.alias(0, arr)
        return _launch(comm, sched, bufs, arr.dtype, None,
                       lambda b: arr, win=self)

    def bcast(self, arr: np.ndarray, root: int = 0) -> np.ndarray:
        """Blocking wrapper over ``ibcast``."""
        return self.ibcast(arr, root).wait()

    # ------------------------------------------------------------------
    # synchronization (paper §3.4)
    # ------------------------------------------------------------------
    def fence(self) -> None:
        """Collective epoch separator (MPI_Win_fence): completes this
        rank's outstanding requests (local flush), then joins the
        seq-number barrier. On return, every rank's RMA ops from the
        previous epoch are globally visible."""
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_RMA_FENCE_BEGIN)
        self.flush()
        self._fence.wait()
        if tr.enabled:
            tr.emit(EV_RMA_FENCE_END)

    # PSCW
    def post(self, origins: list[int]) -> None:
        """Open an EXPOSURE epoch toward ``origins`` (MPI_Win_post):
        they may access this rank's segment once their ``start``
        returns. Pair with ``wait``."""
        self._pscw.post(origins)

    def start(self, targets: list[int]) -> None:
        """Open an ACCESS epoch toward ``targets`` (MPI_Win_start):
        blocks until each has posted. Pair with ``complete``."""
        self._pscw.start(targets)

    def complete(self, targets: list[int]) -> None:
        """Close the access epoch (MPI_Win_complete): flushes this
        rank's outstanding requests first so the targets observe
        everything issued inside the epoch."""
        self.flush()
        self._pscw.complete(targets)

    def wait(self, origins: list[int]) -> None:
        """Close the exposure epoch (MPI_Win_wait): returns once every
        origin called ``complete``."""
        self._pscw.wait(origins)

    # lock-unlock (passive target)
    def lock(self, shared: bool = False) -> None:
        """Passive-target epoch on the window lock (MPI_Win_lock;
        window-global, not per-rank): exclusive by default, ``shared``
        for concurrent readers/accumulators. The target rank does not
        participate."""
        if shared:
            self._lock.acquire_shared()
        else:
            self._lock.acquire_excl()

    def unlock(self, shared: bool = False) -> None:
        """Close a ``lock`` epoch; flushes outstanding requests first
        (MPI unlock completion semantics)."""
        self.flush()
        if shared:
            self._lock.release_shared()
        else:
            self._lock.release_excl()

    def lock_all(self) -> None:
        """Passive-target epoch on ALL ranks at once (MPI_Win_lock_all:
        shared mode by definition — concurrent lock_all epochs on
        different ranks proceed in parallel; exclusive access still
        goes through ``lock()``). Complete individual transfers inside
        the epoch with ``flush``/``flush_local``."""
        self._lock.acquire_shared()
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_RMA_LOCK_ALL)

    def unlock_all(self) -> None:
        """Close the ``lock_all`` epoch: flushes every outstanding
        request, then releases the shared lock."""
        self.flush()
        self._lock.release_shared()
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_RMA_UNLOCK_ALL)

    def flush(self, target: int | None = None,
              timeout: float | None = 60.0) -> None:
        """Complete outstanding ``rput``/``rget`` requests to ``target``
        (all targets when ``None``), pumping the progress engine. On a
        shared-memory window remote completion and local completion
        coincide — when ``flush`` returns, the data IS in the target
        segment (each chunk was a write_release)."""
        tr = self._tr
        tgt = -1 if target is None else target
        if tr.enabled:
            tr.emit(EV_RMA_FLUSH_BEGIN, tgt)
        keep = []
        for t, r in self._reqs:
            if target is None or t == target:
                r.wait(timeout)
            elif not r.done:
                keep.append((t, r))
        self._reqs = keep
        if tr.enabled:
            tr.emit(EV_RMA_FLUSH_END, tgt)

    def flush_local(self, target: int | None = None,
                    timeout: float | None = 60.0) -> None:
        """MPI_Win_flush_local: completes the LOCAL side (source/dest
        buffers reusable). Identical to ``flush`` here — shared-memory
        chunks are remotely visible the instant they complete locally —
        kept as a distinct spelling so programs stay portable to
        transports where the two differ."""
        self.flush(target, timeout)

    def free(self) -> None:
        """Collective MPI_Win_free: every rank calls it. Completes this
        rank's outstanding requests, fences so no rank is still inside
        an access/exposure epoch when the backing objects go away, then
        rank 0 destroys them. Idempotent on every rank (a second call
        is a no-op), and safe for ranks that are mid-epoch — a held
        lock or an un-waited PSCW epoch is plain shared state that dies
        with the sync object, and the fence orders every rank's last
        RMA op before the destroy. Note: the destroy itself happens
        after the final sync point, so do not re-create a window under
        the same name without an external barrier."""
        if self._freed:
            return
        self._freed = True
        self.flush()
        self._fence.wait()
        if self.rank == 0:
            try:
                if self.data is not None:
                    self.arena.destroy(self.data)
                self.arena.destroy(self.sync)
            except FileNotFoundError:
                pass


class DynamicWindow(Window):
    """MPI_Win_create_dynamic analogue: a window with NO backing arena
    object — displacements are ABSOLUTE pool offsets into regions the
    owning rank has ``attach``-ed, so an existing pool-resident buffer
    (a ``PoolBuffer`` KV page, an ``ObjHandle``) is exposed one-sided
    WITHOUT copying it into a window arena. The whole pool being one
    flat shared mapping is exactly MPI's dynamic-window absolute-address
    model: ``attach`` returns the region's pool offset, peers use that
    offset as ``disp`` in put/get/rput/rget/raccumulate.

    The attach table lives in the shared sync object: per-rank rows of
    ``attach_slots`` (offset u64, len u64) entries, single-writer (only
    the owning rank stores its row) like the notify matrix — so
    ``_addr`` gives REAL remote bounds checking by scanning the target's
    published row (an unattached or detached address raises
    ``IndexError``, the same contract as a static window's bounds
    check). Publication order is offset-then-len and detach tombstones
    the len word, so a concurrent reader never sees a torn live entry.
    Attach/detach are pure nt-word stores: no payload moves, nothing is
    counted in ``ProtocolStats`` (regression-tested).

    The full sync surface (fence/PSCW/lock/notify) and the request-based
    operations work unchanged; the window COLLECTIVES
    (``iallgather``/``ibcast``) need per-rank segments and therefore a
    ``win_allocate`` window. ``local_view(disp, nbytes)`` aliases any
    region attached by THIS rank. Construct via
    ``comm.win_create_dynamic(name)``."""

    dynamic = True

    def __init__(self, arena: Arena, name: str, n_ranks: int, rank: int,
                 *, create: bool, comm=None, attach_slots: int = 32):
        if attach_slots < 1:
            raise ValueError(f"attach_slots must be >= 1, "
                             f"got {attach_slots}")
        self._attach_slots = attach_slots
        super().__init__(arena, name, n_ranks, rank, 0, create=create,
                         comm=comm)
        self._attach_off = self._extra_off
        # local mirror of this rank's row: slot -> (offset, len)
        self._mine: list = [None] * attach_slots
        if create:
            v = arena.view
            for i in range(2 * n_ranks * attach_slots):
                v.nt_store_u64(self._attach_off + 8 * i, 0)

    def _extra_sync_bytes(self, n_ranks: int) -> int:
        return 16 * n_ranks * self._attach_slots

    def _row(self, rank: int) -> int:
        return self._attach_off + 16 * self._attach_slots * rank

    @staticmethod
    def _resolve_region(buf) -> tuple[int, int]:
        """(pool offset, nbytes) of an attachable object: PoolBuffer,
        PoolView, ObjHandle, an ``(offset, nbytes)`` pair, or anything
        with ``.offset`` and ``.nbytes``/``.size``."""
        from repro.core.pt2pt import PoolBuffer, PoolView  # lazy: cycle
        if isinstance(buf, PoolView):
            return buf.buffer.offset + buf.off, buf.nbytes
        if isinstance(buf, PoolBuffer):
            return buf.offset, buf.nbytes
        if isinstance(buf, tuple) and len(buf) == 2:
            return int(buf[0]), int(buf[1])
        off = getattr(buf, "offset", None)
        n = getattr(buf, "nbytes", getattr(buf, "size", None))
        if off is None or n is None:
            raise TypeError(
                f"cannot attach {type(buf).__name__}: need a pool-"
                f"resident object (PoolBuffer/PoolView/ObjHandle) or "
                f"an (offset, nbytes) pair")
        return int(off), int(n)

    def attach(self, buf) -> int:
        """MPI_Win_attach: publish a pool-resident region so every rank
        may target it. Returns the region's absolute pool offset — the
        ``disp`` peers pass to put/get/rput/rget. Zero payload copies;
        reuses tombstoned (detached) entries. Raises ``RuntimeError``
        when the per-rank table (``attach_slots`` entries) is full."""
        off, nbytes = self._resolve_region(buf)
        if nbytes <= 0:
            raise ValueError(f"cannot attach empty region ({nbytes} B)")
        v = self.arena.view
        base = self._row(self.rank)
        for k in range(self._attach_slots):
            if self._mine[k] is None:
                # offset first, len last: the len store PUBLISHES the
                # entry, so a remote scan never sees a torn live row
                v.nt_store_u64(base + 16 * k, off)
                v.nt_store_u64(base + 16 * k + 8, nbytes)
                self._mine[k] = (off, nbytes)
                return off
        raise RuntimeError(
            f"attach table full ({self._attach_slots} regions attached "
            f"by rank {self.rank}); detach one or raise attach_slots")

    def detach(self, addr: int) -> None:
        """MPI_Win_detach: tombstone the entry attached at pool offset
        ``addr`` (one nt-word store — the len word goes to 0). The
        caller is responsible for quiescing peers first, as in MPI:
        a concurrent remote access to a detaching region races."""
        base = self._row(self.rank)
        for k, ent in enumerate(self._mine):
            if ent is not None and ent[0] == addr:
                self.arena.view.nt_store_u64(base + 16 * k + 8, 0)
                self._mine[k] = None
                return
        raise KeyError(f"no region attached at pool offset {addr}")

    def _addr(self, target: int, disp: int, n: int) -> int:
        """Resolve an absolute pool offset against ``target``'s
        PUBLISHED attach row — the dynamic window's bounds check. The
        scan costs ``attach_slots`` nt-loads; serving hot paths should
        cache the returned base and issue rput/rget against it (the
        engine re-validates per chunk, keeping detach visible)."""
        if not 0 <= target < self.n:
            raise IndexError(f"target {target}")
        if n < 0 or disp < 0:
            raise IndexError(f"bad region [{disp}, {disp + n})")
        v = self.arena.view
        base = self._row(target)
        for k in range(self._attach_slots):
            ln = v.nt_load_u64(base + 16 * k + 8)
            if not ln:
                continue
            off = v.nt_load_u64(base + 16 * k)
            if off <= disp and disp + n <= off + ln:
                return disp
        raise IndexError(
            f"[{disp}, {disp + n}) is not inside any region attached "
            f"by rank {target}")
