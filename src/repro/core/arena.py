"""CXL SHM Arena (paper §3.1): named shared-memory objects over a flat pool.

The dax device gives a flat byte range — no files, no lifecycle. The Arena
adds POSIX-SHM-like named objects without kernel support:

  [ header | bakery lock | free list | metadata (multi-level hash) | heap ]

* metadata is a FIXED-CAPACITY multi-level hash table: ``n_levels`` levels
  whose capacities are consecutive descending primes below ``base_slots``
  (the paper's production config: 10 levels under 200,000 -> 199,999 ...
  199,873, 1,999,260 slots total). A key probes exactly ONE slot per level
  (hash salted by level), so lookup is O(levels), parallelizable across
  levels, and there is no resizing and no probe chains — deleting a slot
  never breaks other keys' probes.
* the heap is a bump allocator with a bounded first-fit free list;
  every object is cacheline(64B)-aligned (paper §3.7: alignment makes the
  flush protocol and non-temporal accesses exact).
* creation/destruction are serialized by a Lamport BAKERY lock living in
  the pool itself — mutual exclusion with only per-rank single-writer
  slots, because CXL pooled memory provides no cross-host atomic RMW
  (paper §3.5). Lookup (open) is lock-free.

All accesses go through ``CoherentView`` so the same code is correct on an
incoherent pool (write_release / read_acquire / non-temporal control words).

APIs mirror the paper's Table 2: create / open / destroy / close /
init / finalize.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.coherence import CoherentView
from repro.core.pool import CACHELINE, Pool

MAGIC = b"CXLARENA"
VERSION = 1
SLOT_SIZE = 64
NAME_MAX = 47
MAX_RANKS = 64

_HDR_SIZE = 128
_BAKERY_CHOOSING = _HDR_SIZE                       # u8[MAX_RANKS]
_BAKERY_NUMBER = _BAKERY_CHOOSING + MAX_RANKS      # u64[MAX_RANKS]
_BAKERY_END = _BAKERY_NUMBER + 8 * MAX_RANKS

# header fields (absolute offsets)
_H_MAGIC = 0
_H_VERSION = 8
_H_NLEVELS = 12
_H_BASESLOTS = 16
_H_HEAP_OFF = 20
_H_HEAP_CUR = 28
_H_POOL_SIZE = 36
_H_FREELIST_CAP = 44
_H_FREELIST_LEN = 48
_H_FREELIST_OFF = 52
_H_META_OFF = 60


def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    if n % 2 == 0:
        return n == 2
    i = 3
    while i * i <= n:
        if n % i == 0:
            return False
        i += 2
    return True


def level_capacities(base_slots: int, n_levels: int) -> list[int]:
    """The ``n_levels`` largest primes <= base_slots, descending."""
    caps = []
    p = base_slots
    while len(caps) < n_levels and p >= 2:
        if _is_prime(p):
            caps.append(p)
        p -= 1
    if len(caps) < n_levels:
        raise ValueError(f"cannot find {n_levels} primes <= {base_slots}")
    return caps


def _hash_name(name: bytes, level: int) -> int:
    """Deterministic cross-process hash, salted per level (FNV-1a 64)."""
    h = 0xCBF29CE484222325 ^ (0x9E3779B97F4A7C15 * (level + 1)
                              & 0xFFFFFFFFFFFFFFFF)
    for b in name:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


@dataclass
class ObjHandle:
    name: str
    offset: int      # absolute offset of the object data in the pool
    size: int
    slot_off: int    # absolute offset of the metadata slot
    closed: bool = False


class ArenaFullError(RuntimeError):
    pass


class Arena:
    """One rank's mapping of the shared arena."""

    def __init__(self, pool: Pool, rank: int = 0, *, mode: str = "coherent",
                 n_levels: int = 10, base_slots: int = 251,
                 freelist_cap: int = 256, initialize: bool | None = None):
        if rank >= MAX_RANKS:
            raise ValueError(f"rank {rank} >= MAX_RANKS {MAX_RANKS}")
        self.pool = pool
        self.rank = rank
        self.view = CoherentView(pool, mode)
        v = self.view
        magic = v.read_acquire(_H_MAGIC, 8)
        if initialize is None:
            initialize = magic != MAGIC
        if initialize:
            caps = level_capacities(base_slots, n_levels)
            meta_off = _BAKERY_END + 16 * freelist_cap
            meta_off += (-meta_off) % CACHELINE
            meta_size = sum(caps) * SLOT_SIZE
            heap_off = meta_off + meta_size
            heap_off += (-heap_off) % CACHELINE
            if heap_off >= pool.size:
                raise ValueError(
                    f"pool of {pool.size}B too small: metadata alone needs "
                    f"{heap_off}B (base_slots={base_slots} x {n_levels} "
                    f"levels)")
            # zero bakery + freelist region
            v.write_release(_BAKERY_CHOOSING,
                            bytes(_BAKERY_END + 16 * freelist_cap
                                  - _BAKERY_CHOOSING))
            # zero the 'used' byte of every slot — pre-publication init,
            # no peer can observe the region yet
            for off in range(meta_off, meta_off + meta_size, SLOT_SIZE):
                v.raw_write(off, b"\x00")  # lint: raw-ok (init)
            hdr = bytearray(_HDR_SIZE)
            hdr[_H_VERSION:_H_VERSION + 4] = VERSION.to_bytes(4, "little")
            hdr[_H_NLEVELS:_H_NLEVELS + 4] = n_levels.to_bytes(4, "little")
            hdr[_H_BASESLOTS:_H_BASESLOTS + 4] = base_slots.to_bytes(4, "little")
            hdr[_H_HEAP_OFF:_H_HEAP_OFF + 8] = heap_off.to_bytes(8, "little")
            hdr[_H_HEAP_CUR:_H_HEAP_CUR + 8] = heap_off.to_bytes(8, "little")
            hdr[_H_POOL_SIZE:_H_POOL_SIZE + 8] = pool.size.to_bytes(8, "little")
            hdr[_H_FREELIST_CAP:_H_FREELIST_CAP + 4] = \
                freelist_cap.to_bytes(4, "little")
            hdr[_H_FREELIST_OFF:_H_FREELIST_OFF + 8] = \
                _BAKERY_END.to_bytes(8, "little")
            hdr[_H_META_OFF:_H_META_OFF + 8] = meta_off.to_bytes(8, "little")
            v.write_release(8, bytes(hdr[8:]))
            v.write_release(_H_MAGIC, MAGIC)   # magic last: publication
        else:
            hdr = bytearray(v.read_acquire(0, _HDR_SIZE))
            if bytes(hdr[:8]) != MAGIC:
                raise RuntimeError("arena not initialized")
            n_levels = int.from_bytes(hdr[_H_NLEVELS:_H_NLEVELS + 4], "little")
            base_slots = int.from_bytes(hdr[_H_BASESLOTS:_H_BASESLOTS + 4],
                                        "little")
            freelist_cap = int.from_bytes(
                hdr[_H_FREELIST_CAP:_H_FREELIST_CAP + 4], "little")
            caps = level_capacities(base_slots, n_levels)
        self.n_levels = n_levels
        self.base_slots = base_slots
        self.caps = caps
        self.freelist_cap = freelist_cap
        self.freelist_off = _BAKERY_END
        self.meta_off = int.from_bytes(
            v.read_acquire(_H_META_OFF, 8), "little")
        self.heap_off = int.from_bytes(
            v.read_acquire(_H_HEAP_OFF, 8), "little")
        # level start offsets
        self.level_off = []
        o = self.meta_off
        for c in caps:
            self.level_off.append(o)
            o += c * SLOT_SIZE

    # ------------------------------------------------------------------
    # bakery lock (atomics-free mutual exclusion in the pool)
    # ------------------------------------------------------------------
    def _lock(self) -> None:
        v = self.view
        r = self.rank
        v.nt_store_u8(_BAKERY_CHOOSING + r, 1)
        mx = 0
        for j in range(MAX_RANKS):
            mx = max(mx, v.nt_load_u64(_BAKERY_NUMBER + 8 * j))
        my = mx + 1
        v.nt_store_u64(_BAKERY_NUMBER + 8 * r, my)
        v.nt_store_u8(_BAKERY_CHOOSING + r, 0)
        for j in range(MAX_RANKS):
            if j == r:
                continue
            while v.nt_load_u8(_BAKERY_CHOOSING + j):
                time.sleep(0)
            while True:
                nj = v.nt_load_u64(_BAKERY_NUMBER + 8 * j)
                if nj == 0 or (nj, j) > (my, r):
                    break
                time.sleep(0)

    def _unlock(self) -> None:
        self.view.nt_store_u64(_BAKERY_NUMBER + 8 * self.rank, 0)

    # ------------------------------------------------------------------
    # slots
    # ------------------------------------------------------------------
    def _slot_off(self, name: bytes, level: int) -> int:
        idx = _hash_name(name, level) % self.caps[level]
        return self.level_off[level] + idx * SLOT_SIZE

    def _read_slot(self, off: int) -> tuple[int, bytes, int, int]:
        raw = self.view.read_acquire(off, SLOT_SIZE)
        used = raw[0]
        name = bytes(raw[1:1 + NAME_MAX]).rstrip(b"\x00")
        offset = int.from_bytes(raw[48:56], "little")
        size = int.from_bytes(raw[56:64], "little")
        return used, name, offset, size

    def _write_slot(self, off: int, name: bytes, offset: int,
                    size: int) -> None:
        raw = bytearray(SLOT_SIZE)
        raw[0] = 1
        raw[1:1 + len(name)] = name
        raw[48:56] = offset.to_bytes(8, "little")
        raw[56:64] = size.to_bytes(8, "little")
        self.view.write_release(off, bytes(raw))

    def _find(self, name: bytes) -> tuple[int, int, int] | None:
        """-> (slot_off, offset, size) or None. Probes one slot per level."""
        for lvl in range(self.n_levels):
            so = self._slot_off(name, lvl)
            used, sname, offset, size = self._read_slot(so)
            if used and sname == name:
                return so, offset, size
        return None

    # ------------------------------------------------------------------
    # allocation
    # ------------------------------------------------------------------
    def _freelist(self) -> list[tuple[int, int]]:
        n = self.view.nt_load_u32(_H_FREELIST_LEN)
        out = []
        for i in range(n):
            raw = self.view.read_acquire(self.freelist_off + 16 * i, 16)
            out.append((int.from_bytes(raw[:8], "little"),
                        int.from_bytes(raw[8:], "little")))
        return out

    def _freelist_write(self, entries: list[tuple[int, int]]) -> None:
        for i, (o, s) in enumerate(entries):
            self.view.write_release(
                self.freelist_off + 16 * i,
                o.to_bytes(8, "little") + s.to_bytes(8, "little"))
        self.view.nt_store_u32(_H_FREELIST_LEN, len(entries))

    def _alloc(self, size: int) -> int:
        size = size + (-size) % CACHELINE
        fl = self._freelist()
        for i, (o, s) in enumerate(fl):
            if s >= size:                      # first fit
                rest = s - size
                if rest >= CACHELINE:
                    fl[i] = (o + size, rest)
                else:
                    fl.pop(i)
                self._freelist_write(fl)
                return o
        cur = self.view.nt_load_u64(_H_HEAP_CUR)
        if cur + size > self.pool.size:
            raise ArenaFullError(
                f"heap exhausted: need {size}B at {cur}, pool {self.pool.size}")
        self.view.nt_store_u64(_H_HEAP_CUR, cur + size)
        return cur

    def _free(self, offset: int, size: int) -> None:
        size = size + (-size) % CACHELINE
        fl = self._freelist()
        if len(fl) < self.freelist_cap:
            fl.append((offset, size))
            self._freelist_write(fl)
        # else: leak (bounded metadata — the paper's arena never frees at all)

    # ------------------------------------------------------------------
    # public API (paper Table 2)
    # ------------------------------------------------------------------
    def create(self, name: str, size: int) -> ObjHandle:
        nb = name.encode()
        if not 0 < len(nb) <= NAME_MAX:
            raise ValueError(f"name must be 1..{NAME_MAX} bytes")
        if size <= 0:
            raise ValueError("size must be positive")
        self._lock()
        try:
            if self._find(nb) is not None:
                raise FileExistsError(f"object {name!r} exists")
            # claim the first free slot across levels
            for lvl in range(self.n_levels):
                so = self._slot_off(nb, lvl)
                used, _, _, _ = self._read_slot(so)
                if not used:
                    offset = self._alloc(size)
                    self._write_slot(so, nb, offset, size)
                    return ObjHandle(name, offset, size, so)
            raise ArenaFullError(
                f"all {self.n_levels} levels collide for {name!r}")
        finally:
            self._unlock()

    def open(self, name: str) -> ObjHandle:
        nb = name.encode()
        hit = self._find(nb)
        if hit is None:
            raise FileNotFoundError(f"object {name!r} not found")
        so, offset, size = hit
        return ObjHandle(name, offset, size, so)

    def destroy(self, handle: ObjHandle) -> None:
        self._lock()
        try:
            hit = self._find(handle.name.encode())
            if hit is None:
                raise FileNotFoundError(handle.name)
            so, offset, size = hit
            self.view.write_release(so, b"\x00")   # used = 0
            self._free(offset, size)
            handle.closed = True
        finally:
            self._unlock()

    def close(self, handle: ObjHandle) -> None:
        handle.closed = True      # local bookkeeping only (paper semantics)

    def finalize(self) -> None:
        pass

    # ------------------------------------------------------------------
    # data access through the coherence protocol
    # ------------------------------------------------------------------
    def write(self, handle: ObjHandle, off: int, data: bytes) -> None:
        if off < 0 or off + len(data) > handle.size:
            raise IndexError("write beyond object")
        self.view.write_release(handle.offset + off, data)

    def read(self, handle: ObjHandle, off: int, n: int) -> bytes:
        if off < 0 or off + n > handle.size:
            raise IndexError("read beyond object")
        return self.view.read_acquire(handle.offset + off, n)

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        used = 0
        for lvl in range(self.n_levels):
            base = self.level_off[lvl]
            for i in range(self.caps[lvl]):
                # advisory stats snapshot: stale reads are acceptable
                if self.view.raw_read(base + i * SLOT_SIZE,
                                      1)[0]:  # lint: raw-ok (stats)
                    used += 1
        return {
            "slots_total": sum(self.caps),
            "slots_used": used,
            "heap_used": self.view.nt_load_u64(_H_HEAP_CUR) - self.heap_off,
            "heap_total": self.pool.size - self.heap_off,
            "level_caps": list(self.caps),
        }


# paper production configuration (§3.7): ~2M slots
PAPER_ARENA = dict(n_levels=10, base_slots=200_000)
