"""The shared progress core: one cooperative engine per communicator.

Every outstanding non-blocking operation — pt2pt sends, posted receives,
rendezvous stager reclaim AND collective schedule executions — is owned
by this engine, and every ``test()`` / ``wait()`` / explicit
``comm.progress()`` turns it. That single rule is what makes the system
composable: a rank blocked in ``recv()`` still advances its neighbour's
``iallreduce``; compute injected between ``iallreduce`` start and
``wait`` needs only an occasional ``comm.progress()`` tick to keep
payloads moving (the overlap column in ``benchmarks/fig5_8_osu.py``).

Layout:

* ``ProgressEngine`` — the per-destination send FIFOs, per-source posted
  receive FIFOs and stager reclaim previously embedded in
  ``Communicator._progress``, plus the list of active schedule
  executions. ``tick()`` is reentrancy-guarded: nodes issued mid-tick
  (a schedule issuing ``isend``) are picked up on the next turn.
* ``_SchedExec`` — one execution of a compiled ``repro.core.sched``
  Schedule: dependency counts, ready queue, in-flight request map.
  Request completion CALLBACKS (``Request._on_done``) retire nodes and
  release their dependents; ``advance()`` issues whatever became ready.
  Receives are issued before sends at every step so pool-resident
  destinations publish their matchbox entries as early as possible.
* ``CollRequest`` — the user-facing handle ``comm.iallreduce`` & friends
  return: ``test()/wait()`` with MPI semantics, ``wait()`` yielding the
  collective's result.
* ``_HeapBufs`` / ``_ResidentBufs`` — the two buffer backends a
  schedule can bind to. Wire format is identical (same tags, sizes,
  rounds), so ranks may disagree on backend choice per collective and
  still interoperate — the same contract the hand-rolled loops kept.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Optional

import numpy as np

from repro.core.sched import (BufRef, CopyOp, GetOp, PutOp, RecvOp,
                              ReduceOp, Schedule, SendOp)
from repro.core.trace import (EV_SCHED_ABORT, EV_SCHED_BEGIN,
                              EV_SCHED_DONE, EV_SCHED_END,
                              EV_SCHED_ISSUE, EV_TICK, Tracer)

__all__ = ["ProgressEngine", "CollRequest", "waitall", "waitany",
           "testall"]

# executions driven by a comm that predates the tracer (tests building
# _SchedExec by hand) fall back to this always-disabled recorder
_NULL_TRACER = Tracer(capacity=1, enabled=False)


class ProgressEngine:
    """Cooperative progress for one communicator (no threads: progress
    happens inside the caller's test/wait/progress calls, the explicit
    MPI_Test/MPI_Wait model the paper keeps — §3.4)."""

    def __init__(self, comm):
        self.comm = comm
        # one FIFO per destination: a message's chunks must occupy the
        # pair queue CONTIGUOUSLY, so only the head request of each
        # destination is ever pumped
        self.send_fifo: dict[int, deque] = {}
        # posted receives, one FIFO per source (the MPI posted-receive
        # queue): the head drains the pair queue; non-heads may still
        # complete from parked messages or in-place posted deliveries
        self.recv_fifo: dict[int, deque] = {}
        # rendezvous stagers awaiting the receiver's ack
        self.stagers: list = []
        # active collective schedule executions
        self.colls: list[_SchedExec] = []
        self._in_tick = False

    # ------------------------------------------------------------------
    def tick(self) -> None:
        """One cooperative sweep: advance the head send of every
        destination, pump every posted receive, reclaim acked stagers,
        then advance every active collective execution. Reentrant calls
        (a schedule node issuing isend mid-tick) are no-ops."""
        if self._in_tick:
            return
        self._in_tick = True
        tr = self.comm.tracer
        t0 = 0
        if tr.enabled:
            # record only ticks with work in flight — idle spin turns
            # would evict every interesting record from the ring
            if (self.colls or self.stagers
                    or any(self.send_fifo.values())
                    or any(self.recv_fifo.values())):
                t0 = time.monotonic_ns()
        try:
            self._tick_sends()
            self._tick_recvs()
            if self.stagers:
                self._reclaim_stagers()
            if self.colls:
                for ex in list(self.colls):
                    ex.advance()
                    if ex.finished:
                        try:
                            self.colls.remove(ex)
                        except ValueError:
                            pass
        finally:
            self._in_tick = False
            if tr.enabled and t0:
                tr.emit(EV_TICK, time.monotonic_ns() - t0)

    def _tick_sends(self) -> None:
        for fifo in list(self.send_fifo.values()):
            while fifo:
                head = fifo[0]
                try:
                    next(head._gen)
                    break                    # blocked on queue space
                except StopIteration:
                    head._finish()
                    fifo.popleft()           # next message may start
                except BaseException as e:
                    # a failed send (e.g. ArenaFullError while staging)
                    # must not be reported done: record it on the
                    # request, unblock the FIFO, surface it to the
                    # caller that pumped progress
                    head._error = e
                    fifo.popleft()
                    raise

    def _tick_recvs(self) -> None:
        for src, fifo in list(self.recv_fifo.items()):
            while fifo and (fifo[0].done or fifo[0]._error is not None):
                fifo.popleft()
            if not fifo:
                continue
            # Only the effective HEAD of a pair's FIFO can drain the
            # pair queue; a non-head receive can complete solely from
            # PARKED payloads (out-of-order tag matches, salvages, self
            # sends). So the tick pumps the head always, and sweeps the
            # rest only while parked data exists — keeping the per-tick
            # cost O(sources), not O(posted receives). Chunk-granular
            # schedules pre-post dozens of sub-receives per peer; a
            # spin-wait that pumped every one of them each tick would
            # eat the pipelining it exists to drive.
            parked = self._parked_nonempty(src)
            for req in list(fifo) if parked else [fifo[0]]:
                if req.done or req._error is not None:
                    continue
                try:
                    next(req._gen)
                except StopIteration:
                    req._finish()            # matched passively
                except BaseException as e:
                    # a failed receive (e.g. truncation) is recorded on
                    # its own request — never surfaced to the innocent
                    # caller that happened to pump progress
                    req._error = e
            while fifo and (fifo[0].done or fifo[0]._error is not None):
                fifo.popleft()

    def _parked_nonempty(self, src: int) -> bool:
        park = getattr(self.comm, "_parked", None)
        if park is None:
            return True                      # unknown comm: pump all
        q = park.get(src)
        return bool(q)

    def _reclaim_stagers(self) -> None:
        v = self.comm.arena.view
        still = []
        for h in self.stagers:
            if v.nt_load_u8(h.offset):       # receiver ack'd the drain
                self.comm.arena.destroy(h)
            else:
                still.append(h)
        self.stagers[:] = still

    def add_coll(self, ex: "_SchedExec") -> None:
        self.colls.append(ex)
        ex.advance()                 # pre-post receives before returning


# --------------------------------------------------------------------------
# buffer backends
# --------------------------------------------------------------------------

class _HeapBufs:
    """Plain process-heap slots: sends are buffer-protocol views (eager
    or staged rendezvous on the wire), receives land via ``recv_into``.
    ``bind`` may alias a slot to a caller-owned array (ibcast receives
    straight into the user buffer — no round-buffer detour)."""

    resident = False

    def __init__(self, slot_sizes: dict[int, int]):
        self._slots: dict[int, np.ndarray] = {
            i: np.zeros(sz, np.uint8) for i, sz in slot_sizes.items()}
        self._owned = True               # release() may drop the slots

    @classmethod
    def from_slots(cls, slots: dict[int, np.ndarray]) -> "_HeapBufs":
        """Wrap CALLER-OWNED slot arrays without copying (persistent
        collectives keep their double-buffered sets across starts) —
        release() must leave them intact for the next iteration."""
        self = cls({})
        self._slots = slots
        self._owned = False
        return self

    def alias(self, slot: int, arr: np.ndarray) -> None:
        u8 = arr.reshape(-1).view(np.uint8)
        self._slots[slot] = u8

    def fill(self, slot: int, data: np.ndarray, pad_to: int = 0) -> None:
        u8 = data.reshape(-1).view(np.uint8)
        dst = self._slots[slot]
        dst[:u8.size] = u8
        if pad_to > u8.size:
            dst[u8.size:pad_to] = 0

    def fill_at(self, slot: int, off: int, data: np.ndarray) -> None:
        u8 = data.reshape(-1).view(np.uint8)
        self._slots[slot][off:off + u8.size] = u8

    def release(self) -> None:
        if self._owned:
            self._slots = {}

    def send_payload(self, ref: BufRef):
        return self._slots[ref.slot][ref.off:ref.off + ref.nbytes]

    def recv_dest(self, ref: BufRef):
        return self._slots[ref.slot][ref.off:ref.off + ref.nbytes]

    def ndview(self, ref: BufRef, dtype) -> np.ndarray:
        return self._slots[ref.slot][ref.off:ref.off + ref.nbytes] \
            .view(dtype)


class _ResidentBufs:
    """Pool-resident slots (PoolBuffers): sends are zero-copy PoolView
    slices, receives publish matchbox entries (posted rendezvous — the
    one-copy path). Buffers are leased from the communicator's round
    pool and returned at release, or owned outright (persistent
    collectives pass their own long-lived set)."""

    resident = True

    def __init__(self, bufs: dict[int, Any],
                 release_cb: Optional[Callable] = None):
        self._bufs = bufs
        self._release_cb = release_cb

    def fill(self, slot: int, data: np.ndarray, pad_to: int = 0) -> None:
        u8 = data.reshape(-1).view(np.uint8)
        mv = self._bufs[slot].view()
        mv[:u8.size] = u8
        if pad_to > u8.size:
            mv[u8.size:pad_to] = b"\0" * (pad_to - u8.size)

    def fill_at(self, slot: int, off: int, data: np.ndarray) -> None:
        u8 = data.reshape(-1).view(np.uint8)
        self._bufs[slot].view()[off:off + u8.size] = u8

    def send_payload(self, ref: BufRef):
        return self._bufs[ref.slot].slice(ref.off, ref.nbytes)

    def recv_dest(self, ref: BufRef):
        return self._bufs[ref.slot].slice(ref.off, ref.nbytes)

    def ndview(self, ref: BufRef, dtype) -> np.ndarray:
        pb = self._bufs[ref.slot]
        return np.frombuffer(pb.view()[ref.off:ref.off + ref.nbytes],
                             dtype=dtype)

    def release(self) -> None:
        if self._release_cb is not None:
            self._release_cb()
            self._release_cb = None


# --------------------------------------------------------------------------
# schedule execution
# --------------------------------------------------------------------------

class _SchedExec:
    """One run of a compiled Schedule over a bound buffer backend.

    ``bound_recvs`` (persistent mode) maps recv node idx -> an ALREADY
    POSTED Request from the round-synchronized pre-post handshake; those
    nodes skip issue entirely and complete when their request does.
    ``finalize`` runs once after the last node retires and produces
    ``result``.
    """

    def __init__(self, comm, sched: Schedule, bufs, tag_base: int,
                 dtype=None, op=None,
                 finalize: Optional[Callable] = None,
                 bound_recvs: Optional[dict[int, Any]] = None,
                 await_claim: float = 0.0, win=None, win_disp: int = 0,
                 rma_path: str = "rma_coll", rma_budget: int = 0,
                 rma_path_put: Optional[str] = None,
                 rma_path_get: Optional[str] = None):
        self.comm = comm
        self.sched = sched
        self.bufs = bufs
        self.tag_base = tag_base
        self.dtype = dtype
        self.op = op
        # one-sided bindings: Put/Get nodes execute against ``win`` at
        # node.disp + ``win_disp``; their payload bytes are attributed
        # to the ``rma_path`` ProtocolStats bucket. ``rma_budget`` > 0
        # caps Put/Get executions per advance() — a chunked rput/rget
        # then moves one chunk per engine tick instead of memcpy'ing
        # the whole payload inside the first test()/progress() call,
        # which is what lets it overlap the caller's compute.
        self.win = win
        self.win_disp = win_disp
        self.rma_path = rma_path
        # mixed-direction schedules (raccumulate's read-modify-write)
        # attribute their Get chunks and Put chunks to DIFFERENT
        # ProtocolStats buckets; plain rput/rget leave these None and
        # everything lands in ``rma_path``
        self.rma_path_put = rma_path_put or rma_path
        self.rma_path_get = rma_path_get or rma_path
        self.rma_budget = rma_budget
        # persistent cyclic schedules: seconds each send may wait for
        # its guaranteed (but possibly spilled) matchbox posting before
        # falling back to staged — see Communicator.isend(_await_claim)
        self.await_claim = await_claim
        self._finalize = finalize
        self.finished = False
        self.result = None
        self.error: Optional[BaseException] = None
        nodes = sched.nodes
        # flight recorder: one exec id + interned kind per execution so
        # hot-path records carry ints only; a chunked schedule's nodes
        # then render as per-chunk lanes keyed (exec, node idx)
        tr = getattr(comm, "tracer", _NULL_TRACER)
        self._tr = tr
        self._trace_exec = 0
        self._trace_kind = 0
        if tr.enabled:
            self._trace_exec = tr.next_exec_id()
            self._trace_kind = tr.intern(sched.kind)
            tr.emit(EV_SCHED_BEGIN, self._trace_exec, self._trace_kind,
                    len(nodes))
        self._n_left = len(nodes)
        self._pending = [len(nd.deps) for nd in nodes]
        self._dependents: list[list[int]] = [[] for _ in nodes]
        for nd in nodes:
            for d in nd.deps:
                self._dependents[d].append(nd.idx)
        self._ready: deque[int] = deque()
        # receives first: pool-resident destinations publish their
        # matchbox entries before any send of ours (or, symmetrically,
        # our peer's) goes looking for them
        for nd in nodes:
            if self._pending[nd.idx] == 0 and isinstance(nd, RecvOp):
                self._ready.append(nd.idx)
        for nd in nodes:
            if self._pending[nd.idx] == 0 and not isinstance(nd, RecvOp):
                self._ready.append(nd.idx)
        self._inflight: dict[int, Any] = {}
        self._bound = bound_recvs or {}
        for idx, req in self._bound.items():
            self._watch(idx, req)
        if not nodes:
            self._complete()

    # ------------------------------------------------------------------
    def _watch(self, idx: int, req) -> None:
        self._inflight[idx] = req
        if req.done:
            self._node_done(idx)
        else:
            req._on_done = lambda _r, i=idx: self._node_done(i)  # noqa: E731

    def _node_done(self, idx: int) -> None:
        self._inflight.pop(idx, None)
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_SCHED_DONE, self._trace_exec, idx)
        self._n_left -= 1
        for j in self._dependents[idx]:
            self._pending[j] -= 1
            if self._pending[j] == 0:
                self._ready.append(j)
        if self._n_left == 0:
            self._complete()

    def _complete(self) -> None:
        self.finished = True
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_SCHED_END, self._trace_exec)
        try:
            if self._finalize is not None:
                self.result = self._finalize(self.bufs)
        finally:
            self.bufs.release()

    def _abort(self, err: BaseException) -> None:
        """A node's request failed (e.g. truncation): cancel the
        schedule's other in-flight receives — retracting their matchbox
        postings and unlinking them from the posted-receive FIFOs, so
        no stale entry points into these buffers and no dead head
        receive parks later traffic. The buffer set is NOT returned to
        the round pool: a straggler send of the failed collective may
        still land in it, and recycling it would hand that write to an
        unrelated collective."""
        self.error = err
        tr = self._tr
        if tr.enabled:
            tr.emit(EV_SCHED_ABORT, self._trace_exec)
        for req in list(self._inflight.values()):
            if req.kind == "recv" and not req.done:
                req._on_done = None
                req.cancel()
        self._inflight.clear()
        try:
            self.comm._engine.colls.remove(self)
        except ValueError:
            pass

    def advance(self) -> None:
        """Issue every ready node. Local nodes (reduce/copy) retire
        immediately and may ready further nodes — the loop drains until
        quiescent. In-flight requests are checked for recorded errors
        so a truncated receive fails the collective, not a bystander."""
        if self.finished or self.error is not None:
            return
        for req in list(self._inflight.values()):
            if req._error is not None:
                self._abort(req._error)
                return
        rma_left = self.rma_budget
        tr = self._tr
        while self._ready:
            idx = self._ready.popleft()
            nd = self.sched.nodes[idx]
            if self.rma_budget and isinstance(nd, (PutOp, GetOp)):
                if rma_left == 0:
                    self._ready.appendleft(idx)   # next tick's chunk
                    break
                rma_left -= 1
            if idx in self._bound:
                continue     # pre-posted: completes via its callback
            if tr.enabled:
                tr.emit(EV_SCHED_ISSUE, self._trace_exec, idx)
            if isinstance(nd, RecvOp):
                req = self.comm.irecv_into(
                    nd.peer, self.bufs.recv_dest(nd.buf),
                    tag=self.tag_base + nd.round, _internal=True)
                self._watch(idx, req)
            elif isinstance(nd, SendOp):
                req = self.comm.isend(nd.peer,
                                      self.bufs.send_payload(nd.buf),
                                      tag=self.tag_base + nd.round,
                                      _internal=True,
                                      _await_claim=self.await_claim)
                self._watch(idx, req)
            elif isinstance(nd, ReduceOp):
                dst = self.bufs.ndview(nd.dst, self.dtype)
                src = self.bufs.ndview(nd.src, self.dtype)
                dst[...] = self.op(dst, src)
                self._node_done(idx)
            elif isinstance(nd, CopyOp):
                dst = self.bufs.ndview(nd.dst, np.uint8)
                src = self.bufs.ndview(nd.src, np.uint8)
                dst[...] = src
                self._node_done(idx)
            elif isinstance(nd, PutOp):
                self.win._exec_put(nd.target, self.win_disp + nd.disp,
                                   self.bufs.ndview(nd.buf, np.uint8),
                                   path=self.rma_path_put)
                self._node_done(idx)
            elif isinstance(nd, GetOp):
                self.win._exec_get(nd.target, self.win_disp + nd.disp,
                                   self.bufs.ndview(nd.buf, np.uint8),
                                   path=self.rma_path_get)
                self._node_done(idx)


_DEFAULT_TIMEOUT = object()       # sentinel: scale with schedule depth


class CollRequest:
    """Handle for a non-blocking collective (``comm.iallreduce`` and
    friends). ``test()`` pumps the shared progress engine; ``wait()``
    blocks until completion and returns the collective's result (the
    reduced array, the gathered flat array, ``None`` for ibarrier).
    The default ``wait`` timeout scales with the schedule's round
    count (30 s per round, the per-round budget the pre-engine
    blocking loops had). ``Schedule.rounds`` counts SUB-rounds on a
    chunked schedule, so a round that chunking turned into N chunk
    sub-rounds gets N budgets, not one — a multi-GB pipelined
    collective is no longer capped at the message-granular budget.
    Pass ``timeout=None`` to wait forever."""

    kind = "coll"

    def __init__(self, comm, ex: _SchedExec):
        self._comm = comm
        self._ex = ex

    @property
    def default_timeout(self) -> float:
        """30 s per (sub-)round — ``sched.rounds`` is the tag span, which
        chunking expands to the real message count."""
        return 30.0 * max(1, self._ex.sched.rounds)

    @property
    def done(self) -> bool:
        return self._ex.finished

    @property
    def error(self) -> Optional[BaseException]:
        return self._ex.error

    @property
    def result(self):
        return self._ex.result

    def test(self) -> bool:
        if self._ex.error is not None:
            raise self._ex.error
        if self._ex.finished:
            return True
        self._comm._progress()
        if self._ex.error is not None:
            raise self._ex.error
        return self._ex.finished

    def wait(self, timeout=_DEFAULT_TIMEOUT):
        if timeout is _DEFAULT_TIMEOUT:
            timeout = self.default_timeout
        t0 = time.monotonic()
        while not self.test():
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(
                    f"collective {self._ex.sched.kind} timed out")
            time.sleep(0)
        return self._ex.result


# --------------------------------------------------------------------------
# fair multi-request completion helpers (pt2pt, persistent, collective)
# --------------------------------------------------------------------------

def _tick_engines(reqs: list) -> None:
    """One tick per DISTINCT engine among the requests (mixed-comm
    request lists are legal): the engine completes every request kind
    in one sweep, so the per-request polls below never need to pump."""
    seen: list = []
    for r in reqs:
        eng = getattr(getattr(r, "_comm", None), "_engine", None)
        if eng is not None and all(eng is not e for e in seen):
            seen.append(eng)
            eng.tick()


def _req_done(r) -> bool:
    """Non-pumping completion poll (the engines were already ticked
    this sweep). Raises the request's recorded error, if any. Falls
    back to ``test()`` for request types without a ``done`` state
    (persistent requests delegate their error surfacing to it too)."""
    err = getattr(r, "error", None)
    if err is None:
        err = getattr(r, "_error", None)
    if err is not None:
        raise err
    done = getattr(r, "done", None)
    if done is None:
        return r.test()
    return bool(done)


def waitall(reqs: list, timeout: float | None = 60.0) -> None:
    """Complete every request, pumping the shared engine fairly: each
    sweep ticks each involved engine ONCE, then checks every
    still-pending request (mixed pt2pt / persistent / collective
    requests welcome) — no request starves behind an earlier one and
    no sweep re-pumps the engine per request."""
    t0 = time.monotonic()
    pending = list(reqs)
    while pending:
        _tick_engines(pending)
        pending = [r for r in pending if not _req_done(r)]
        if pending and timeout is not None \
                and time.monotonic() - t0 > timeout:
            raise TimeoutError(f"waitall: {len(pending)} pending")
        if pending:
            time.sleep(0)


def waitany(reqs: list, timeout: float | None = 60.0) -> tuple[int, Any]:
    """Block until ANY request completes; returns ``(index, request)``.
    Sweeps the whole list each turn — no request starves behind an
    earlier-listed laggard."""
    if not reqs:
        raise ValueError("waitany of an empty request list")
    t0 = time.monotonic()
    while True:
        _tick_engines(reqs)
        for i, r in enumerate(reqs):
            if _req_done(r):
                return i, r
        if timeout is not None and time.monotonic() - t0 > timeout:
            raise TimeoutError("waitany: no request completed")
        time.sleep(0)


def testall(reqs: list) -> bool:
    """One fair sweep: each involved engine ticks once, then every
    request is polled; True iff all have completed."""
    _tick_engines(reqs)
    return all([_req_done(r) for r in reqs])
