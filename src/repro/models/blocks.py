"""Model building blocks: norms, rotary embeddings, attention (GQA, causal,
chunked/memory-efficient, decode-with-cache), cross-attention, SwiGLU FFN,
capacity-based MoE, Mamba selective scan, RWKV6 (Finch) time/channel mix.

All blocks are pure functions  ``apply(params, x, ...) -> y``  with explicit
parameter pytrees; initialization lives next to application so
``jax.eval_shape(init)`` gives allocation-free parameter specs for the
dry-run. Everything is written against a 16-way tensor-parallel axis in mind:
projection output dims are flattened (n_heads * d_head) so TP sharding does
not depend on head-count divisibility.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, MambaConfig, RWKVConfig

Params = dict[str, Any]


# --------------------------------------------------------------------------
# small helpers
# --------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def rmsnorm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float):
    """x: (..., S, n_heads, d_head); positions: (..., S) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                      # (d_head//2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                 # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq": dense_init(ks[0], (D, H * Dh), dtype=dt),
        "wk": dense_init(ks[1], (D, KV * Dh), dtype=dt),
        "wv": dense_init(ks[2], (D, KV * Dh), dtype=dt),
        "wo": dense_init(ks[3], (H * Dh, D), scale=1.0 / math.sqrt(H * Dh), dtype=dt),
    }


def _repeat_kv(k, n_rep: int):
    """(B, S, KV, Dh) -> (B, S, KV*n_rep, Dh) by head repetition (GQA)."""
    if n_rep == 1:
        return k
    b, s, kv, dh = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, dh))
    return k.reshape(b, s, kv * n_rep, dh)


def _plain_attention(q, k, v, causal: bool, q_offset=0, kv_len: Optional[jax.Array] = None):
    """q: (B,Sq,H,Dh)  k,v: (B,Sk,H,Dh). fp32 softmax."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        mask = kpos <= qpos
        scores = jnp.where(mask[None, None], scores, -1e30)
    if kv_len is not None:
        valid = jnp.arange(sk)[None, None, None, :] < kv_len[:, None, None, None]
        scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _chunked_attention(q, k, v, causal: bool, q_chunk: int, kv_chunk: int):
    """Memory-efficient (online-softmax) attention; never materializes SxS.

    This is the pure-jnp oracle mirrored by kernels/flash_attention. Causal
    masking is applied per (q-block, kv-block); kv-blocks strictly above the
    diagonal are skipped by construction of the scan bounds.
    """
    b, s, h, dh = q.shape
    sk = k.shape[1]
    assert s % q_chunk == 0 and sk % kv_chunk == 0
    nq, nk = s // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(dh)

    q = q.reshape(b, nq, q_chunk, h, dh)
    k = k.reshape(b, nk, kv_chunk, h, dh)
    v = v.reshape(b, nk, kv_chunk, h, dh)

    def q_block(qi, qb):
        # qb: (B, q_chunk, H, Dh)
        def kv_step(carry, ki):
            acc, m, l = carry
            kb = k[:, ki]
            vb = v[:, ki]
            scores = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                                preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
                scores = jnp.where((kpos <= qpos)[None, None], scores, -1e30)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, h, q_chunk, dh), jnp.float32)
        m0 = jnp.full((b, h, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        n_kv = (qi + 1) * q_chunk // kv_chunk if causal else nk
        # scan over every kv block but mask work above the diagonal; the
        # optimized path (flash kernel / block-skip) is a §Perf iteration.
        (acc, m, l), _ = lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3)  # (B, q_chunk, H, Dh)

    outs = lax.map(lambda i: q_block(i, q[:, i]), jnp.arange(nq))
    # outs: (nq, B, q_chunk, H, Dh) -> (B, S, H, Dh)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh).astype(q.dtype)


def attn_decode_readonly(params: Params, cfg: ModelConfig, x, kv_cache):
    """Cross-attention at decode time: q from x (B,1,D), k/v from the static
    ctx cache (B, KV, Nctx, Dh). No cache update, no causal mask."""
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    b, s, _ = x.shape
    cdt = _dtype(cfg)
    q = (x @ params["wq"].astype(cdt)).reshape(b, s, H, Dh)
    k = kv_cache["k"].transpose(0, 2, 1, 3)  # (B, Nctx, KV, Dh)
    v = kv_cache["v"].transpose(0, 2, 1, 3)
    k = _repeat_kv(k, H // KV)
    v = _repeat_kv(v, H // KV)
    out = _plain_attention(q, k, v, causal=False)
    return out.reshape(b, s, H * Dh) @ params["wo"].astype(cdt)


def attn_apply(params: Params, cfg: ModelConfig, x, positions, *,
               ctx=None, cache=None, cache_len=None, dist=None):
    """Self- or cross-attention.

    x: (B, S, D). ctx: (B, Nctx, D) for cross-attention.
    cache: optional dict {k: (B, KV, Smax, Dh), v: ...} for decode; when given,
    S must be 1 and `cache_len` (B,) gives the valid prefix length. Returns
    (out, new_cache).
    """
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    b, s, _ = x.shape
    cdt = _dtype(cfg)
    q = (x @ params["wq"].astype(cdt)).reshape(b, s, H, Dh)
    kv_src = ctx if ctx is not None else x
    k = (kv_src @ params["wk"].astype(cdt)).reshape(b, -1, KV, Dh)
    v = (kv_src @ params["wv"].astype(cdt)).reshape(b, -1, KV, Dh)

    is_cross = ctx is not None
    if not is_cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions[..., : k.shape[1]] if cache is None else positions,
                       cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # decode: append the new token's K/V at position cache_len
        assert s == 1
        k_cache, v_cache = cache["k"], cache["v"]     # (B, KV, Smax, Dh)
        pos = cache_len                                # (B,) int32
        if cfg.kv_update == "dus":
            # per-example dynamic_update_slice — a true scatter; avoids the
            # one_hot broadcast that forces SPMD full rematerialization of
            # the seq-sharded cache (see EXPERIMENTS.md §Perf cell B)
            def _upd(c, n, p):
                return lax.dynamic_update_slice(c, n, (0, p, 0))
            k_cache = jax.vmap(_upd)(k_cache, k.transpose(0, 2, 1, 3), pos)
            v_cache = jax.vmap(_upd)(v_cache, v.transpose(0, 2, 1, 3), pos)
        else:
            oh = jax.nn.one_hot(pos, k_cache.shape[2], dtype=k.dtype)
            k_cache = k_cache + oh[:, None, :, None] * k.transpose(0, 2, 1, 3)
            v_cache = v_cache + oh[:, None, :, None] * v.transpose(0, 2, 1, 3)
        new_cache = {"k": k_cache, "v": v_cache}
        smax = k_cache.shape[2]
        if (cfg.decode_attn == "flashdecode" and dist is not None
                and dist.model_size > 1 and smax % dist.model_size == 0):
            # flash-decoding: the cache stays SEQ-sharded end to end.
            # q is tiny (B,1,H,Dh) — replicate it; scores are S-sharded;
            # softmax over the sharded axis lowers to partial-max/sum
            # psums of (B,H,1) scalars instead of gathering the cache
            # (the measured 1 GiB/layer/step pathology; §Perf cell B).
            q_r = lax.with_sharding_constraint(
                q, jax.sharding.NamedSharding(
                    dist.mesh, jax.sharding.PartitionSpec(
                        dist.bspec, None, None, None)))
            kc = dist.constrain_kv(k_cache)            # (B, KV, S, Dh)
            vc = dist.constrain_kv(v_cache)
            scale = 1.0 / math.sqrt(Dh)
            scores = jnp.einsum(
                "bqhd,bhsd->bhqs", q_r,
                jnp.repeat(kc, H // KV, axis=1),
                preferred_element_type=jnp.float32) * scale
            scores = dist.constrain_scores(scores)     # (B, H, 1, S)@model
            valid = (jnp.arange(smax)[None, None, None, :]
                     < (cache_len + 1)[:, None, None, None])
            scores = jnp.where(valid, scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhqs,bhsd->bqhd",
                             probs.astype(q.dtype),
                             jnp.repeat(vc, H // KV, axis=1),
                             preferred_element_type=jnp.float32
                             ).astype(q.dtype)
        else:
            k_full = k_cache.transpose(0, 2, 1, 3)     # (B, Smax, KV, Dh)
            v_full = v_cache.transpose(0, 2, 1, 3)
            k_full = _repeat_kv(k_full, H // KV)
            v_full = _repeat_kv(v_full, H // KV)
            out = _plain_attention(q, k_full, v_full, causal=False,
                                   kv_len=cache_len + 1)
    else:
        k = _repeat_kv(k, H // KV)
        v = _repeat_kv(v, H // KV)
        if (dist is not None and cfg.attn_seq_shard and not is_cross
                and s % max(dist.model_size, 1) == 0):
            # context parallelism: scores (B, H, S/TP, S) per device —
            # the remedy when heads cannot split the model axis
            q = dist.constrain_seq(q)
        chunk = cfg.attn_chunk or (1024 if s > 8192 else 0)
        if chunk and not is_cross and s % chunk == 0:
            out = _chunked_attention(q, k, v, causal=True,
                                     q_chunk=chunk, kv_chunk=chunk)
        else:
            out = _plain_attention(q, k, v, causal=not is_cross)
        if dist is not None and cfg.attn_seq_shard and not is_cross:
            out = dist.constrain_seq(out)
    out = out.reshape(b, s, H * Dh)
    return out @ params["wo"].astype(cdt), new_cache


# --------------------------------------------------------------------------
# FFNs
# --------------------------------------------------------------------------

def ffn_init(key, cfg: ModelConfig) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_gate": dense_init(ks[0], (D, F), dtype=dt),
        "w_up": dense_init(ks[1], (D, F), dtype=dt),
        "w_down": dense_init(ks[2], (F, D), scale=1.0 / math.sqrt(F), dtype=dt),
    }


def ffn_apply(params: Params, cfg: ModelConfig, x):
    cdt = _dtype(cfg)
    g = x @ params["w_gate"].astype(cdt)
    u = x @ params["w_up"].astype(cdt)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(cdt)


def cmix_init(key, cfg: ModelConfig) -> Params:
    """RWKV channel-mix: receptance-gated squared-relu FFN with token shift."""
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "cm_r": dense_init(ks[0], (D, D), dtype=dt),
        "cm_k": dense_init(ks[1], (D, F), dtype=dt),
        "cm_v": dense_init(ks[2], (F, D), scale=1.0 / math.sqrt(F), dtype=dt),
        "mix_k": jnp.full((D,), 0.5, dt),
        "mix_r": jnp.full((D,), 0.5, dt),
    }


def cmix_apply(params: Params, cfg: ModelConfig, x, x_prev=None):
    """x: (B,S,D). x_prev: (B,D) decode-state token shift; returns (y, last_x)."""
    cdt = _dtype(cfg)
    if x_prev is None:
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        shifted = x_prev[:, None, :]  # S == 1 decode
    xk = x * params["mix_k"].astype(cdt) + shifted * (1 - params["mix_k"].astype(cdt))
    xr = x * params["mix_r"].astype(cdt) + shifted * (1 - params["mix_r"].astype(cdt))
    r = jax.nn.sigmoid(xr @ params["cm_r"].astype(cdt))
    k = jnp.square(jax.nn.relu(xk @ params["cm_k"].astype(cdt)))
    return r * (k @ params["cm_v"].astype(cdt)), x[:, -1, :]


# --------------------------------------------------------------------------
# MoE (GShard-style capacity dispatch, expert-parallel friendly)
# --------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    E = cfg.moe.n_experts
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "router": dense_init(ks[0], (D, E), scale=0.02, dtype=dt),
        "w_gate": dense_init(ks[1], (E, D, F), scale=1.0 / math.sqrt(D), dtype=dt),
        "w_up": dense_init(ks[2], (E, D, F), scale=1.0 / math.sqrt(D), dtype=dt),
        "w_down": dense_init(ks[3], (E, F, D), scale=1.0 / math.sqrt(F), dtype=dt),
    }


def moe_capacity(cfg: ModelConfig, group_tokens: int) -> int:
    moe = cfg.moe
    c = math.ceil(group_tokens * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(c, 1)


def moe_apply(params: Params, cfg: ModelConfig, x):
    """x: (B, S, D) -> (y, aux_loss).

    Tokens are grouped along the existing (B, S) layout: groups are rows of
    the batch when S > 1 (so dispatch never crosses the data-parallel axis),
    or groups of adjacent batch rows for decode shapes (S == 1). The dispatch
    is sort-free: positions within an expert come from a cumsum over the
    one-hot assignment; tokens past capacity are dropped (GShard semantics,
    capacity_factor 1.25).
    """
    moe = cfg.moe
    E, K = moe.n_experts, moe.top_k
    cdt = _dtype(cfg)
    b, s, d = x.shape
    if s > 1:
        groups, gtok = b, s
        xg = x
    else:
        gsz = min(b, 16)
        groups, gtok = b // gsz, gsz
        xg = x.reshape(groups, gtok, d)
    C = moe_capacity(cfg, gtok)

    logits = (xg @ params["router"].astype(cdt)).astype(jnp.float32)  # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)                                # (G,T,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) inside its expert queue
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)              # (G,T,K,E)
    flat = onehot.reshape(groups, gtok * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                             # (G,T*K,E)
    pos = jnp.einsum("gte,gte->gt", pos, flat).reshape(groups, gtok, K)
    keep = pos < C
    pos = pos.astype(jnp.int32)

    # scatter token indices into (G, E, C) dispatch table
    tok_ids = jnp.broadcast_to(jnp.arange(gtok)[None, :, None], top_e.shape)
    dispatch = jnp.full((groups, E, C), gtok, jnp.int32)  # gtok == OOB sentinel
    gidx = jnp.broadcast_to(jnp.arange(groups)[:, None, None], top_e.shape)
    dispatch = dispatch.at[
        gidx.reshape(groups, -1),
        jnp.where(keep, top_e, 0).reshape(groups, -1),
        jnp.where(keep, pos, C - 1).reshape(groups, -1),
    ].set(jnp.where(keep, tok_ids, gtok).reshape(groups, -1), mode="drop")

    # gather expert inputs (OOB sentinel -> zeros via fill)
    xpad = jnp.concatenate([xg, jnp.zeros((groups, 1, d), xg.dtype)], axis=1)
    expert_in = jnp.take_along_axis(
        xpad[:, None], dispatch[..., None].clip(0, gtok), axis=2
    )  # (G, E, C, D)

    h_g = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"].astype(cdt))
    h_u = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"].astype(cdt))
    h = jax.nn.silu(h_g) * h_u
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(cdt))

    # combine: weight each dispatched slot and scatter-add back to tokens.
    # slot weights mirror the dispatch scatter; the OOB sentinel token id
    # (== gtok) lands in the padding row and is dropped by the final slice.
    slot_w = jnp.zeros((groups, E, C), jnp.float32)
    slot_w = slot_w.at[
        gidx.reshape(groups, -1),
        jnp.where(keep, top_e, 0).reshape(groups, -1),
        jnp.where(keep, pos, C - 1).reshape(groups, -1),
    ].add(jnp.where(keep, top_p, 0.0).reshape(groups, -1), mode="drop")
    weighted = (expert_out.astype(jnp.float32)
                * slot_w[..., None]).reshape(groups, E * C, d)
    g_rows = jnp.broadcast_to(jnp.arange(groups)[:, None], (groups, E * C))
    out = jnp.zeros((groups, gtok + 1, d), jnp.float32)
    out = out.at[g_rows, dispatch.reshape(groups, -1)].add(weighted, mode="drop")
    y = out[:, :gtok].astype(cdt)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=(0, 1))                       # (E,)
    ce = onehot.sum(axis=2).mean(axis=(0, 1))          # fraction routed per e
    aux = E * jnp.sum(me * ce / K)
    if s == 1:
        y = y.reshape(b, s, d)
    return y, aux


def moe_apply_ep(params: Params, cfg: ModelConfig, x, dist):
    """Expert-parallel MoE via shard_map (cfg.moe_shard == 'ep_a2a').

    Activations are replicated over the model axis (they are dp-sharded
    only), so every shard already holds every token: shard m builds the
    capacity dispatch for ITS E/TP experts only — dispatch tensors are
    TP-times smaller than the GSPMD dense-dispatch path — runs its expert
    FFNs locally, and the per-shard partial outputs combine with ONE
    (B, S, D) psum per layer. No token all_to_all is needed at all in
    this layout; the wire cost collapses to the dense-FFN pattern
    (EXPERIMENTS.md §Perf cell C3).
    """
    moe = cfg.moe
    E, K = moe.n_experts, moe.top_k
    TP = dist.model_size
    if TP <= 1 or E % TP != 0:
        return moe_apply(params, cfg, x)
    E_loc = E // TP
    cdt = _dtype(cfg)
    from jax.sharding import PartitionSpec as P  # local import (no cycle)

    def f(router, wg, wu, wd, xx):
        # router (D, E) replicated; wg/wu (E_loc, D, F), wd (E_loc, F, D)
        # local expert shards; xx (B_loc, S, D) replicated over 'model'.
        idx = lax.axis_index("model")
        b, s, d = xx.shape
        gtok = b * s
        xg = xx.reshape(1, gtok, d)
        logits = (xg @ router.astype(cdt)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)                # (1,T,E)
        top_p, top_e = lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        # positions within each GLOBAL expert queue (identical math on
        # every shard — routing is deterministic), then keep only the
        # local expert range
        onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)
        flat = onehot.reshape(1, gtok * K, E)
        pos = jnp.cumsum(flat, axis=1) - flat
        pos = jnp.einsum("gte,gte->gt", pos, flat).reshape(1, gtok, K)
        C = moe_capacity(cfg, gtok)
        local = (top_e >= idx * E_loc) & (top_e < (idx + 1) * E_loc)
        keep = (pos < C) & local
        e_loc = jnp.where(local, top_e - idx * E_loc, 0)
        pos = pos.astype(jnp.int32)

        tok_ids = jnp.broadcast_to(jnp.arange(gtok)[None, :, None],
                                   top_e.shape)
        dispatch = jnp.full((1, E_loc, C), gtok, jnp.int32)
        gidx = jnp.zeros_like(top_e)
        dispatch = dispatch.at[
            gidx.reshape(1, -1),
            jnp.where(keep, e_loc, 0).reshape(1, -1),
            jnp.where(keep, pos, C - 1).reshape(1, -1),
        ].set(jnp.where(keep, tok_ids, gtok).reshape(1, -1), mode="drop")

        xpad = jnp.concatenate([xg, jnp.zeros((1, 1, d), xg.dtype)], axis=1)
        expert_in = jnp.take_along_axis(
            xpad[:, None], dispatch[..., None].clip(0, gtok), axis=2)
        h_g = jnp.einsum("gecd,edf->gecf", expert_in, wg.astype(cdt))
        h_u = jnp.einsum("gecd,edf->gecf", expert_in, wu.astype(cdt))
        h = jax.nn.silu(h_g) * h_u
        expert_out = jnp.einsum("gecf,efd->gecd", h, wd.astype(cdt))

        slot_w = jnp.zeros((1, E_loc, C), jnp.float32)
        slot_w = slot_w.at[
            gidx.reshape(1, -1),
            jnp.where(keep, e_loc, 0).reshape(1, -1),
            jnp.where(keep, pos, C - 1).reshape(1, -1),
        ].add(jnp.where(keep, top_p, 0.0).reshape(1, -1), mode="drop")
        weighted = (expert_out.astype(jnp.float32)
                    * slot_w[..., None]).reshape(1, E_loc * C, d)
        g_rows = jnp.zeros((1, E_loc * C), jnp.int32)
        out = jnp.zeros((1, gtok + 1, d), jnp.float32)
        out = out.at[g_rows, dispatch.reshape(1, -1)].add(weighted,
                                                          mode="drop")
        y = lax.psum(out[:, :gtok], "model")   # combine partial outputs
        # aux loss: every shard sees all routing info — no comm needed
        me = probs.mean(axis=(0, 1))
        ce = onehot.sum(axis=2).mean(axis=(0, 1))
        aux = E * jnp.sum(me * ce / K)
        return y.reshape(b, s, d).astype(cdt), aux

    bspec = dist.bspec
    return jax.shard_map(
        f, mesh=dist.mesh,
        in_specs=(P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  P(bspec, None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(params["router"], params["w_gate"], params["w_up"],
      params["w_down"], x)


# --------------------------------------------------------------------------
# Mamba (selective state space)
# --------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig) -> Params:
    mc = cfg.mamba or MambaConfig()
    D = cfg.d_model
    d_in = mc.expand * D
    dt_rank = mc.dt_rank or -(-D // 16)
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    A = jnp.broadcast_to(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32),
                         (d_in, mc.d_state))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * d_in), dtype=dt),
        "conv_w": dense_init(ks[1], (mc.d_conv, d_in), scale=0.5, dtype=dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * mc.d_state), dtype=dt),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), dtype=dt),
        "dt_bias": jnp.full((d_in,), -4.6, dt),   # softplus^-1(0.01)
        "A_log": jnp.log(A).astype(dt),
        "D": jnp.ones((d_in,), dt),
        "out_proj": dense_init(ks[4], (d_in, D), dtype=dt),
    }


def _selective_scan(u, dt, B, Cm, A, chunk: int = 64):
    """u: (b, S, d_in); dt: (b, S, d_in); B, Cm: (b, S, N); A: (d_in, N).

    h_t = exp(A*dt_t) h_{t-1} + dt_t * B_t * u_t;  y_t = <Cm_t, h_t>.
    Chunked: sequential lax.scan over chunks, parallel associative scan inside.
    """
    b, S, d_in = u.shape
    N = A.shape[1]
    pad = (-S) % chunk
    if pad:
        u, dt, B, Cm = (jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
                        for a in (u, dt, B, Cm))
    Sp = S + pad
    nc = Sp // chunk
    u = u.reshape(b, nc, chunk, d_in)
    dt = dt.reshape(b, nc, chunk, d_in)
    B = B.reshape(b, nc, chunk, N)
    Cm = Cm.reshape(b, nc, chunk, N)

    def chunk_step(h, inp):
        uc, dtc, Bc, Cc = inp  # (b, chunk, ...)
        dA = jnp.exp(dtc[..., None] * A[None, None].astype(jnp.float32))  # (b,c,d,N)
        dBu = (dtc * uc)[..., None] * Bc[..., None, :]                    # (b,c,d,N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        aa, bb = lax.associative_scan(combine, (dA, dBu), axis=1)
        h_seq = aa * h[:, None] + bb                                      # (b,c,d,N)
        y = jnp.einsum("bcdn,bcn->bcd", h_seq, Cc.astype(jnp.float32))
        return h_seq[:, -1], y

    h0 = jnp.zeros((b, d_in, N), jnp.float32)
    _, ys = lax.scan(chunk_step, h0,
                     (u.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2, 3),
                      B.transpose(1, 0, 2, 3), Cm.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(b, Sp, d_in)
    return y[:, :S]


def mamba_apply(params: Params, cfg: ModelConfig, x, *, state=None):
    """x: (B, S, D). state: {conv: (B, d_conv-1, d_in), h: (B, d_in, N)} for
    decode (S == 1). Returns (y, new_state)."""
    mc = cfg.mamba or MambaConfig()
    cdt = _dtype(cfg)
    b, s, D = x.shape
    d_in = mc.expand * D
    xz = x @ params["in_proj"].astype(cdt)
    xi, z = jnp.split(xz, 2, axis=-1)                  # (B,S,d_in) each

    conv_w = params["conv_w"].astype(cdt)              # (d_conv, d_in)
    new_state = None
    if state is None:
        xpad = jnp.pad(xi, ((0, 0), (mc.d_conv - 1, 0), (0, 0)))
        conv = sum(xpad[:, i:i + s] * conv_w[i] for i in range(mc.d_conv))
    else:
        hist = jnp.concatenate([state["conv"], xi], axis=1)  # (B, d_conv, d_in)
        conv = jnp.einsum("bcd,cd->bd", hist, conv_w)[:, None]
        new_conv = hist[:, 1:]
    conv = jax.nn.silu(conv + params["conv_b"].astype(cdt))

    proj = conv @ params["x_proj"].astype(cdt)
    dt_rank = params["dt_proj"].shape[0]
    dt_x, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + mc.d_state], axis=-1)
    dt = jax.nn.softplus((dt_x @ params["dt_proj"].astype(cdt)).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    if state is None:
        y = _selective_scan(conv.astype(jnp.float32), dt,
                            Bm.astype(jnp.float32), Cm.astype(jnp.float32), A)
    else:
        h = state["h"]
        dA = jnp.exp(dt[:, 0, :, None] * A[None])                   # (B,d,N)
        dBu = (dt[:, 0] * conv[:, 0].astype(jnp.float32))[..., None] \
            * Bm[:, 0, None, :].astype(jnp.float32)
        h = h * dA + dBu
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))[:, None]
        new_state = {"conv": new_conv, "h": h}
    y = y + conv.astype(jnp.float32) * params["D"].astype(jnp.float32)
    y = y.astype(cdt) * jax.nn.silu(z)
    return y @ params["out_proj"].astype(cdt), new_state


def mamba_state_init(cfg: ModelConfig, batch: int):
    mc = cfg.mamba or MambaConfig()
    d_in = mc.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, d_in), _dtype(cfg)),
        "h": jnp.zeros((batch, d_in, mc.d_state), jnp.float32),
    }


# --------------------------------------------------------------------------
# RWKV6 (Finch) time mix
# --------------------------------------------------------------------------

def rwkv6_init(key, cfg: ModelConfig) -> Params:
    rc = cfg.rwkv or RWKVConfig()
    D = cfg.d_model
    H = D // rc.head_size
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wr": dense_init(ks[0], (D, D), dtype=dt),
        "wk": dense_init(ks[1], (D, D), dtype=dt),
        "wv": dense_init(ks[2], (D, D), dtype=dt),
        "wg": dense_init(ks[3], (D, D), dtype=dt),
        "wo": dense_init(ks[4], (D, D), dtype=dt),
        "w0": jnp.full((D,), -2.0, dt),            # base decay (w = exp(-exp(.)))
        "w_a": dense_init(ks[5], (D, rc.decay_lora), dtype=dt),
        "w_b": dense_init(ks[6], (rc.decay_lora, D), scale=0.1, dtype=dt),
        "u": dense_init(ks[7], (H, rc.head_size), scale=0.5, dtype=dt),
        "mix_x": jnp.full((D,), 0.5, dt),
    }


def _wkv6_scan(r, k, v, w, u):
    """Linear recurrence with data-dependent per-channel decay (exact oracle).

    r,k,v: (B,S,H,n); w: (B,S,H,n) decay in (0,1); u: (H,n) bonus.
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
        o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    Sequential lax.scan over time — numerically exact for any decay strength.
    The chunked-parallel form (the performance path) lives in kernels/rwkv6
    and is validated against this oracle.
    """
    b, S, h, n = r.shape

    def step(state, inp):
        rt, kt, vt, wt = inp                       # (b, h, n) each
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        o = jnp.einsum("bhn,bhnm->bhm", rt, state + u[None, :, :, None] * kv)
        state = state * wt[..., None] + kv
        return state, o

    state0 = jnp.zeros((b, h, n, n), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w))
    _, os_ = lax.scan(step, state0, xs)
    return os_.transpose(1, 0, 2, 3)               # (B, S, H, n)


def rwkv6_apply(params: Params, cfg: ModelConfig, x, *, state=None):
    """x: (B,S,D). state: {"S": (B,H,n,n), "x_prev": (B,D)} for decode."""
    rc = cfg.rwkv or RWKVConfig()
    cdt = _dtype(cfg)
    b, s, D = x.shape
    n = rc.head_size
    H = D // n

    if state is None:
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        shifted = state["x_prev"][:, None, :]
    mix = params["mix_x"].astype(cdt)
    xm = x * mix + shifted * (1 - mix)

    r = (xm @ params["wr"].astype(cdt)).reshape(b, s, H, n)
    k = (xm @ params["wk"].astype(cdt)).reshape(b, s, H, n)
    v = (xm @ params["wv"].astype(cdt)).reshape(b, s, H, n)
    g = jax.nn.silu(xm @ params["wg"].astype(cdt))
    w_log = params["w0"].astype(jnp.float32) + (
        jnp.tanh(xm @ params["w_a"].astype(cdt)) @ params["w_b"].astype(cdt)
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, s, H, n)   # decay in (0,1)
    u = params["u"].astype(jnp.float32)

    new_state = None
    if state is None:
        o = _wkv6_scan(r, k, v, w, u)
    else:
        S0 = state["S"]                                # (B,H,n,n)
        rf, kf, vf, wf = (a[:, 0].astype(jnp.float32) for a in (r, k, v, w))
        kv = jnp.einsum("bhn,bhm->bhnm", kf, vf)
        o = jnp.einsum("bhn,bhnm->bhm", rf, S0 + u[None, :, :, None] * kv)[:, None]
        S_new = S0 * wf[..., None] + kv
        new_state = {"S": S_new, "x_prev": x[:, -1, :]}
    o = o.reshape(b, s, D).astype(cdt) * g
    return o @ params["wo"].astype(cdt), new_state


def rwkv6_state_init(cfg: ModelConfig, batch: int):
    rc = cfg.rwkv or RWKVConfig()
    H = cfg.d_model // rc.head_size
    return {
        "S": jnp.zeros((batch, H, rc.head_size, rc.head_size), jnp.float32),
        "x_prev": jnp.zeros((batch, cfg.d_model), _dtype(cfg)),
    }
