"""The LM: embedding → pattern-scanned backbone → (tied) head, with train,
prefill and decode entry points.

Layer stacking: ``cfg.pattern`` (a tuple of BlockSpecs) is applied
``cfg.n_groups`` times via ``lax.scan`` over group-stacked parameters; the
pattern itself is a python-level loop (so heterogeneous interleaves like
Jamba's 1:7 mamba:attn carry no parameter padding). ``unroll=True`` replaces
the scan with a python loop — used by the HLO-analyzer validation tests
(XLA's cost_analysis counts while bodies once; see analysis/hlo.py).

Distribution is by sharding constraint (GSPMD); the vocab-parallel
embedding / cross-entropy use shard_map so that no vocab-sized all-gather is
ever materialized (see distributed/vocab_parallel.py).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import blocks as B

Params = dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, blk: BlockSpec) -> Params:
    ks = iter(jax.random.split(key, 8))
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {"norm1": jnp.ones((cfg.d_model,), dt)}
    if blk.mixer in ("attn", "cross_attn"):
        p["mixer"] = B.attn_init(next(ks), cfg, cross=blk.mixer == "cross_attn")
    elif blk.mixer == "mamba":
        p["mixer"] = B.mamba_init(next(ks), cfg)
    elif blk.mixer == "rwkv6":
        p["mixer"] = B.rwkv6_init(next(ks), cfg)
    else:
        raise ValueError(blk.mixer)
    if blk.ffn != "none":
        p["norm2"] = jnp.ones((cfg.d_model,), dt)
        if blk.ffn == "dense":
            p["ffn"] = B.ffn_init(next(ks), cfg)
        elif blk.ffn == "moe":
            p["ffn"] = B.moe_init(next(ks), cfg)
        elif blk.ffn == "cmix":
            p["ffn"] = B.cmix_init(next(ks), cfg)
        else:
            raise ValueError(blk.ffn)
    return p


def init(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 3 + len(cfg.pattern))
    dt = jnp.dtype(cfg.param_dtype)
    Vp = cfg.padded_vocab

    def stack_init(k, blk):
        return jax.vmap(lambda kk: _block_init(kk, cfg, blk))(
            jax.random.split(k, cfg.n_groups))

    params: Params = {
        "embed": B.dense_init(keys[0], (Vp, cfg.d_model), scale=0.02, dtype=dt),
        "blocks": tuple(stack_init(keys[3 + i], blk)
                        for i, blk in enumerate(cfg.pattern)),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = B.dense_init(keys[1], (Vp, cfg.d_model),
                                      scale=0.02, dtype=dt)
    return params


def param_specs(cfg: ModelConfig) -> Params:
    """Allocation-free parameter ShapeDtypeStructs (for the dry-run)."""
    return jax.eval_shape(lambda: init(cfg, jax.random.key(0)))


# --------------------------------------------------------------------------
# decode-state init
# --------------------------------------------------------------------------

def _block_state_init(cfg: ModelConfig, blk: BlockSpec, batch: int,
                      cache_len: int) -> Params:
    kv_dt = jnp.dtype(cfg.kv_cache_dtype) if cfg.kv_cache_dtype != "int8" \
        else jnp.int8
    st: Params = {}
    if blk.mixer == "attn":
        KV, Dh = cfg.n_kv_heads, cfg.d_head
        st["kv"] = {
            "k": jnp.zeros((batch, KV, cache_len, Dh), kv_dt),
            "v": jnp.zeros((batch, KV, cache_len, Dh), kv_dt),
        }
        if cfg.kv_cache_dtype == "int8":
            st["kv"]["k_scale"] = jnp.zeros((batch, KV, cache_len), jnp.float32)
            st["kv"]["v_scale"] = jnp.zeros((batch, KV, cache_len), jnp.float32)
    elif blk.mixer == "cross_attn":
        KV, Dh = cfg.n_kv_heads, cfg.d_head
        st["kv"] = {
            "k": jnp.zeros((batch, KV, cfg.n_ctx_tokens, Dh),
                           jnp.dtype(cfg.compute_dtype)),
            "v": jnp.zeros((batch, KV, cfg.n_ctx_tokens, Dh),
                           jnp.dtype(cfg.compute_dtype)),
        }
    elif blk.mixer == "mamba":
        st["ssm"] = B.mamba_state_init(cfg, batch)
    elif blk.mixer == "rwkv6":
        st["ssm"] = B.rwkv6_state_init(cfg, batch)
    if blk.ffn == "cmix":
        st["cm_x_prev"] = jnp.zeros((batch, cfg.d_model),
                                    jnp.dtype(cfg.compute_dtype))
    return st


def decode_state_init(cfg: ModelConfig, batch: int, cache_len: int):
    """Stacked-over-groups decode state, one entry per pattern position."""
    def stack(blk):
        one = lambda: _block_state_init(cfg, blk, batch, cache_len)  # noqa: E731
        leaves = jax.eval_shape(one)
        return jax.tree.map(
            lambda s: jnp.zeros((cfg.n_groups,) + s.shape, s.dtype), leaves)
    return tuple(stack(blk) for blk in cfg.pattern)


def decode_state_specs(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: decode_state_init(cfg, batch, cache_len))


# --------------------------------------------------------------------------
# block application
# --------------------------------------------------------------------------

def _apply_block(bp: Params, cfg: ModelConfig, blk: BlockSpec, x, positions,
                 *, ctx=None, state=None, pos=None, train: bool = True,
                 dist=None):
    """Returns (x, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = B.rmsnorm(x, bp["norm1"], cfg.norm_eps)
    new_state: Params = {}

    if blk.mixer in ("attn", "cross_attn"):
        is_cross = blk.mixer == "cross_attn"
        if state is not None and not train:
            kv = state["kv"]
            if is_cross:
                # static cross-attn cache: no update, attend over ctx tokens
                mix = B.attn_decode_readonly(bp["mixer"], cfg, h, kv)
                new_state["kv"] = kv
            else:
                mix, nkv = B.attn_apply(bp["mixer"], cfg, h, positions,
                                        cache=kv, cache_len=pos, dist=dist)
                new_state["kv"] = nkv
        else:
            mix, _ = B.attn_apply(bp["mixer"], cfg, h, positions,
                                  ctx=ctx if is_cross else None, dist=dist)
    elif blk.mixer == "mamba":
        mix, nst = B.mamba_apply(bp["mixer"], cfg, h,
                                 state=None if train else state["ssm"])
        if not train:
            new_state["ssm"] = nst
    elif blk.mixer == "rwkv6":
        mix, nst = B.rwkv6_apply(bp["mixer"], cfg, h,
                                 state=None if train else state["ssm"])
        if not train:
            new_state["ssm"] = nst
    else:
        raise ValueError(blk.mixer)

    if blk.parallel and blk.ffn != "none":
        # Cohere-style: attn and ffn both read the same normed input
        f, aux2, fstate = _apply_ffn(bp, cfg, blk, h, state, train,
                                     dist=dist)
        x = x + mix + f
    else:
        x = x + mix
        if blk.ffn != "none":
            h2 = B.rmsnorm(x, bp["norm2"], cfg.norm_eps)
            f, aux2, fstate = _apply_ffn(bp, cfg, blk, h2, state, train,
                                         dist=dist)
            x = x + f
        else:
            aux2, fstate = jnp.zeros((), jnp.float32), {}
    aux = aux + aux2
    new_state.update(fstate)
    return x, new_state, aux


def _apply_ffn(bp, cfg, blk, h, state, train, dist=None):
    aux = jnp.zeros((), jnp.float32)
    fstate: Params = {}
    if blk.ffn == "dense":
        f = B.ffn_apply(bp["ffn"], cfg, h)
    elif blk.ffn == "moe":
        if cfg.moe_shard == "ep_a2a" and dist is not None:
            f, aux = B.moe_apply_ep(bp["ffn"], cfg, h, dist)
        else:
            f, aux = B.moe_apply(bp["ffn"], cfg, h)
    elif blk.ffn == "cmix":
        xp = None if train else state["cm_x_prev"]
        f, last = B.cmix_apply(bp["ffn"], cfg, h, x_prev=xp)
        if not train:
            fstate["cm_x_prev"] = last
    else:
        raise ValueError(blk.ffn)
    return f, aux, fstate


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _embed_tokens(params, cfg: ModelConfig, batch, dist=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend == "frames" and "frames" in batch:
        return batch["frames"].astype(cdt)
    tokens = batch["tokens"]
    if dist is not None and dist.vocab_parallel(cfg):
        return dist.vp_embed(params["embed"], tokens, cfg)
    return params["embed"].astype(cdt)[tokens]


def forward(params: Params, cfg: ModelConfig, batch, *, dist=None,
            unroll: bool = False):
    """Causal full-sequence forward. batch: {"tokens"|"frames", "ctx"?}.
    Returns (x_final (B,S,D), aux_loss)."""
    x = _embed_tokens(params, cfg, batch, dist)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ctx = batch.get("ctx")
    if ctx is not None:
        ctx = ctx.astype(x.dtype)

    def apply_group(xc, gp):
        aux = jnp.zeros((), jnp.float32)
        if dist is not None:
            xc = dist.constrain_act(xc)
        for p, blk in enumerate(cfg.pattern):
            xc, _, a = _apply_block(gp[p], cfg, blk, xc, positions,
                                    ctx=ctx, train=True, dist=dist)
            aux = aux + a
        return xc, aux

    if cfg.remat == "block":
        apply_group = jax.checkpoint(
            apply_group,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat == "full":
        apply_group = jax.checkpoint(apply_group)

    if unroll:
        auxes = []
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda l: l[g], params["blocks"])
            x, a = apply_group(x, gp)
            auxes.append(a)
        aux = jnp.stack(auxes).sum() if auxes else jnp.zeros((), jnp.float32)
    else:
        x, auxes = lax.scan(lambda xc, gp: apply_group(xc, gp),
                            x, params["blocks"])
        aux = auxes.sum()

    x = B.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def lm_head(params: Params, cfg: ModelConfig):
    return params.get("head", params["embed"])


def loss_fn(params: Params, cfg: ModelConfig, batch, *, dist=None,
            unroll: bool = False):
    """Cross-entropy LM loss; labels masked where < 0."""
    x, aux = forward(params, cfg, batch, dist=dist, unroll=unroll)
    labels = batch["labels"]
    head = lm_head(params, cfg)
    if dist is not None and dist.vocab_parallel(cfg):
        ce = dist.vp_cross_entropy(head, x, labels, cfg)
    else:
        logits = (x @ head.astype(x.dtype).T).astype(jnp.float32)
        logits = logits[..., : cfg.vocab_size]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        ce = lse - ll
    mask = (labels >= 0).astype(jnp.float32)
    loss = (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux, "tokens": mask.sum()}


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def decode_step(params: Params, cfg: ModelConfig, state, batch, pos, *,
                dist=None):
    """One decode step. batch: {"tokens": (B,1)} | {"frames": (B,1,D)} (+ctx).
    state: from decode_state_init; pos: (B,) write/attend position.
    Returns (logits (B, vocab), new_state)."""
    x = _embed_tokens(params, cfg, batch, dist)
    b = x.shape[0]
    positions = pos[:, None]

    def group_step(xc, inp):
        gp, gs = inp
        if dist is not None:
            xc = dist.constrain_act(xc)
        new_gs = []
        for p, blk in enumerate(cfg.pattern):
            xc, nst, _ = _apply_block(gp[p], cfg, blk, xc, positions,
                                      state=gs[p], pos=pos, train=False,
                                      dist=dist)
            new_gs.append(nst)
        return xc, tuple(new_gs)

    x, new_state = lax.scan(group_step, x, (params["blocks"], state))
    x = B.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = lm_head(params, cfg)
    if (cfg.decode_return == "token" and dist is not None
            and dist.vocab_parallel(cfg)):
        # greedy token id per row; the (B, V) logits never materialize
        token = dist.vp_greedy_token(head, x[:, 0], cfg)
        return token, new_state
    logits = (x[:, 0] @ head.astype(x.dtype).T).astype(jnp.float32)
    return logits[..., : cfg.vocab_size], new_state


def prefill(params: Params, cfg: ModelConfig, batch, *, dist=None):
    """Full-sequence prefill returning last-position logits.

    (Serving realism note: state materialization for the subsequent decode is
    exercised by decode_step from decode_state_init; the prefill benchmark
    shape measures the forward itself, which dominates.)"""
    x, _ = forward(params, cfg, batch, dist=dist)
    head = lm_head(params, cfg)
    logits = (x[:, -1] @ head.astype(x.dtype).T).astype(jnp.float32)
    return logits[..., : cfg.vocab_size]
