"""Serve-tier wiring: one router rank + N-1 workers over one Comm.

``serve_rank(env, cfg)`` is the per-rank program for the thread or
process runtimes: it builds the shared dynamic KV window, attaches this
rank's page shard, allgathers the page directory, broadcasts the
router's shared stats word, then runs the rank's role to completion.
``run_serve(cfg, ranks=...)`` wraps it in ``run_threads`` and returns
the per-rank reports (router report at index 0).

Zero-copy bookkeeping: every rank snapshots its ``ProtocolStats``
around the serve phase and attaches the delta to its report
(``stats_delta``), so callers can assert the data plane's contract —
page bytes appear ONLY under the origin-side ``rma_put``/``rma_get``
(and 8-byte ``raccumulate`` stats words in both), never under
``rndv_staged``, and a passive page home drains nothing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.runtime import run_threads
from repro.serve import wire
from repro.serve.pages import PageDirectory, PageStore
from repro.serve.router import Router
from repro.serve.worker import Worker


@dataclasses.dataclass
class ServeConfig:
    """Knobs for one serve run. Defaults are smoke-sized: a few dozen
    sessions, small pages, everything verified."""
    sessions: int = 32            # total Poisson arrivals (open loop)
    rate: float = 400.0           # arrivals per second
    seed: int = 0
    prompt_min: int = 8
    prompt_max: int = 24
    gen_min: int = 8
    gen_max: int = 24
    page_tokens: int = 16         # KV positions per page
    page_bytes: int = 4096
    slots_per_worker: int = 64
    max_batch: int = 8            # continuous-batching width per worker
    admit_depth: int = 4          # persistent request ring depth
    stats_interval: int = 8       # steps between raccumulate + BEAT
    decode_us: float = 0.0        # synthetic per-step compute
    verify_every: int = 1         # router recomputes 1-in-k checksums
    worker_timeout: float = 0.0   # >0: fail-stop heartbeat window (s)
    deadline_s: float = 60.0      # hard abort for CI hangs
    fail_rank: int = -1           # fault injection: this worker...
    fail_after_steps: int = -1    # ...aborts after this many steps

    @property
    def max_pages(self) -> int:
        return wire.pages_for(self.prompt_max, self.gen_max,
                              self.page_tokens)

    def pool_bytes_needed(self, ranks: int) -> int:
        """Pages + round buffers + queue matrix headroom per run."""
        pages = ranks * self.slots_per_worker * (self.page_bytes + 4096)
        return pages + (8 << 20)


def serve_rank(env, cfg: ServeConfig) -> dict:
    """The per-rank serve program (router on rank 0)."""
    comm = env.comm
    if comm.size < 2:
        raise ValueError("serving needs at least 2 ranks "
                         "(1 router + 1 worker)")
    win = comm.win_create_dynamic(
        "kv", attach_slots=cfg.slots_per_worker + 2)
    store = PageStore(comm, win, cfg.slots_per_worker, cfg.page_bytes)
    directory = PageDirectory(comm, store)
    # the router's shared stats word: workers raccumulate token deltas
    if comm.rank == 0:
        stats_buf = comm.alloc_buffer(8)
        stats_buf.write(b"\x00" * 8)
        stats_addr = win.attach(stats_buf)
        comm.bcast(np.asarray([stats_addr], dtype=np.int64))
    else:
        stats_buf = None
        stats_addr = int(comm.bcast(None)[0])
    before = comm.arena.view.stats.snapshot()
    if comm.rank == 0:
        report = Router(comm, cfg, directory).run()
    else:
        report = Worker(comm, cfg, store, directory, win,
                        stats_addr=stats_addr).run()
    comm.barrier()                # all traffic quiesced before teardown
    report["stats_delta"] = comm.arena.view.stats.delta(before)
    if comm.rank == 0:
        report["stats_tokens"] = int(np.frombuffer(
            stats_buf.read(), dtype=np.int64)[0])
        win.detach(stats_addr)
        stats_buf.free()
    comm.barrier()                # no rget may race the detach below
    store.free()
    win.free()
    return report


def run_serve(cfg: ServeConfig, ranks: int = 3, *,
              timeout: float | None = None) -> list[dict]:
    """Drive a full serve run under the thread runtime; returns the
    per-rank reports (router first)."""
    return run_threads(
        ranks, lambda env: serve_rank(env, cfg),
        pool_bytes=cfg.pool_bytes_needed(ranks),
        timeout=timeout if timeout is not None else cfg.deadline_s + 30.0)
