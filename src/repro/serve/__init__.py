"""Multi-rank serving data plane over the comm core (the ROADMAP's
serving tier): a front-end router rank admits an open-loop population
of synthetic sessions through persistent-request pools, worker ranks
run continuous-batching decode over a rank-sharded KV/page cache whose
pool-resident pages attach to one shared ``DynamicWindow`` and move
strictly one-sidedly (``rput`` fills, ``rget`` drains — zero
receiver-side copies, asserted via ``ProtocolStats.path_copied_bytes``).

  wire     fixed-width int64 control frames + deterministic synthetic
           tokens/pages/checksums (content is a pure function of
           (session, position, seed) — re-routable, verifiable)
  pages    PageStore (pool buffers attached to the window) and the
           allgathered PageDirectory
  router   admission, round-robin sharded placement, open-loop Poisson
           arrivals, fail-stop retirement + epoch-fenced re-routing
  worker   continuous batching, page fills/drains, raccumulate'd
           shared token stats, ``abort()`` fault hook
  service  ``ServeConfig`` + ``serve_rank`` (per-rank program) +
           ``run_serve`` (thread-runtime launcher)

See ``docs/serving.md`` and ``benchmarks/serve_qps.py``.
"""
from repro.serve.pages import PageDirectory, PageStore
from repro.serve.router import Router
from repro.serve.service import ServeConfig, run_serve, serve_rank
from repro.serve.worker import Worker

__all__ = ["PageDirectory", "PageStore", "Router", "ServeConfig",
           "Worker", "run_serve", "serve_rank"]
