"""Worker ranks: continuous-batching decode over the sharded page cache.

A worker runs one loop: drain admissions from its persistent receive
ring, let waiting sessions JOIN the decode batch (up to ``max_batch``),
advance every batched session by one synthetic token, and let finished
sessions LEAVE — continuous batching, requests join and leave between
steps, the batch never drains to restart.

KV pages are produced as decode crosses page boundaries.  A page homed
on this rank is a plain local pool write; a page homed elsewhere moves
by ``win.rput`` against the PASSIVE home (zero receiver-side drain —
one chunk per engine tick, overlapping the next decode steps; the
request is only awaited at session completion).  At completion the
worker drains every REMOTE page back with ``win.rget`` and verifies it
against the regenerable expected bytes, folds the session checksum,
and reports DONE through its persistent send ring.

Every ``stats_interval`` steps the worker ``raccumulate``s its decoded
token delta into the router's shared stats word (satellite 1's
request-based accumulate: exclusive window lock held only across the
engine-pumped get->reduce->put chain) and heartbeats the router so
fail-stop detection has a signal even mid-long-session.

``abort()`` is the fault hook: cancel the posted admission receives
(matchbox retracted), stop serving.  The pages this rank HOMES stay
attached and readable — pool memory outlives the rank, so surviving
sessions keep rget-ing their pages from the dead shard.
"""
from __future__ import annotations

import time

import numpy as np

from repro.serve import wire


class _ActiveSession:
    __slots__ = ("sid", "epoch", "prompt", "gen", "pages", "tokens",
                 "next_page", "checksum", "page_reqs", "t_join")

    def __init__(self, msg: dict, now: float):
        self.sid = msg["sid"]
        self.epoch = msg["epoch"]
        self.prompt = msg["prompt"]
        self.gen = msg["gen"]
        self.pages = msg["pages"]
        self.tokens = 0
        self.next_page = 0
        self.checksum = 0
        self.page_reqs = []       # (req, src) — src pinned till wait()
        self.t_join = now


class Worker:
    def __init__(self, comm, cfg, store, directory, win, router: int = 0,
                 stats_addr: int = -1):
        self.comm = comm
        self.cfg = cfg
        self.store = store
        self.dir = directory
        self.win = win
        self.router = router
        self.stats_addr = stats_addr
        self.rank = comm.rank
        # persistent pools: admissions in, DONE/BEAT frames out
        words = wire.admit_words(cfg.max_pages)
        self._rx_bufs = [np.zeros(words, dtype=np.int64)
                         for _ in range(cfg.admit_depth)]
        self._rx = [comm.recv_init(router, b) for b in self._rx_bufs]
        for r in self._rx:
            r.start()
        self._rx_head = 0
        self._tx_bufs = [np.zeros(wire.DONE_WORDS, dtype=np.int64)
                         for _ in range(cfg.admit_depth)]
        self._tx = [comm.send_init(router, b) for b in self._tx_bufs]
        self._tx_head = 0
        self.pending: list[_ActiveSession] = []
        self.batch: list[_ActiveSession] = []
        self.stopping = False
        self.aborted = False
        # report counters
        self.steps = 0
        self.busy_steps = 0       # steps that advanced a live batch
        self.served = 0
        self.tokens_out = 0
        self.rput_bytes = 0
        self.rget_bytes = 0
        self.local_fills = 0
        self.racc_calls = 0
        self.verify_failures = 0
        self._tokens_unreported = 0
        self._scratch = np.empty(cfg.page_bytes, dtype=np.uint8)

    # -- control-plane frames -------------------------------------------

    def _drain_admits(self, now: float) -> None:
        while not self.stopping:
            req = self._rx[self._rx_head]
            if not req.test():
                return
            buf = self._rx_bufs[self._rx_head]
            kind = int(buf[0])
            if kind == wire.MSG_STOP:
                self.stopping = True
                req.start()          # keep the ring armed for teardown
            else:
                self.pending.append(_ActiveSession(
                    wire.decode_admit(buf), now))
                req.start()
            self._rx_head = (self._rx_head + 1) % len(self._rx)

    def _send_status(self, fill) -> None:
        req = self._tx[self._tx_head]
        if req.started and req.active:
            req.wait()
        fill(self._tx_bufs[self._tx_head])
        req.start()
        self._tx_head = (self._tx_head + 1) % len(self._tx)

    # -- data plane ------------------------------------------------------

    def _fill_page(self, sess: _ActiveSession, p: int) -> None:
        content = wire.page_fill(sess.sid, p, self.cfg.seed,
                                 self.cfg.page_bytes)
        home, slot = sess.pages[p]
        if home == self.rank:
            self.store.write_local(slot, content)
            self.local_fills += 1
        else:
            addr = self.dir.addr(home, slot)
            req = self.win.rput(home, addr, content)
            sess.page_reqs.append((req, content))
            self.rput_bytes += content.nbytes

    def _advance(self, sess: _ActiveSession) -> bool:
        """One decode step; True when the session just finished."""
        pos = sess.prompt + sess.tokens
        sess.tokens += 1
        sess.checksum = wire.fold(
            sess.checksum, wire.token(sess.sid, pos, self.cfg.seed))
        kv = sess.prompt + sess.tokens
        while (sess.next_page + 1) * self.cfg.page_tokens <= kv:
            self._fill_page(sess, sess.next_page)
            sess.next_page += 1
        if sess.tokens < sess.gen:
            return False
        while sess.next_page < len(sess.pages):   # final partial page
            self._fill_page(sess, sess.next_page)
            sess.next_page += 1
        return True

    def _complete(self, sess: _ActiveSession) -> None:
        """Flush outstanding fills, drain every remote page back by
        rget, verify, fold the page checksums, report DONE."""
        for req, _src in sess.page_reqs:
            req.wait()
        sess.page_reqs = []
        for p, (home, slot) in enumerate(sess.pages):
            if home == self.rank:
                data = np.frombuffer(self.store.read_local(slot),
                                     dtype=np.uint8)
            else:
                addr = self.dir.addr(home, slot)
                self.win.rget(home, addr, self._scratch).wait()
                self.rget_bytes += self._scratch.nbytes
                data = self._scratch
            want = wire.page_fill(sess.sid, p, self.cfg.seed,
                                  self.cfg.page_bytes)
            if not np.array_equal(data, want):
                self.verify_failures += 1
            sess.checksum = wire.fold(sess.checksum,
                                      wire.page_checksum(want))
        self.served += 1
        self.tokens_out += sess.tokens
        self._tokens_unreported += sess.tokens
        self._send_status(lambda b, s=sess: wire.encode_done(
            b, self.rank, s.sid, s.epoch, s.tokens, s.checksum,
            self.steps))

    def _accumulate_stats(self) -> None:
        delta = self._tokens_unreported
        if delta == 0 or self.stats_addr < 0:
            return
        self._tokens_unreported = 0
        self.win.raccumulate(self.router, self.stats_addr,
                             np.asarray([delta], dtype=np.int64)).wait()
        self.racc_calls += 1

    # -- the loop --------------------------------------------------------

    def step(self) -> None:
        now = time.monotonic()
        self._drain_admits(now)
        while self.pending and len(self.batch) < self.cfg.max_batch:
            self.batch.append(self.pending.pop(0))    # JOIN
        if self.batch:
            self.busy_steps += 1
        finished = []
        for sess in self.batch:
            if self._advance(sess):
                finished.append(sess)
        for sess in finished:
            self.batch.remove(sess)                   # LEAVE
            self._complete(sess)
        self.steps += 1
        if self.cfg.decode_us > 0:
            time.sleep(self.cfg.decode_us * 1e-6)     # synthetic compute
        if self.steps % self.cfg.stats_interval == 0:
            self._accumulate_stats()
            self._send_status(lambda b: wire.encode_beat(
                b, self.rank, self.tokens_out, self.steps))
        self.comm.progress()

    def run(self) -> dict:
        fail_at = (self.cfg.fail_after_steps
                   if self.rank == self.cfg.fail_rank else -1)
        while not (self.stopping and not self.batch and not self.pending):
            self.step()
            if fail_at >= 0 and self.steps >= fail_at:
                self.abort()
                break
            time.sleep(0)
        if not self.aborted:
            self._accumulate_stats()
            self._teardown()
        return self.report()

    def abort(self) -> None:
        """Fail-stop: retract posted admission receives, stop serving.
        Homed pages stay attached — the shared pool outlives the rank,
        peers keep reading them."""
        self.aborted = True
        for r in self._rx:
            r.cancel()
            r.free()
        self._rx = []

    def _teardown(self) -> None:
        for r in self._rx:
            r.cancel()
            r.free()
        self._rx = []
        for r in self._tx:
            if r.started and r.active:
                r.wait()
            r.free()
        self._tx = []

    def report(self) -> dict:
        return dict(role="worker", rank=self.rank, steps=self.steps,
                    busy_steps=self.busy_steps,
                    served=self.served, tokens=self.tokens_out,
                    rput_bytes=self.rput_bytes,
                    rget_bytes=self.rget_bytes,
                    local_fills=self.local_fills,
                    racc_calls=self.racc_calls,
                    verify_failures=self.verify_failures,
                    aborted=self.aborted)
