"""Wire structs and deterministic synthetic content for the serve tier.

Every control message is a fixed-width little array of int64 words so
the router/worker pools can pre-plan them as MPI-4 persistent requests
(``send_init``/``recv_init``): admission is a hot loop, not a malloc
loop.  Two frames exist:

  ADMIT  router -> worker   [MSG_ADMIT, sid, epoch, prompt, gen,
                             n_pages, packed_page * max_pages]
  STOP   router -> worker   [MSG_STOP, 0, ...]          (same width)
  DONE   worker -> router   [MSG_DONE, worker, sid, epoch, tokens,
                             checksum, steps, 0]
  BEAT   worker -> router   [MSG_BEAT, worker, 0, 0, tokens, 0,
                             steps, 0]                  (same width)

``epoch`` tracks re-admissions after a worker death: the router only
accepts a DONE whose (sid, epoch) matches the live assignment, so a
straggler completion from a retired placement can never double-count.

Page placements travel packed as ``home << 32 | slot`` — the router is
the single allocator of page slots, workers just obey the placement.

All synthetic content (decode tokens, KV page bytes) is a pure
function of ``(session, position, seed)`` so a re-routed session
regenerates byte-identical pages on a different worker and the router
can verify end-to-end checksums without ever holding the data.
"""
from __future__ import annotations

import numpy as np

MSG_ADMIT = 1
MSG_STOP = 2
MSG_DONE = 3
MSG_BEAT = 4

DONE_WORDS = 8
VOCAB = 50257
_U64 = (1 << 64) - 1


def admit_words(max_pages: int) -> int:
    return 6 + int(max_pages)


def pack_page(home: int, slot: int) -> int:
    return (int(home) << 32) | int(slot)


def unpack_page(word: int) -> tuple[int, int]:
    w = int(word)
    return w >> 32, w & 0xFFFFFFFF


# --------------------------------------------------------------------------
# deterministic synthetic content
# --------------------------------------------------------------------------

def _mix(x: int) -> int:
    """splitmix64 finalizer — the usual avalanche over 64-bit ints."""
    x &= _U64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _U64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _U64
    return x ^ (x >> 31)


def token(sid: int, pos: int, seed: int) -> int:
    """The decode token of session ``sid`` at KV position ``pos``."""
    return _mix(seed * 0x9E3779B97F4A7C15 + sid * 0x632BE59BD9B4E019
                + pos) % VOCAB


def page_fill(sid: int, page: int, seed: int, nbytes: int) -> np.ndarray:
    """The KV bytes of page ``page`` of session ``sid`` — regenerable
    anywhere, so a fault-rerouted session reproduces identical pages."""
    rng = np.random.Generator(np.random.PCG64(
        _mix(seed * 0xD6E8FEB86659FD93 + sid * 0xCA5A826395121157 + page)))
    return rng.integers(0, 256, nbytes, dtype=np.uint8)


def page_checksum(u8: np.ndarray) -> int:
    u8 = np.ascontiguousarray(u8).reshape(-1).view(np.uint8)
    return int((int(u8.astype(np.uint64).sum()) + 31 * u8.size)
               % (1 << 31))


def fold(acc: int, value: int) -> int:
    """Order-sensitive checksum fold (tokens, then page checksums)."""
    return (acc * 1000003 + int(value)) % (1 << 31)


def session_checksum(sid: int, prompt: int, gen: int, page_tokens: int,
                     page_bytes: int, seed: int) -> int:
    """What a correct serve of this session must report: every decoded
    token folded in KV order, then every page's checksum."""
    acc = 0
    for t in range(gen):
        acc = fold(acc, token(sid, prompt + t, seed))
    n_pages = pages_for(prompt, gen, page_tokens)
    for p in range(n_pages):
        acc = fold(acc, page_checksum(page_fill(sid, p, seed, page_bytes)))
    return acc


def pages_for(prompt: int, gen: int, page_tokens: int) -> int:
    total = int(prompt) + int(gen)
    return -(-total // int(page_tokens))


# --------------------------------------------------------------------------
# frame encode/decode (in place — the buffers are persistent)
# --------------------------------------------------------------------------

def encode_admit(buf: np.ndarray, sid: int, epoch: int, prompt: int,
                 gen: int, pages: list[int]) -> None:
    buf[0] = MSG_ADMIT
    buf[1] = sid
    buf[2] = epoch
    buf[3] = prompt
    buf[4] = gen
    buf[5] = len(pages)
    buf[6:6 + len(pages)] = pages
    buf[6 + len(pages):] = 0


def encode_stop(buf: np.ndarray) -> None:
    buf[:] = 0
    buf[0] = MSG_STOP


def decode_admit(buf: np.ndarray) -> dict:
    n = int(buf[5])
    return dict(sid=int(buf[1]), epoch=int(buf[2]), prompt=int(buf[3]),
                gen=int(buf[4]),
                pages=[unpack_page(w) for w in buf[6:6 + n]])


def encode_done(buf: np.ndarray, worker: int, sid: int, epoch: int,
                tokens: int, checksum: int, steps: int) -> None:
    buf[:] = 0
    buf[0] = MSG_DONE
    buf[1] = worker
    buf[2] = sid
    buf[3] = epoch
    buf[4] = tokens
    buf[5] = checksum
    buf[6] = steps


def encode_beat(buf: np.ndarray, worker: int, tokens: int,
                steps: int) -> None:
    buf[:] = 0
    buf[0] = MSG_BEAT
    buf[1] = worker
    buf[4] = tokens
    buf[6] = steps


def decode_status(buf: np.ndarray) -> dict:
    return dict(kind=int(buf[0]), worker=int(buf[1]), sid=int(buf[2]),
                epoch=int(buf[3]), tokens=int(buf[4]),
                checksum=int(buf[5]), steps=int(buf[6]))
