"""Rank-sharded KV/page cache over pool-resident dynamic-window pages.

Each rank owns ``n_slots`` fixed-size pages allocated straight from the
comm's pool (``comm.alloc_buffer``) and attached to a shared
``DynamicWindow`` — no copy into a window arena, the pool buffer IS the
window segment (satellite 2's ``Win_attach`` model).  A page therefore
has one global name: the absolute pool offset its home rank attached.

Page movement is strictly one-sided against a PASSIVE home:

  fill   ``win.rput(home, addr, bytes)``  — origin-counted ``rma_put``
  fetch  ``win.rget(home, addr, dst)``    — origin-counted ``rma_get``

The home rank executes nothing and copies nothing (zero receiver-side
drain; the serve bench asserts this through
``ProtocolStats.path_copied_bytes``).  Because the pages live in the
shared pool, they even outlive their home RANK: a worker that
fail-stops mid-decode leaves every page it hosted readable by rget
until the buffers are freed at teardown — the CXL-pool property the
paper builds on.
"""
from __future__ import annotations

import numpy as np


class PageStore:
    """This rank's shard of the page cache: pool buffers + attachments."""

    def __init__(self, comm, win, n_slots: int, page_bytes: int):
        self.comm = comm
        self.win = win
        self.page_bytes = int(page_bytes)
        self.bufs = [comm.alloc_buffer(page_bytes) for _ in range(n_slots)]
        self.addrs = [win.attach(b) for b in self.bufs]

    @property
    def n_slots(self) -> int:
        return len(self.bufs)

    def write_local(self, slot: int, data) -> None:
        """Fill a locally-homed page (one counted local copy)."""
        self.bufs[slot].write(data)

    def read_local(self, slot: int) -> bytes:
        return self.bufs[slot].read(0, self.page_bytes)

    def free(self) -> None:
        """Detach and release every page. Collective discipline is the
        caller's: no peer may still be rget-ing these pages."""
        for a in self.addrs:
            self.win.detach(a)
        for b in self.bufs:
            b.free()
        self.bufs = []
        self.addrs = []


class PageDirectory:
    """Global slot -> absolute-address table, allgathered once at
    startup (every rank attaches the same slot count, so the table is
    rectangular).  After this one collective, page addressing is pure
    local arithmetic — the serve hot loop never asks anyone where a
    page lives."""

    def __init__(self, comm, store: PageStore):
        mine = np.asarray(store.addrs, dtype=np.int64)
        flat = comm.allgather(mine)
        self.table = flat.reshape(comm.size, -1)
        self.page_bytes = store.page_bytes

    def addr(self, home: int, slot: int) -> int:
        return int(self.table[home, slot])
