"""The front-end router rank: admission, placement, completion, faults.

The router (rank 0) owns THE allocator for every worker's page slots
and admits an open-loop Poisson population of synthetic sessions.  All
control traffic runs over pre-planned persistent requests:

  * one ``send_init`` ring per worker for ADMIT/STOP frames — the hot
    admission loop mutates a pinned buffer and ``start()``s, it never
    allocates;
  * one ``recv_init`` ring per worker for DONE/BEAT frames, tested
    head-only so frame order is preserved (the pt2pt FIFO matches
    posted receives in order).

Placement is rank-sharded round-robin: a session's pages are dealt
across every alive worker's shard, so most page fills and the final
page drain cross ranks one-sidedly (that traffic is the point of the
bench).  Admission is open loop — the arrival schedule is drawn once
from a seeded exponential stream and never reacts to completions, so
measured latency includes real queueing delay.

Fault handling is fail-stop: a worker that misses its heartbeat window
while holding sessions is retired — the router CANCELS its posted
DONE/BEAT receives (retracting the matchbox postings so the slots are
reusable), drops the dead shard from the allocator, and re-admits the
worker's sessions elsewhere under a bumped epoch.  Stale completions
from the old placement can never double-count: DONE carries (sid,
epoch) and the router only accepts the live pair.
"""
from __future__ import annotations

import time

import numpy as np

from repro.serve import wire


class _SendRing:
    """Depth-d ring of persistent ADMIT-frame sends to one worker."""

    def __init__(self, comm, worker: int, words: int, depth: int):
        self.bufs = [np.zeros(words, dtype=np.int64) for _ in range(depth)]
        self.reqs = [comm.send_init(worker, b) for b in self.bufs]
        self.head = 0

    def claim(self) -> np.ndarray:
        """The next frame buffer, recycled once its last send lands."""
        req = self.reqs[self.head]
        if req.started and req.active:
            req.wait()
        return self.bufs[self.head]

    def send(self) -> None:
        self.reqs[self.head].start()
        self.head = (self.head + 1) % len(self.reqs)

    def free(self) -> None:
        for r in self.reqs:
            if r.started and r.active:
                r.wait()
            r.free()


class _RecvRing:
    """Depth-d ring of persistent DONE/BEAT receives from one worker,
    tested head-only (frames complete in post order)."""

    def __init__(self, comm, worker: int, depth: int):
        self.bufs = [np.zeros(wire.DONE_WORDS, dtype=np.int64)
                     for _ in range(depth)]
        self.reqs = [comm.recv_init(worker, b) for b in self.bufs]
        for r in self.reqs:
            r.start()
        self.head = 0

    def poll(self):
        """One completed frame (decoded dict) or None; re-arms the slot."""
        req = self.reqs[self.head]
        if not req.test():
            return None
        msg = wire.decode_status(self.bufs[self.head])
        req.start()
        self.head = (self.head + 1) % len(self.reqs)
        return msg

    def cancel(self) -> None:
        """Retract every posted receive (worker retired): the matchbox
        entries are withdrawn and the requests freed."""
        for r in self.reqs:
            r.cancel()
            r.free()
        self.reqs = []


class _Session:
    __slots__ = ("sid", "prompt", "gen", "arrival", "epoch", "worker",
                 "pages", "t_admit", "t_done")

    def __init__(self, sid, prompt, gen, arrival):
        self.sid = sid
        self.prompt = prompt
        self.gen = gen
        self.arrival = arrival
        self.epoch = 0
        self.worker = -1
        self.pages = []           # [(home, slot), ...]
        self.t_admit = None
        self.t_done = None


class Router:
    def __init__(self, comm, cfg, directory):
        self.comm = comm
        self.cfg = cfg
        self.dir = directory
        self.workers = list(range(1, comm.size))
        self.alive = set(self.workers)
        self.free_slots = {w: list(range(cfg.slots_per_worker))
                           for w in self.workers}
        self.load = {w: 0 for w in self.workers}
        words = wire.admit_words(cfg.max_pages)
        self.tx = {w: _SendRing(comm, w, words, cfg.admit_depth)
                   for w in self.workers}
        self.rx = {w: _RecvRing(comm, w, cfg.admit_depth)
                   for w in self.workers}
        self.sessions: dict[int, _Session] = {}
        self.backlog: list[_Session] = []
        self.done: list[_Session] = []
        self.retired: list[int] = []
        self.reroutes = 0
        self.bad_checksums = 0
        self._place_cursor = 0

        rng = np.random.default_rng(cfg.seed)
        gaps = rng.exponential(1.0 / cfg.rate, size=cfg.sessions)
        self._arrivals = np.cumsum(gaps)
        self._prompts = rng.integers(cfg.prompt_min, cfg.prompt_max + 1,
                                     size=cfg.sessions)
        self._gens = rng.integers(cfg.gen_min, cfg.gen_max + 1,
                                  size=cfg.sessions)
        self._next_sid = 0
        self.t0 = None

    # -- placement ------------------------------------------------------

    def _place(self, n_pages: int):
        """Deal n_pages slots round-robin across alive shards; None when
        the cache cannot hold the session right now (stays in backlog)."""
        pool = [w for w in self.workers
                if w in self.alive and self.free_slots[w]]
        if not pool or sum(len(self.free_slots[w]) for w in pool) < n_pages:
            return None
        placement = []
        while len(placement) < n_pages:
            w = pool[self._place_cursor % len(pool)]
            self._place_cursor += 1
            if self.free_slots[w]:
                placement.append((w, self.free_slots[w].pop()))
        return placement

    def _reclaim(self, sess: _Session) -> None:
        for home, slot in sess.pages:
            if home in self.alive:
                self.free_slots[home].append(slot)
        sess.pages = []

    def _admit(self, sess: _Session, now: float) -> bool:
        n_pages = wire.pages_for(sess.prompt, sess.gen,
                                 self.cfg.page_tokens)
        placement = self._place(n_pages)
        if placement is None:
            return False
        serving = min((w for w in self.alive), key=lambda w: self.load[w],
                      default=None)
        if serving is None:
            return False
        sess.pages = placement
        sess.worker = serving
        self.load[serving] += 1
        if sess.t_admit is None:
            sess.t_admit = now
        buf = self.tx[serving].claim()
        wire.encode_admit(buf, sess.sid, sess.epoch, sess.prompt, sess.gen,
                          [wire.pack_page(h, s) for h, s in placement])
        self.tx[serving].send()
        return True

    # -- completion / fault handling ------------------------------------

    def _on_done(self, msg: dict, now: float) -> None:
        sess = self.sessions.get(msg["sid"])
        if sess is None or sess.t_done is not None \
                or msg["epoch"] != sess.epoch:
            return                      # stale epoch: retired placement
        sess.t_done = now
        self.load[sess.worker] -= 1
        every = max(1, self.cfg.verify_every)
        if sess.sid % every == 0:
            want = wire.session_checksum(
                sess.sid, sess.prompt, sess.gen, self.cfg.page_tokens,
                self.cfg.page_bytes, self.cfg.seed)
            if msg["checksum"] != want:
                self.bad_checksums += 1
        self._reclaim(sess)
        self.done.append(sess)

    def retire_worker(self, w: int) -> None:
        """Fail-stop retirement: retract the dead worker's postings,
        drop its shard, re-route its sessions under a new epoch."""
        if w not in self.alive:
            return
        self.alive.discard(w)
        self.retired.append(w)
        self.rx[w].cancel()
        self.free_slots[w] = []
        for sess in self.sessions.values():
            if sess.worker == w and sess.t_done is None:
                self._reclaim(sess)
                sess.epoch += 1
                sess.worker = -1
                self.reroutes += 1
                self.backlog.append(sess)

    # -- main loop ------------------------------------------------------

    def run(self) -> dict:
        cfg = self.cfg
        self.t0 = t0 = time.monotonic()
        last_seen = {w: t0 for w in self.workers}
        deadline = t0 + cfg.deadline_s
        while len(self.done) < cfg.sessions:
            now = time.monotonic()
            if now > deadline:
                raise RuntimeError(
                    f"serve deadline exceeded: {len(self.done)}/"
                    f"{cfg.sessions} sessions done, alive={self.alive}")
            for w in self.workers:
                if w not in self.alive:
                    continue
                while True:
                    msg = self.rx[w].poll()
                    if msg is None:
                        break
                    last_seen[w] = now
                    if msg["kind"] == wire.MSG_DONE:
                        self._on_done(msg, now)
            if cfg.worker_timeout > 0:
                for w in list(self.alive):
                    if now - last_seen[w] > cfg.worker_timeout \
                            and self.load[w] > 0:
                        self.retire_worker(w)
            while self._next_sid < cfg.sessions \
                    and now - t0 >= self._arrivals[self._next_sid]:
                i = self._next_sid
                self._next_sid += 1
                sess = _Session(i, int(self._prompts[i]),
                                int(self._gens[i]),
                                t0 + float(self._arrivals[i]))
                self.sessions[i] = sess
                self.backlog.append(sess)
            still = []
            for sess in self.backlog:
                if not self._admit(sess, time.monotonic()):
                    still.append(sess)
            self.backlog = still
            self.comm.progress()
            time.sleep(0)            # fair scheduling vs worker threads
        for w in self.alive:
            buf = self.tx[w].claim()
            wire.encode_stop(buf)
            self.tx[w].send()
        for w in self.workers:
            self.tx[w].free()
            if w in self.alive:
                self.rx[w].cancel()
        return self.report()

    # -- results --------------------------------------------------------

    def report(self) -> dict:
        lats = sorted((s.t_done - s.arrival) * 1e6 for s in self.done)

        def pct(q):
            if not lats:
                return 0.0
            return lats[min(len(lats) - 1, int(q * (len(lats) - 1) + 0.5))]

        span = max(1e-9, (max(s.t_done for s in self.done) - self.t0)
                   if self.done else 0.0)
        return dict(
            role="router",
            sessions=len(self.done),
            qps=len(self.done) / span,
            p50_us=pct(0.50),
            p99_us=pct(0.99),
            mean_us=(sum(lats) / len(lats)) if lats else 0.0,
            tokens=sum(s.gen for s in self.done),
            retired=list(self.retired),
            reroutes=self.reroutes,
            bad_checksums=self.bad_checksums,
        )
