"""Software cache coherence (§3.5): the protocol is NECESSARY (omitting it
yields stale reads on the incoherent pool) and SUFFICIENT (applying it
yields the backing pool's truth)."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.coherence import CoherentView
from repro.core.pool import CACHELINE, IncoherentPool, LocalPool, RankCache


def two_ranks(size=1 << 16):
    backing = LocalPool(size)
    mk = lambda: IncoherentPool(backing, RankCache(backing))  # noqa: E731
    return backing, CoherentView(mk(), "incoherent"), \
        CoherentView(mk(), "incoherent")


class TestStaleness:
    def test_write_invisible_without_flush(self):
        """Writer dirties its cache; reader (who cached the line first)
        sees the OLD value — the exact hazard of non-coherent CXL SHM."""
        _, w, r = two_ranks()
        assert r.raw_read(0, 4) == b"\x00" * 4     # reader caches the line
        w.raw_write(0, b"NEW!")                    # writer: cache only
        assert r.raw_read(0, 4) == b"\x00" * 4     # stale for the reader

    def test_reader_stale_even_after_writer_flush(self):
        """Writer flushing is not enough: the reader's clean cached copy
        must be invalidated too (the fence+flush BEFORE read)."""
        _, w, r = two_ranks()
        assert r.raw_read(0, 4) == b"\x00" * 4
        w.write_release(0, b"NEW!")                # flushed to backing
        assert r.raw_read(0, 4) == b"\x00" * 4     # still stale (cached)
        assert r.read_acquire(0, 4) == b"NEW!"     # protocol fixes it

    def test_protocol_sufficient(self):
        _, w, r = two_ranks()
        for i, payload in enumerate([b"aaaa", b"bbbb", b"cccc"]):
            off = i * CACHELINE
            w.write_release(off, payload)
            assert r.read_acquire(off, 4) == payload

    def test_nt_control_words(self):
        """Non-temporal u64s (queue head/tail) bypass both caches."""
        _, w, r = two_ranks()
        w.nt_store_u64(128, 0xDEADBEEF)
        assert r.nt_load_u64(128) == 0xDEADBEEF
        w.nt_store_u8(256, 7)
        assert r.nt_load_u8(256) == 7

    def test_unaligned_spans(self):
        _, w, r = two_ranks()
        payload = bytes(range(200))
        w.write_release(CACHELINE - 13, payload)   # spans 4+ lines
        assert r.read_acquire(CACHELINE - 13, 200) == payload


class TestModes:
    def test_uncacheable_correct(self):
        backing = LocalPool(4096)
        v = CoherentView(backing, "uncacheable")
        v.write_release(0, b"data")
        assert v.read_acquire(0, 4) == b"data"
        assert v.stats.uncached_ops > 0

    def test_incoherent_requires_incoherent_pool(self):
        with pytest.raises(ValueError):
            CoherentView(LocalPool(64), "incoherent")

    def test_stats_counted(self):
        _, w, r = two_ranks()
        w.write_release(0, bytes(3 * CACHELINE))
        assert w.stats.flush_lines >= 3
        assert w.stats.fences >= 1
        r.read_acquire(0, 10)
        assert r.stats.reads == 1


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 960),
                          st.binary(min_size=1, max_size=96)),
                min_size=1, max_size=30))
def test_property_protocol_linearizes(ops):
    """For any interleaving of protocol writes by 4 ranks to disjoint or
    overlapping regions, a protocol read returns exactly the backing
    truth (last write wins in program order)."""
    backing = LocalPool(2048)
    views = [CoherentView(IncoherentPool(backing, RankCache(backing)),
                          "incoherent") for _ in range(4)]
    shadow = bytearray(2048)
    for rank, off, data in ops:
        views[rank].write_release(off, data)
        shadow[off:off + len(data)] = data
    reader = views[0]
    assert reader.read_acquire(0, 1024) == bytes(shadow[:1024])
