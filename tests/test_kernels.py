"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (the kernel body executes on CPU; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cellcopy.kernel import cellcopy
from repro.kernels.cellcopy.ops import copy_message, verify
from repro.kernels.cellcopy.ref import cellcopy_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import flash_attention_bshd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6.kernel import wkv6
from repro.kernels.rwkv6.ops import wkv6_bshn
from repro.kernels.rwkv6.ref import wkv6_ref


class TestCellcopy:
    @pytest.mark.parametrize("cells,words,block", [
        (8, 128, 2), (16, 256, 4), (32, 512, 8), (4, 1024, 4)])
    def test_sweep(self, cells, words, block, rng):
        src = jnp.asarray(rng.integers(-2**31, 2**31 - 1,
                                       size=(cells, words), dtype=np.int32))
        dst, sums = cellcopy(src, block_cells=block)
        rd, rs = cellcopy_ref(src)
        assert jnp.array_equal(dst, rd)
        assert jnp.array_equal(sums, rs)
        assert bool(verify(dst, sums))

    def test_message_roundtrip_odd_length(self, rng):
        msg = rng.integers(0, 256, size=123_457, dtype=np.uint8)
        out, _ = copy_message(msg, cell_bytes=16384, block_cells=2)
        assert np.array_equal(np.asarray(out), msg)

    def test_corruption_detected(self, rng):
        src = jnp.asarray(rng.integers(0, 100, size=(8, 128),
                                       dtype=np.int32))
        dst, sums = cellcopy(src, block_cells=2)
        bad = dst.at[3, 5].add(1)
        assert not bool(verify(bad, sums))


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kv,s,d,causal,dtype", [
        (2, 4, 4, 256, 64, True, jnp.float32),    # MHA causal
        (1, 8, 2, 256, 128, True, jnp.bfloat16),  # GQA bf16
        (2, 4, 1, 128, 64, False, jnp.float32),   # MQA non-causal
        (1, 2, 2, 512, 32, True, jnp.float32),    # long seq small d
    ])
    def test_sweep(self, b, h, kv, s, d, causal, dtype):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (b, h, s, d), dtype)
        k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
        v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
        got = flash_attention(q, k, v, causal=causal, block_q=64,
                              block_k=64)
        want = attention_ref(q, k, v, causal=causal)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_block_shape_invariance(self):
        ks = jax.random.split(jax.random.key(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64))
        k = jax.random.normal(ks[1], (1, 2, 256, 64))
        v = jax.random.normal(ks[2], (1, 2, 256, 64))
        a = flash_attention(q, k, v, block_q=64, block_k=64)
        b_ = flash_attention(q, k, v, block_q=128, block_k=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)

    def test_bshd_wrapper_matches_blocks_layout(self):
        from repro.models.blocks import _plain_attention
        ks = jax.random.split(jax.random.key(3), 3)
        b, s, h, d = 2, 128, 4, 64
        q = jax.random.normal(ks[0], (b, s, h, d))
        k = jax.random.normal(ks[1], (b, s, h, d))
        v = jax.random.normal(ks[2], (b, s, h, d))
        got = flash_attention_bshd(q, k, v, causal=True, block_q=64,
                                   block_k=64, interpret=True)
        want = _plain_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestWKV6:
    @pytest.mark.parametrize("b,h,s,n,chunk", [
        (2, 2, 64, 16, 16), (1, 4, 128, 32, 32), (2, 1, 96, 64, 32),
        (1, 1, 32, 8, 8)])
    def test_sweep(self, b, h, s, n, chunk):
        ks = jax.random.split(jax.random.key(7), 5)
        r = jax.random.normal(ks[0], (b, h, s, n))
        k = jax.random.normal(ks[1], (b, h, s, n))
        v = jax.random.normal(ks[2], (b, h, s, n))
        w = jnp.exp(-jnp.exp(
            jax.random.normal(ks[3], (b, h, s, n)) * 0.5 - 2.0))
        u = jax.random.normal(ks[4], (h, n)) * 0.5
        got = wkv6(r, k, v, w, u, chunk=chunk)
        want = wkv6_ref(r, k, v, w, u)
        rel = float(jnp.abs(got - want).max()
                    / (jnp.abs(want).max() + 1e-9))
        assert rel < 1e-4, rel

    def test_chunk_invariance(self):
        ks = jax.random.split(jax.random.key(9), 5)
        b, h, s, n = 1, 2, 64, 16
        r, k, v = (jax.random.normal(ks[i], (b, h, s, n)) for i in range(3))
        w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, h, s, n)) - 2.0))
        u = jax.random.normal(ks[4], (h, n))
        a = wkv6(r, k, v, w, u, chunk=16)
        b_ = wkv6(r, k, v, w, u, chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)

    def test_bshn_wrapper_matches_blocks_oracle(self):
        from repro.models.blocks import _wkv6_scan
        ks = jax.random.split(jax.random.key(11), 5)
        b, s, h, n = 2, 64, 2, 16
        r, k, v = (jax.random.normal(ks[i], (b, s, h, n)) for i in range(3))
        w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) - 2.0))
        u = jax.random.normal(ks[4], (h, n))
        got = wkv6_bshn(r, k, v, w, u, chunk=16, interpret=True)
        want = _wkv6_scan(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
