"""Flight recorder (repro.core.trace): ring-buffer semantics, the
disabled-mode zero-write guarantee across a real collective, the
Chrome-trace exporter's lane discipline, the unified metrics report,
the ProtocolStats snapshot/delta helpers, and the merge/summarize CLI."""
import json

import numpy as np
import pytest

from repro.core import run_threads
from repro.core.coherence import CoherentView, ProtocolStats
from repro.core.pool import LocalPool
from repro.core.trace import (EV_MB_CONSUME, EV_MB_POST, EV_NAMES, EV_TICK,
                              Histogram, Tracer, as_tracer, chrome_events,
                              load_dump, merge_dumps, summarize_dumps)

MiB = 1 << 20


# --------------------------------------------------------------------------
# ring semantics
# --------------------------------------------------------------------------

class TestRing:
    def test_wraparound_keeps_newest_never_reallocates(self):
        tr = Tracer(capacity=8)
        buf_id = id(tr._buf)
        for i in range(20):
            tr.emit(EV_TICK, i)
        assert tr.recorded == 20
        evs = tr.events()
        assert len(evs) == 8                     # capacity, not total
        assert [e[2] for e in evs] == list(range(12, 20))   # newest kept
        assert [e[0] for e in evs] == sorted(e[0] for e in evs)
        assert id(tr._buf) is not None and id(tr._buf) == buf_id

    def test_under_capacity_returns_all_oldest_first(self):
        tr = Tracer(capacity=64)
        for i in range(5):
            tr.emit(EV_TICK, i)
        assert [e[2] for e in tr.events()] == [0, 1, 2, 3, 4]

    def test_counts_survive_wraparound(self):
        tr = Tracer(capacity=4)
        for i in range(10):
            tr.emit(EV_TICK, i)
        assert tr.counts[EV_TICK] == 10          # counter, not ring size

    def test_capacity_floor(self):
        with pytest.raises(ValueError, match="capacity"):
            Tracer(capacity=0)

    def test_clear_resets_ring_and_histograms(self):
        tr = Tracer(capacity=8)
        tr.emit(EV_TICK, 123)
        tr.clear()
        assert tr.recorded == 0 and tr.events() == []
        assert tr.hist_tick.summary()["count"] == 0

    def test_posted_hit_keyed_by_post_id_and_peer(self):
        """post_ids restart at 1 per source pair: the same id from two
        peers must not cross-wire the post->consume latency pairing."""
        tr = Tracer(capacity=64)
        tr.emit(EV_MB_POST, 1, 5)                # id 1 from peer 5
        tr.emit(EV_MB_POST, 1, 6)                # id 1 from peer 6
        tr.emit(EV_MB_CONSUME, 1, 5)
        tr.emit(EV_MB_CONSUME, 1, 6)
        assert tr.hist_posted_hit.summary()["count"] == 2
        assert tr._post_t == {}


class TestAsTracer:
    def test_normalization(self):
        assert as_tracer(None, 3).enabled is False
        assert as_tracer(False, 3).enabled is False
        t = as_tracer(True, 3)
        assert t.enabled and t.rank == 3
        assert as_tracer(4096, 0).capacity == 4096
        inj = Tracer(capacity=2, rank=7, enabled=False)
        assert as_tracer(inj, 0) is inj          # instance passes through
        with pytest.raises(TypeError):
            as_tracer("yes", 0)


class TestHistogram:
    def test_log2_buckets_and_percentiles(self):
        h = Histogram()
        for ns in (100, 1000, 1000, 100000):
            h.record(ns)
        s = h.summary()
        assert s["count"] == 4
        # percentile returns the bucket's upper edge (<= 2x the truth)
        assert 1000 <= h.percentile(0.5) <= 2000
        assert 100000 <= h.percentile(0.99) <= 200000


# --------------------------------------------------------------------------
# disabled mode: a real chunked collective must not write one record
# --------------------------------------------------------------------------

class _CountingRecorder(Tracer):
    def __init__(self):
        super().__init__(capacity=16, enabled=False)
        self.emit_calls = 0

    def emit(self, ev, a0=0, a1=0, a2=0):
        self.emit_calls += 1
        super().emit(ev, a0, a1, a2)


class TestDisabledMode:
    def test_zero_emits_across_chunked_iallreduce(self):
        """Every instrumentation site sits behind ``if tr.enabled:``
        (LP005); with tracing off, a full chunked iallreduce plus pt2pt
        traffic must reach the recorder exactly zero times."""
        rec = _CountingRecorder()

        def prog(env):
            c = env.comm
            assert c.tracer is rec               # injected recorder
            x = np.full((1 * MiB) // 8, float(env.rank + 1))
            c.iallreduce(x, algo="ring", chunk_bytes=128 << 10).wait(30)
            peer = 1 - env.rank
            c.send(peer, b"x" * 64, tag=1)
            c.recv(peer, tag=1)
            return True

        assert all(run_threads(2, prog, pool_bytes=64 << 20,
                               comm_kw={"trace": rec}, timeout=120))
        assert rec.emit_calls == 0
        assert rec.recorded == 0


# --------------------------------------------------------------------------
# traced end-to-end run: report + dumps + Chrome export + CLI
# --------------------------------------------------------------------------

def _traced_run(tmp_path):
    """2 thread ranks, tracing on: chunked iallreduce + posted-rendezvous
    pt2pt + an RMA notified-put epoch; returns the per-rank dump paths."""
    def prog(env):
        c = env.comm
        x = np.full((1 * MiB) // 8, float(env.rank + 1))
        c.iallreduce(x, algo="ring", chunk_bytes=256 << 10).wait(30)
        if env.rank == 0:
            c.recv(1, tag=2)                     # credit: entry live
            c.send(1, b"\xab" * (256 << 10), tag=1)
        else:
            pb = c.alloc_buffer(256 << 10)
            rreq = c.irecv_into(0, pb, tag=1)
            c.send(0, b"", tag=2)
            rreq.wait(30)
            pb.free()
        w = c.win_allocate("ttrace", 4096)
        w.lock_all()
        if env.rank == 0:
            w.put_notify(1, 0, b"\xcd" * 512)
        else:
            w.wait_notify(0)
        w.unlock_all()
        w.fence()
        w.free()
        report = c.trace_report()
        path = c.trace_dump(tmp_path / f"rank{env.rank}.json")
        return report, path

    res = run_threads(2, prog, pool_bytes=64 << 20, eager_threshold=0,
                      comm_kw={"trace": True}, timeout=120)
    return res


class TestTracedRun:
    @pytest.fixture(scope="class")
    def run(self, tmp_path_factory):
        return _traced_run(tmp_path_factory.mktemp("trace"))

    def test_report_surfaces_latency_histograms(self, run):
        r0, _ = run[0][0], run[0][1]
        r1 = run[1][0]
        assert r0["enabled"] and r0["events_recorded"] > 0
        # engine-tick occupancy on both ranks
        assert r0["histograms"]["engine_tick_ns"]["count"] > 0
        assert r1["histograms"]["engine_tick_ns"]["count"] > 0
        # posted-hit latency on the receiving rank (post->consume)
        assert r1["histograms"]["posted_hit_ns"]["count"] >= 1
        # wait_notify spin latency on the notified rank
        assert r1["histograms"]["notify_wait_ns"]["count"] >= 1
        # unified with ProtocolStats
        assert r0["protocol_stats"]["copied_bytes"] > 0

    def test_event_taxonomy_coverage(self, run):
        kinds = set()
        for report, _ in run:
            kinds.update(report["counters"])
        named = kinds & set(EV_NAMES.values())
        assert len(named) >= 8, sorted(named)    # acceptance bar
        assert any(k.startswith("pt2pt.") for k in named)
        assert any(k.startswith("sched.") for k in named)
        assert any(k.startswith("mb.") for k in named)
        assert any(k.startswith("rma.") for k in named)

    def test_chrome_export_roundtrip_and_lane_discipline(self, run):
        dumps = [load_dump(p) for _, p in run]
        merged = merge_dumps(dumps)
        merged = json.loads(json.dumps(merged))  # JSON round-trip
        evs = merged["traceEvents"]
        assert {e["ph"] for e in evs} >= {"X", "M"}
        names = {e["name"] for e in evs if e["ph"] != "M"}
        assert len(names) >= 8
        assert {e["pid"] for e in evs} == {0, 1}   # one lane per rank
        # duration slices on one (pid, tid) lane never overlap and are
        # time-ordered — Perfetto renders them as clean nested tracks
        lanes = {}
        for e in evs:
            if e["ph"] == "X":
                lanes.setdefault((e["pid"], e["tid"]), []).append(e)
        assert lanes
        for lane in lanes.values():
            lane.sort(key=lambda e: e["ts"])
            for a, b in zip(lane, lane[1:]):
                assert a["ts"] + a["dur"] <= b["ts"] + 1e-6, (a, b)

    def test_cli_merge_and_summarize(self, run, tmp_path, capsys):
        from repro.trace import main
        files = [str(p) for _, p in run]
        out = tmp_path / "timeline.json"
        assert main(["merge", *files, "-o", str(out)]) == 0
        trace = json.loads(out.read_text())
        assert trace["traceEvents"]
        assert main(["summarize", *files, "--top", "5"]) == 0
        text = capsys.readouterr().out
        assert "engine.tick" in text
        assert summarize_dumps([load_dump(p) for _, p in run])

    def test_cli_missing_file_fails(self, tmp_path, capsys):
        from repro.trace import main
        assert main(["merge", str(tmp_path / "nope.json")]) == 1
        assert "missing dump" in capsys.readouterr().err

    def test_chrome_events_skips_disabled_empty_dump(self, tmp_path):
        tr = Tracer(capacity=4, enabled=False)
        p = tr.dump(tmp_path / "empty.json")
        evs = chrome_events(load_dump(p))
        assert all(e["ph"] == "M" for e in evs)  # metadata only


# --------------------------------------------------------------------------
# ProtocolStats snapshot/delta + the count_path upsert regression
# --------------------------------------------------------------------------

class TestProtocolStatsDelta:
    def test_snapshot_is_deep_and_delta_diffs(self):
        st = ProtocolStats()
        st.copies, st.copied_bytes = 2, 100
        st.path_copied_bytes["eager"] = 100
        s0 = st.snapshot()
        st.copies, st.copied_bytes = 5, 350
        st.path_copied_bytes["eager"] += 250
        assert s0["copied_bytes"] == 100         # unaffected by later moves
        d = st.delta(s0)
        assert d["copies"] == 3
        assert d["copied_bytes"] == 250
        # only the paths that moved survive the per-path diff
        assert d["path_copied_bytes"] == {"eager": 250}

    def test_delta_tolerates_older_snapshot_missing_keys(self):
        st = ProtocolStats()
        st.fences = 4
        s0 = st.snapshot()
        del s0["fences"]                          # snapshot from old code
        st.fences = 9
        assert st.delta(s0)["fences"] == 9        # diffs against zero

    def test_count_path_upserts_unknown_bucket(self):
        """Regression: count_path("serve_hot", ...) used to KeyError on
        any path outside the pre-declared dict; new subsystems must be
        able to attribute traffic without editing coherence.py."""
        v = CoherentView(LocalPool(1 << 16), "coherent")
        v.count_path("rndv_posted", 64)           # pre-declared bucket
        v.count_path("serve_hot", 128)            # unknown: upsert
        v.count_path("serve_hot", 128)
        assert v.stats.path_copied_bytes["rndv_posted"] == 64
        assert v.stats.path_copied_bytes["serve_hot"] == 256
        # pre-declared zero-traffic buckets still report 0
        assert v.stats.path_copied_bytes["rma_put"] == 0
