"""Distribution layer tests that need >1 device run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest process
keeps the real single-device view, per the assignment)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_sub(code: str) -> dict:
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                                          "HOME": "/root"}, timeout=540)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_vocab_parallel_matches_dense():
    """vp_embed + vp_cross_entropy == dense reference on a 2x4 mesh."""
    res = run_sub("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.context import DistContext
        from repro.configs import get_config
        import dataclasses

        cfg = dataclasses.replace(
            get_config('smollm-135m').reduced(), vocab_parallel=True,
            vocab_size=64, vocab_pad_multiple=4, compute_dtype='float32')
        mesh = make_test_mesh((2, 4), ('data', 'model'))
        dist = DistContext(mesh)
        V, D = cfg.padded_vocab, cfg.d_model
        key = jax.random.key(0)
        table = jax.random.normal(key, (V, D), jnp.float32)
        toks = jax.random.randint(jax.random.key(1), (4, 8), 0,
                                  cfg.vocab_size)
        got = dist.vp_embed(table, toks, cfg)
        want = table[toks]
        e1 = float(jnp.abs(got - want).max())

        x = jax.random.normal(jax.random.key(2), (4, 8, D), jnp.float32)
        labels = toks
        ce = dist.vp_cross_entropy(table, x, labels, cfg)
        logits = jnp.einsum('bsd,vd->bsv', x, table)[..., :cfg.vocab_size]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        e2 = float(jnp.abs(ce - (lse - ll)).max())

        tok = dist.vp_greedy_token(table, x[:, 0], cfg)
        want_tok = jnp.argmax(logits[:, 0], axis=-1)
        e3 = int((tok != want_tok).sum())
        print(json.dumps({'e_embed': e1, 'e_ce': e2, 'argmax_mism': e3}))
    """)
    assert res["e_embed"] < 1e-5
    assert res["e_ce"] < 1e-4
    assert res["argmax_mism"] == 0


@pytest.mark.slow
def test_cmpi_sync_grads_and_compression():
    """Hierarchical shard_map gradient sync == reference step; int8-pod
    compression stays within quantization error."""
    res = run_sub("""
        import json, dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_config, SHAPES
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.schedules import make_cmpi_train_step
        from repro.models import lm
        from repro.train import optimizer as opt, data as D

        cfg = get_config('smollm-135m').reduced()
        shape = dataclasses.replace(SHAPES['train_4k'], seq_len=32,
                                    global_batch=8)
        mesh = make_test_mesh((2, 2, 2), ('pod', 'data', 'model'))
        params = lm.init(cfg, jax.random.key(0))
        oc = opt.for_model(cfg)
        ostate = opt.init(oc, params)
        ds = D.SyntheticLM(D.for_model(cfg, shape))
        batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

        def ref_loss(p):
            return lm.loss_fn(p, cfg, batch)
        (_, _), g = jax.value_and_grad(ref_loss, has_aux=True)(params)
        rp, _, _ = opt.apply_updates(oc, params, g, ostate)

        out = {}
        for comp in ('none', 'int8'):
            fn, in_sh, out_sh = make_cmpi_train_step(cfg, shape, mesh,
                                                     compression=comp)
            jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            p2, o2, m = jfn(params, ostate, batch)
            d = max(float(jnp.abs(a - b).max()) for a, b in
                    zip(jax.tree.leaves(p2), jax.tree.leaves(rp)))
            out[comp] = d
        print(json.dumps(out))
    """)
    assert res["none"] < 1e-4          # exact up to reduction order
    assert res["int8"] < 5e-3          # bounded quantization error


@pytest.mark.slow
def test_small_mesh_dryrun_lowers():
    """A miniature of the production dry-run: lower + compile train and
    decode steps for a reduced arch on (2,2,2) — proves the sharding rules
    are coherent end-to-end without the 512-device cost."""
    res = run_sub("""
        import json, dataclasses
        import jax
        from repro.configs import get_config, SHAPES
        from repro.launch.mesh import make_test_mesh
        from repro.launch import specs as SP
        from repro.train import steps as ST

        cfg = dataclasses.replace(get_config('llama3-8b').reduced(),
                                  d_model=64, n_heads=8, n_kv_heads=4,
                                  d_head=8, vocab_size=256,
                                  vocab_pad_multiple=16)
        shape = dataclasses.replace(SHAPES['train_4k'], seq_len=64,
                                    global_batch=8)
        mesh = make_test_mesh((2, 2, 2), ('pod', 'data', 'model'))
        ts = ST.make_train_step(cfg, shape, mesh)
        lowered = jax.jit(ts.fn, in_shardings=ts.in_shardings,
                          out_shardings=ts.out_shardings).lower(
            SP.param_specs(cfg), SP.opt_state_specs(cfg),
            SP.batch_specs(cfg, shape))
        c1 = lowered.compile()

        dshape = dataclasses.replace(SHAPES['decode_32k'], seq_len=64,
                                     global_batch=8)
        ss = ST.make_serve_decode(cfg, dshape, mesh)
        state, pos = SP.decode_specs(cfg, dshape)
        c2 = jax.jit(ss.fn, in_shardings=ss.in_shardings,
                     out_shardings=ss.out_shardings).lower(
            SP.param_specs(cfg), state, SP.batch_specs(cfg, dshape),
            pos).compile()
        print(json.dumps({
            'train_mem': int(c1.memory_analysis().temp_size_in_bytes),
            'decode_mem': int(c2.memory_analysis().temp_size_in_bytes)}))
    """)
    assert res["train_mem"] > 0
    assert res["decode_mem"] >= 0


@pytest.mark.slow
def test_moe_ep_a2a_matches_dense_dispatch():
    """shard_map expert-parallel MoE == GSPMD dense-dispatch MoE."""
    res = run_sub("""
        import json, dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.context import DistContext
        from repro.models import blocks as B

        cfg = dataclasses.replace(
            get_config('granite-moe-1b-a400m').reduced(),
            compute_dtype='float32')
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, capacity_factor=8.0))
        mesh = make_test_mesh((2, 4), ('data', 'model'))
        dist = DistContext(mesh)
        params = B.moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model),
                              jnp.float32)
        dense, _ = B.moe_apply(params, cfg, x)
        ep, _ = B.moe_apply_ep(params, cfg, x, dist)
        print(json.dumps(
            {'maxdiff': float(jnp.abs(dense - ep).max())}))
    """)
    assert res["maxdiff"] < 1e-4


@pytest.mark.slow
def test_flashdecode_matches_auto():
    """decode_attn=flashdecode (seq-sharded scores, LSE via psum) must be
    numerically equivalent to the gather-based auto path on a mesh."""
    res = run_sub("""
        import json, dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.context import DistContext
        from repro.models import lm

        base = dataclasses.replace(
            get_config('llama3-8b').reduced(), compute_dtype='float32',
            n_heads=8, n_kv_heads=4, d_head=8, d_model=64,
            vocab_size=64, vocab_pad_multiple=4)
        mesh = make_test_mesh((2, 4), ('data', 'model'))
        dist = DistContext(mesh)
        params = lm.init(base, jax.random.key(0))
        b, cl = 4, 8
        toks = np.random.default_rng(0).integers(
            0, base.vocab_size, (b, 4)).astype(np.int32)

        def roll(cfg):
            st = lm.decode_state_init(cfg, b, cl)
            outs = []
            for i in range(4):
                lg, st = lm.decode_step(
                    params, cfg, st, {'tokens': jnp.asarray(toks[:, i:i+1])},
                    jnp.full((b,), i, jnp.int32), dist=dist)
                outs.append(np.asarray(lg))
            return np.stack(outs)

        auto = roll(base)
        fd = roll(dataclasses.replace(base, decode_attn='flashdecode'))
        print(json.dumps({'maxdiff': float(np.abs(auto - fd).max())}))
    """)
    assert res["maxdiff"] < 1e-4


def test_compression_roundtrip_bounds():
    from repro.distributed import compression as C
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    q, s = C.int8_encode(x)
    dec = C.int8_decode(q, s)
    err = np.abs(np.asarray(dec - x))
    bound = np.asarray(s) / 2 + 1e-7    # half-step quantization bound
    assert (err <= bound + 1e-6).all()
    # error feedback drives mean residual toward zero over steps
    resid = C.ErrorFeedback.init({"g": x})
    total = jnp.zeros_like(x)
    for _ in range(4):
        comp, new_r = C.ErrorFeedback.apply({"g": x}, resid)
        qq, ss = C.int8_encode(comp["g"])
        dec = C.int8_decode(qq, ss)
        resid = new_r({"g": dec})
        total = total + dec
    # accumulated decode ~= 4x the true signal (residual carried)
    assert float(jnp.abs(total / 4 - x).max()) < float(np.asarray(s).max())


def test_sharding_rules_cover_all_archs():
    """param_pspecs ranks match leaf ranks for every arch (no silent
    mis-specified leaves), on an abstract mesh."""
    from unittest import mock
    from repro.configs import ARCHS, get_config
    from repro.distributed import sharding as shd
    from repro.models import lm

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    for arch in ARCHS:
        cfg = get_config(arch)
        specs = lm.param_specs(cfg)
        pspecs = shd.param_pspecs(cfg, FakeMesh())
        for leaf, spec in zip(jax.tree.leaves(specs),
                              jax.tree.leaves(
                                  pspecs,
                                  is_leaf=lambda x: isinstance(
                                      x, jax.sharding.PartitionSpec))):
            assert len(spec) <= len(leaf.shape), (arch, leaf.shape, spec)
