"""Two-sided pt2pt (§3.3) + collectives over cMPI, coherent AND incoherent
pools, plus the real-process runtime."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (allgather_bruck, allgather_ring, allreduce,
                        alltoall, barrier_dissemination, bcast, reduce,
                        run_processes, run_threads)
from repro.core.collectives import allreduce_rd, reduce_scatter_ring


class TestP2P:
    @pytest.mark.parametrize("coherent", [True, False])
    def test_ring_exchange(self, coherent):
        def prog(env):
            r, n = env.rank, env.size
            env.comm.send((r + 1) % n, f"m{r}".encode(), tag=5)
            return env.comm.recv((r - 1) % n, tag=5)[0]

        res = run_threads(4, prog, coherent=coherent)
        assert [res[r] for r in range(4)] == \
            [f"m{(r - 1) % 4}".encode() for r in range(4)]

    def test_tag_matching_reorders(self):
        def prog(env):
            if env.rank == 0:
                env.comm.send(1, b"first", tag=1)
                env.comm.send(1, b"second", tag=2)
            if env.rank == 1:
                # receive out of order: tag 2 first
                b2, _ = env.comm.recv(0, tag=2)
                b1, _ = env.comm.recv(0, tag=1)
                return (b1, b2)
            return None

        res = run_threads(2, prog)
        assert res[1] == (b"first", b"second")

    def test_head_to_head_isend(self):
        """Both ranks isend a queue-overflowing message then recv — the
        progress engine must avoid the classic deadlock."""
        big = bytes(200_000)

        def prog(env):
            peer = 1 - env.rank
            req = env.comm.isend(peer, big, tag=9)
            data, _ = env.comm.recv(peer, tag=9, timeout=60)
            req.wait(60)
            return len(data)

        res = run_threads(2, prog, cell_size=4096, n_cells=4, timeout=120)
        assert res == [200_000, 200_000]

    def test_self_send(self):
        def prog(env):
            env.comm.send(env.rank, b"self", tag=3)
            return env.comm.recv(env.rank, tag=3)[0]

        assert run_threads(2, prog) == [b"self", b"self"]

    def test_real_processes(self):
        """The shared-memory pool between REAL processes (fork)."""
        def prog(env):
            peer = 1 - env.rank
            env.comm.send(peer, f"proc{env.rank}".encode() * 100, tag=1)
            return env.comm.recv(peer, tag=1)[0][:6]

        res = run_processes(2, prog, pool_bytes=32 << 20)
        assert res[0] == b"proc1p"[:6] or res[0].startswith(b"proc1")
        assert res[1].startswith(b"proc0")


class TestCollectives:
    @pytest.mark.parametrize("n", [2, 3, 4, 5])
    def test_allreduce_ring(self, n):
        def prog(env):
            x = (np.arange(23.0) + 1) * (env.rank + 1)
            return allreduce(env.comm, x, algo="ring")

        exp = (np.arange(23.0) + 1) * sum(range(1, n + 1))
        for out in run_threads(n, prog):
            assert np.allclose(out, exp)

    @pytest.mark.parametrize("n", [2, 4, 8])
    def test_allreduce_recursive_doubling(self, n):
        def prog(env):
            return allreduce_rd(env.comm,
                                np.full(7, float(env.rank + 1)))

        for out in run_threads(n, prog):
            assert np.allclose(out, sum(range(1, n + 1)))

    @pytest.mark.parametrize("n", [2, 3, 5])
    def test_allgather_both(self, n):
        def prog(env):
            shard = np.array([env.rank, env.rank * 10])
            return (allgather_bruck(env.comm, shard),
                    allgather_ring(env.comm, shard).reshape(-1))

        exp = np.array([v for i in range(n) for v in (i, i * 10)])
        for bruck, ring in run_threads(n, prog):
            assert np.array_equal(bruck, exp)
            assert np.array_equal(ring, exp)

    def test_reduce_scatter(self):
        n = 4

        def prog(env):
            x = np.arange(8.0) + env.rank
            return reduce_scatter_ring(env.comm, x)

        res = run_threads(n, prog)
        full = sum(np.arange(8.0) + r for r in range(n))
        for r in range(n):
            assert np.allclose(res[r], full[2 * ((r + 1) % n):
                                            2 * ((r + 1) % n) + 2])

    def test_bcast_reduce(self):
        def prog(env):
            data = np.arange(6.0) if env.rank == 1 else None
            b = bcast(env.comm, data, root=1)
            s = reduce(env.comm, np.full(3, float(env.rank)), root=0)
            return b, s

        res = run_threads(3, prog)
        for r, (b, s) in enumerate(res):
            assert np.allclose(b, np.arange(6.0))
            if r == 0:
                assert np.allclose(s, 3.0)   # 0+1+2

    @pytest.mark.parametrize("n,root", [(3, 1), (5, 2), (5, 4), (6, 3),
                                        (7, 5)])
    def test_bcast_nonzero_root_non_pow2(self, n, root):
        """Regression for the binomial-tree forwarding loop: every rank
        must receive with non-zero roots at non-power-of-two sizes."""
        def prog(env):
            data = (np.arange(11.0) * 3 + root) if env.rank == root \
                else None
            return bcast(env.comm, data, root=root)

        for out in run_threads(n, prog):
            assert np.allclose(out, np.arange(11.0) * 3 + root)

    def test_alltoall(self):
        n = 4

        def prog(env):
            blocks = [np.array([env.rank * 100 + d]) for d in range(n)]
            return alltoall(env.comm, blocks)

        res = run_threads(n, prog)
        for r in range(n):
            assert [int(b[0]) for b in res[r]] == \
                [s * 100 + r for s in range(n)]

    def test_barrier(self):
        def prog(env):
            barrier_dissemination(env.comm)
            return True

        assert all(run_threads(5, prog))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(1, 64))
def test_property_allreduce_matches_numpy(n, size):
    def prog(env):
        rng = np.random.default_rng(env.rank)
        x = rng.normal(size=size)
        return x, allreduce(env.comm, x, algo="ring")

    res = run_threads(n, prog)
    expected = sum(r[0] for r in res)
    for _, got in res:
        assert np.allclose(got, expected)
