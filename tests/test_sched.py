"""Schedule-DAG collectives: the IR + compile cache, the shared
progress engine (nonblocking i* collectives, mixed-request wait
helpers), MPI-4 persistent collectives with round-synchronized
pre-posting, matchbox sizing/capacity-miss accounting, tag-space
isolation of collectives from ANY_TAG traffic, and the real-peer
eager-threshold probe."""
import time

import numpy as np
import pytest

from repro.core import run_threads
from repro.core.sched import (MAX_ROUNDS, RecvOp, ReduceOp, SendOp,
                              compile_schedule)

CELL = 4096


class _StubComm:
    """compile_schedule needs only (size, rank, _sched_cache)."""

    def __init__(self, n, rank):
        self.size = n
        self.rank = rank
        self._sched_cache = {}


# --------------------------------------------------------------------------
# IR + compiler
# --------------------------------------------------------------------------

class TestScheduleIR:
    @pytest.mark.parametrize("kind,nbytes", [
        ("allreduce_rd", 4096), ("allreduce_ring", 4096),
        ("reduce_scatter_ring", 4096), ("allgather_ring", 512),
        ("allgather_bruck", 512), ("bcast", 4096), ("reduce", 4096),
        ("barrier", 0)])
    def test_compiles_valid_dags_all_ranks(self, kind, nbytes):
        for n in (2, 3, 4, 5, 8):
            if kind == "allreduce_rd" and n & (n - 1):
                continue
            for rank in range(n):
                s = compile_schedule(_StubComm(n, rank), kind, nbytes, 8)
                s.validate()     # deps strictly backward, rounds in span
                # every send and recv names a peer inside the comm
                for nd in s.nodes:
                    if isinstance(nd, (SendOp, RecvOp)):
                        assert 0 <= nd.peer < n and nd.peer != rank

    def test_compile_cached_per_key(self):
        c = _StubComm(4, 1)
        a = compile_schedule(c, "allreduce_ring", 4096, 8)
        b = compile_schedule(c, "allreduce_ring", 4096, 8)
        assert a is b                        # one compile per key
        assert compile_schedule(c, "allreduce_ring", 8192, 8) is not a

    def test_rd_recvs_preposted(self):
        """Every recursive-doubling receive is dependency-free (own
        slot per round), so the engine pre-posts all of them at start —
        the matchbox-priming property persistent collectives rely on."""
        s = compile_schedule(_StubComm(8, 3), "allreduce_rd", 1024, 8)
        recvs = s.recv_nodes()
        assert len(recvs) == 3
        assert all(not nd.deps for nd in recvs)
        assert s.max_recvs_per_peer() == 1   # one round per peer

    def test_ring_ag_recvs_wait_for_rs_sends(self):
        """The fused ring's allgather receives target chunks the RS
        phase sourced — they must carry the anti-hazard dependency."""
        s = compile_schedule(_StubComm(4, 0), "allreduce_ring", 4096, 8)
        rs = [nd for nd in s.nodes if isinstance(nd, RecvOp)
              and nd.round < 3]
        ag = [nd for nd in s.nodes if isinstance(nd, RecvOp)
              and nd.round >= 3]
        assert all(not nd.deps for nd in rs)
        assert all(nd.deps for nd in ag)
        assert s.max_recvs_per_peer() == 6   # all from `left`

    def test_reduce_sum_of_reduceops_covers_children(self):
        s = compile_schedule(_StubComm(7, 0), "reduce", 512, 8, root=0)
        # root of 7 ranks folds in children 1, 2, 4 -> three ReduceOps
        assert sum(isinstance(nd, ReduceOp) for nd in s.nodes) == 3

    def test_hier_compiles_valid_dags_all_ranks(self):
        for n, g in [(4, 2), (6, 3), (8, 2), (8, 4), (16, 4)]:
            for rank in range(n):
                s = compile_schedule(_StubComm(n, rank), "allreduce_hier",
                                     4096, 8, group=g)
                s.validate()
                for nd in s.nodes:
                    if isinstance(nd, (SendOp, RecvOp)):
                        assert 0 <= nd.peer < n and nd.peer != rank
                # phase structure: (g-1) RS + log2(n/g) inter + (g-1) AG
                m = n // g
                assert s.rounds == 2 * (g - 1) + (m.bit_length() - 1)

    def test_hier_inter_peers_cross_groups(self):
        """Inter-phase partners hold the SAME chunk index in other
        groups: peer = (group ^ 2^j) * g + local."""
        s = compile_schedule(_StubComm(8, 3), "allreduce_hier", 4096, 8,
                             group=2)
        inter = [nd for nd in s.nodes if isinstance(nd, SendOp)
                 and 1 <= nd.round <= 2]
        assert sorted(nd.peer for nd in inter) == [1, 7]   # 3^2g, 3^4g


# --------------------------------------------------------------------------
# chunked schedules (the schedule-level pipelining tentpole)
# --------------------------------------------------------------------------

class TestChunkedSchedules:
    @pytest.mark.parametrize("kind,nbytes", [
        ("allreduce_rd", 1 << 16), ("allreduce_ring", 1 << 16),
        ("reduce_scatter_ring", 1 << 16), ("allgather_ring", 1 << 14),
        ("allgather_bruck", 1 << 14), ("bcast", 1 << 16),
        ("reduce", 1 << 16), ("barrier", 0)])
    def test_chunked_compiles_valid_all_ranks(self, kind, nbytes):
        for n in (2, 3, 4, 5, 8):
            if kind == "allreduce_rd" and n & (n - 1):
                continue
            for rank in range(n):
                s = compile_schedule(_StubComm(n, rank), kind, nbytes, 8,
                                     chunk_bytes=4096)
                s.validate()
                for nd in s.nodes:
                    if isinstance(nd, (SendOp, RecvOp)):
                        assert nd.buf.nbytes <= 4096

    def test_rounds_count_submessages(self):
        """Chunking a round into N sub-messages gives it N sub-rounds
        (distinct wire tags; the CollRequest timeout satellite rides on
        this count too)."""
        c = _StubComm(2, 0)
        base = compile_schedule(c, "allreduce_rd", 1 << 16, 8)
        chunked = compile_schedule(c, "allreduce_rd", 1 << 16, 8,
                                   chunk_bytes=4096)
        assert base.rounds == 1
        assert chunked.rounds == 16          # 64 KiB / 4 KiB
        assert chunked.chunk_bytes == 4096

    def test_chunkwise_deps_pipeline_bcast(self):
        """An interior rank's forward of chunk c depends on the RECEIVE
        of chunk c (plus the slot's send chain) — never on later
        chunks. That is the pipelining property."""
        s = compile_schedule(_StubComm(4, 1), "bcast", 1 << 14, 8,
                             root=0, chunk_bytes=4096)
        recvs = [nd for nd in s.nodes if isinstance(nd, RecvOp)]
        sends = [nd for nd in s.nodes if isinstance(nd, SendOp)]
        assert len(recvs) == 4 and len(sends) == 4
        first_fwd = sends[0]
        assert recvs[0].idx in first_fwd.deps
        assert all(r.idx not in first_fwd.deps for r in recvs[1:])

    def test_send_chain_one_per_slot(self):
        """Sub-sends sourcing one slot are totally ordered (a PoolBuffer
        has ONE drain-ack word)."""
        s = compile_schedule(_StubComm(4, 2), "allreduce_ring", 1 << 16,
                             8, chunk_bytes=2048)
        prev = None
        for nd in s.nodes:
            if isinstance(nd, SendOp) and nd.buf.slot == 0:
                if prev is not None:
                    assert prev in _ancestors(s, nd.idx), \
                        "slot-0 sends must chain"
                prev = nd.idx

    @pytest.mark.parametrize("kind,nbytes", [
        ("reduce", 1 << 16), ("bcast", 1 << 16),
        ("allreduce_ring", 1 << 16), ("allgather_bruck", 1 << 14)])
    def test_chunked_subrounds_agree_across_ranks(self, kind, nbytes):
        """Wire consistency: every chunked send must have exactly one
        matching chunked receive at the SAME sub-round on its peer —
        ranks that skip base rounds (tree leaves) must still agree on
        the sub-round numbering (uniform per-round windows)."""
        for n in (2, 3, 5, 6):
            scheds = [compile_schedule(_StubComm(n, r), kind, nbytes, 8,
                                       chunk_bytes=4096)
                      for r in range(n)]
            sends = sorted((r, nd.peer, nd.round, nd.buf.nbytes)
                           for r, s in enumerate(scheds)
                           for nd in s.nodes if isinstance(nd, SendOp))
            recvs = sorted((nd.peer, r, nd.round, nd.buf.nbytes)
                           for r, s in enumerate(scheds)
                           for nd in s.nodes if isinstance(nd, RecvOp))
            assert sends == recvs

    def test_chunk_bytes_widens_to_fit_tag_window(self):
        """A chunk size that would blow MAX_ROUNDS is widened, never
        rejected."""
        s = compile_schedule(_StubComm(2, 0), "allreduce_rd", 1 << 22, 8,
                             chunk_bytes=256)
        assert s.rounds <= MAX_ROUNDS
        assert s.chunk_bytes > 256

    def test_widening_agrees_across_ranks(self):
        """The MAX_ROUNDS widening loop runs off ``base.rounds * span``
        — both rank-UNIFORM (a reduce leaf breaks out of the tree
        early, but its schedule still reports the full depth), so every
        rank widens to the SAME chunk size and the wire stays
        consistent."""
        for kind in ("reduce", "bcast", "allreduce_ring"):
            for n in (3, 5, 6):
                scheds = [compile_schedule(_StubComm(n, r), kind,
                                           1 << 20, 8, chunk_bytes=256)
                          for r in range(n)]
                assert len({s.chunk_bytes for s in scheds}) == 1
                assert all(s.rounds <= MAX_ROUNDS for s in scheds)
                sends = sorted((r, nd.peer, nd.round, nd.buf.nbytes)
                               for r, s in enumerate(scheds)
                               for nd in s.nodes
                               if isinstance(nd, SendOp))
                recvs = sorted((nd.peer, r, nd.round, nd.buf.nbytes)
                               for r, s in enumerate(scheds)
                               for nd in s.nodes
                               if isinstance(nd, RecvOp))
                assert sends == recvs

    def test_recvs_stay_preposted(self):
        """Dependency-free receives stay dependency-free per chunk —
        the whole sub-receive fan pre-posts at exec start."""
        s = compile_schedule(_StubComm(2, 0), "allreduce_rd", 1 << 14, 8,
                             chunk_bytes=4096)
        recvs = [nd for nd in s.nodes if isinstance(nd, RecvOp)]
        assert len(recvs) == 4
        assert all(not nd.deps for nd in recvs)
        assert s.max_recvs_per_peer() == 4


def _ancestors(sched, idx):
    out = set()
    stack = list(sched.nodes[idx].deps)
    while stack:
        d = stack.pop()
        if d not in out:
            out.add(d)
            stack.extend(sched.nodes[d].deps)
    return out


# --------------------------------------------------------------------------
# nonblocking collectives over the shared progress engine
# --------------------------------------------------------------------------

class TestNonblockingCollectives:
    @pytest.mark.parametrize("n,nelem,algo", [(2, 31, "rd"),
                                              (3, 4000, "ring"),
                                              (4, 4000, "rd")])
    def test_iallreduce_with_injected_compute(self, n, nelem, algo):
        """Compute between start and wait, ticking comm.progress() —
        the overlap usage pattern — still reduces correctly."""
        def prog(env):
            x = (np.arange(nelem, dtype=np.float64) + 1) * (env.rank + 1)
            req = env.comm.iallreduce(x, algo=algo)
            acc = np.zeros(64)
            for i in range(50):              # injected compute
                acc += np.sin(acc + i)
                env.comm.progress()
            out = req.wait(60)
            return out, acc

        exp = (np.arange(nelem, dtype=np.float64) + 1) * sum(
            range(1, n + 1))
        for out, _ in run_threads(n, prog, cell_size=CELL,
                                  pool_bytes=32 << 20, timeout=120):
            assert np.allclose(out, exp)

    def test_ibcast_in_place(self):
        def prog(env):
            buf = (np.arange(5000.0) if env.rank == 1
                   else np.zeros(5000))
            out = env.comm.ibcast(buf, root=1).wait(60)
            assert out is buf                # in-place MPI semantics
            return buf

        for out in run_threads(3, prog, cell_size=CELL,
                               pool_bytes=32 << 20, timeout=120):
            assert np.allclose(out, np.arange(5000.0))

    def test_iallgather_ireduce_scatter_ibarrier(self):
        n = 4

        def prog(env):
            c = env.comm
            g = c.iallgather(np.full(700, float(env.rank))).wait(60)
            rs = c.ireduce_scatter(np.arange(8.0) + env.rank).wait(60)
            c.ibarrier().wait(60)
            return g, rs

        res = run_threads(n, prog, cell_size=CELL, pool_bytes=32 << 20,
                          timeout=120)
        full = sum(np.arange(8.0) + r for r in range(n))
        for r, (g, rs) in enumerate(res):
            assert np.allclose(g.reshape(n, -1)[2], 2.0)
            k = 2 * ((r + 1) % n)
            assert np.allclose(rs, full[k:k + 2])

    def test_concurrent_collectives_disjoint_tags(self):
        """Three collectives in flight at once on one communicator:
        per-launch tag windows keep their rounds apart."""
        def prog(env):
            c = env.comm
            r1 = c.iallreduce(np.full(3000, float(env.rank + 1)))
            r2 = c.iallgather(np.array([env.rank * 7.0]))
            r3 = c.ibarrier()
            c.waitall([r1, r2, r3], timeout=60)
            return r1.result, r2.result

        for a, g in run_threads(3, prog, cell_size=CELL,
                                pool_bytes=32 << 20, timeout=120):
            assert np.allclose(a, 6.0)
            assert np.allclose(g, [0.0, 7.0, 14.0])

    def test_collectives_isolated_from_any_tag_recv(self):
        """An outstanding ANY_TAG user receive must not swallow
        collective rounds: reserved tags are excluded from wildcard
        matching (both queue matching and matchbox wildcard entries)."""
        def prog(env):
            c = env.comm
            peer = 1 - env.rank
            ur = c.irecv(peer, tag=-1)       # ANY_TAG, posted FIRST
            a = c.iallreduce(np.full(4000, float(env.rank + 1)))
            c.ibarrier().wait(60)
            out = a.wait(60)
            c.send(peer, b"user-payload", tag=3)
            data = ur.wait(60)
            return out[0], data

        for s, data in run_threads(2, prog, cell_size=CELL,
                                   pool_bytes=32 << 20, timeout=120):
            assert s == 3.0
            assert data == b"user-payload"

    def test_free_function_shims_match_methods(self):
        """The deprecated free functions route through the SAME
        schedules (heap backend) and agree with the method results."""
        from repro.core import collectives as coll

        def prog(env):
            x = np.arange(600.0) * (env.rank + 1)
            a = coll.allreduce(env.comm, x, algo="ring")
            b = env.comm.allreduce(x, algo="ring")
            return np.allclose(a, b)

        assert all(run_threads(3, prog, cell_size=CELL,
                               pool_bytes=32 << 20, timeout=120))


# --------------------------------------------------------------------------
# persistent collectives: round-synchronized pre-post
# --------------------------------------------------------------------------

class TestPersistentCollectives:
    @pytest.mark.parametrize("n,algo", [(2, "rd"), (3, "ring"),
                                        (4, "rd")])
    def test_allreduce_init_iterations(self, n, algo):
        iters = 5

        def prog(env):
            c = env.comm
            x = np.zeros(3000)
            req = c.allreduce_init(x, algo=algo)
            h0, r0 = c.posted_sends, c.rndv_sends
            vals = []
            slots = []
            for i in range(iters):
                x[:] = float(i * (env.rank + 1))
                vals.append(float(req.start().wait(60)[0]))
                c.barrier()
                slots.append(env.arena.stats()["slots_used"])
            hits, rndv = c.posted_sends - h0, c.rndv_sends - r0
            c.barrier()      # peers may still be reading slot counts
            req.free()
            return vals, hits, rndv, slots

        res = run_threads(n, prog, cell_size=CELL, pool_bytes=64 << 20,
                          comm_kw={"matchbox_slots": 16}, timeout=120)
        exp = [i * sum(range(1, n + 1)) for i in range(iters)]
        for vals, hits, rndv, slots in res:
            assert vals == [float(v) for v in exp]
            # deterministic 100% posted-hit rate (matchbox sized to
            # 2x schedule depth), flat arena footprint across rounds
            assert hits == rndv and rndv >= iters
            assert len(set(slots)) == 1

    def test_heap_persistent_survives_restarts(self):
        """Non-resident pools (incoherent mode) run persistent
        collectives on heap slot sets; release() after an iteration
        must leave the caller-owned double buffers intact for the
        next start()."""
        def prog(env):
            assert not env.comm._resident
            x = np.zeros(2000)
            req = env.comm.allreduce_init(x, algo="rd")
            vals = []
            for i in range(3):
                x[:] = float(i * (env.rank + 1))
                vals.append(float(req.start().wait(60)[0]))
            env.comm.barrier()
            req.free()
            return vals

        res = run_threads(2, prog, coherent=False, cell_size=CELL,
                          pool_bytes=32 << 20, timeout=120)
        assert res[0] == res[1] == [0.0, 3.0, 6.0]

    def test_matchbox_demand_and_free(self):
        def prog(env):
            c = env.comm
            before = env.arena.stats()["slots_used"]
            c.barrier()
            req = c.allreduce_init(np.zeros(2000), algo="rd")
            assert req.matchbox_demand == 2   # rd: 1 recv/peer, 2 parities
            req.start().wait(60)
            req.free()
            c.barrier()
            return env.arena.stats()["slots_used"] - before

        assert all(d == 0 for d in run_threads(2, prog, cell_size=CELL,
                                               pool_bytes=32 << 20,
                                               timeout=120))

    def test_capacity_misses_counted(self):
        """matchbox_slots=1: the second postable receive SPILLS to the
        overflow list; when its payload arrives via a fallback path
        while the posting is still spilled, the lost one-copy
        opportunity is counted in ProtocolStats (the sizing signal)."""
        def prog(env):
            c = env.comm
            if env.rank == 1:
                d1, d2 = c.alloc_buffer(8000), c.alloc_buffer(8000)
                r1 = c.irecv_into(0, d1, tag=1)
                r2 = c.irecv_into(0, d2, tag=2)   # strip full: spilled
                assert len(c._mb_overflow[0]) == 1
                c.send(0, b"", tag=9)
                # ONLY tag=2 is in flight: it arrives staged (no
                # matching entry), parks behind the head, and r2
                # completes from park while r1 still owns the one slot
                # — the posting never left the overflow list -> a miss
                r2.wait(60)
                misses = env.arena.view.stats.mb_capacity_misses
                c.send(0, b"", tag=9)
                r1.wait(60)               # posted in place afterwards
                c.barrier()
                return misses
            c.recv(1, tag=9)
            c.send(1, bytes(8000), tag=2)
            c.recv(1, tag=9)
            c.send(1, bytes(8000), tag=1)
            c.barrier()
            return 0

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=32 << 20,
                          comm_kw={"matchbox_slots": 1}, timeout=120)
        assert res[1] >= 1

    def test_spilled_postings_promote_without_misses(self):
        """Pre-posting far beyond strip capacity spills FIFO and
        promotes as entries retire: every posting reaches the matchbox
        before its payload's descriptor is processed, so
        ``mb_capacity_misses`` stays 0 (the ROADMAP overflow-spill
        follow-up)."""
        K = 12

        def prog(env):
            c = env.comm
            if env.rank == 1:
                bufs = [c.alloc_buffer(8000) for _ in range(K)]
                reqs = [c.irecv_into(0, b, tag=i)
                        for i, b in enumerate(bufs)]
                assert len(c._mb_overflow[0]) == K - 2   # 2 slots live
                c.send(0, b"", tag=99)
                for i, r in enumerate(reqs):
                    r.wait(60)
                    assert bytes(bufs[i].read(0, 1)) == bytes([i + 1])
                assert not c._mb_records
                assert not any(c._mb_overflow.values())
                misses = env.arena.view.stats.mb_capacity_misses
                c.barrier()
                for b in bufs:
                    b.free()
                return misses
            c.recv(1, tag=99)
            for i in range(K):
                c.send(1, bytes([i + 1]) * 8000, tag=i)
            c.barrier()
            return 0

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=64 << 20,
                          comm_kw={"matchbox_slots": 2}, timeout=120)
        assert res[1] == 0

    def test_matchbox_slots_param_reaches_strips(self):
        def prog(env):
            assert env.comm.mb_slots == 7
            assert env.comm._mb.n_slots == 7
            env.comm.barrier()
            return True

        assert all(run_threads(2, prog, cell_size=CELL,
                               comm_kw={"matchbox_slots": 7}))


# --------------------------------------------------------------------------
# mixed-request wait helpers
# --------------------------------------------------------------------------

class TestWaitHelpers:
    def test_waitall_mixed_kinds(self):
        def prog(env):
            c = env.comm
            peer = 1 - env.rank
            sreq = c.isend(peer, np.full(2000, float(env.rank)), tag=5)
            rbuf = np.zeros(2000)
            rreq = c.irecv_into(peer, rbuf, tag=5)
            coll = c.iallreduce(np.full(100, 1.0))
            ps = c.send_init(peer, np.full(50, 2.0), tag=6).start()
            pr_buf = np.zeros(50)
            pr = c.recv_init(peer, pr_buf, tag=6).start()
            c.waitall([sreq, rreq, coll, ps, pr], timeout=60)
            return float(rbuf[0]), float(coll.result[0]), float(pr_buf[0])

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=32 << 20,
                          timeout=120)
        assert res[0] == (1.0, 2.0, 2.0)
        assert res[1] == (0.0, 2.0, 2.0)

    def test_waitany_returns_first_completed(self):
        def prog(env):
            c = env.comm
            if env.rank == 0:
                late = c.irecv(1, tag=42)    # unsendable until go-ahead
                bar = c.ibarrier()
                i, req = c.waitany([late, bar], timeout=60)
                assert i == 1 and req is bar
                c.send(1, b"go", tag=43)
                return late.wait(60)
            c.ibarrier().wait(60)
            go, _ = c.recv(0, tag=43)
            c.send(0, b"late", tag=42)
            return go

        res = run_threads(2, prog, cell_size=CELL, timeout=120)
        assert res[0] == b"late" and res[1] == b"go"

    def test_testall(self):
        def prog(env):
            c = env.comm
            reqs = [c.ibarrier(), c.iallreduce(np.ones(10))]
            while not c.testall(reqs):
                pass
            return float(reqs[1].result[0])

        assert run_threads(2, prog, cell_size=CELL) == [2.0, 2.0]


# --------------------------------------------------------------------------
# real-peer eager-threshold probe
# --------------------------------------------------------------------------

class TestRealPeerProbe:
    def test_pairs_probe_against_peer(self):
        def prog(env):
            c = env.comm
            assert isinstance(c.eager_threshold, int)
            assert c.eager_threshold >= 64
            # the wire still works after probing
            peer = 1 - env.rank
            c.send(peer, b"y" * (CELL * 2), tag=1)
            data, _ = c.recv(peer, tag=1)
            return c.probe_mode, len(data)

        res = run_threads(2, prog, cell_size=CELL,
                          eager_threshold="auto", pool_bytes=32 << 20,
                          timeout=120)
        assert all(m == "peer" for m, _ in res)
        assert all(ln == CELL * 2 for _, ln in res)

    def test_odd_rank_falls_back_to_local(self):
        def prog(env):
            env.comm.barrier()
            return env.comm.probe_mode

        res = run_threads(3, prog, cell_size=CELL,
                          eager_threshold="auto", pool_bytes=32 << 20,
                          timeout=120)
        assert res[0] == "peer" and res[1] == "peer"
        assert res[2] == "local"


# --------------------------------------------------------------------------
# reserved tag space + cancel semantics (review regressions)
# --------------------------------------------------------------------------

class TestReservedTagFence:
    def test_user_tags_in_reserved_space_rejected(self):
        def prog(env):
            with pytest.raises(ValueError, match="reserved"):
                env.comm.isend(1 - env.rank, b"x", tag=0x7E000001)
            with pytest.raises(ValueError, match="reserved"):
                env.comm.irecv(1 - env.rank, tag=0x7F000010)
            env.comm.barrier()
            return True

        assert all(run_threads(2, prog, cell_size=CELL))

    def test_cancel_is_observable(self):
        def prog(env):
            if env.rank == 0:
                req = env.comm.irecv(1, tag=9)
                req.cancel()
                assert req.done and req.cancelled
                assert req.data is None
                env.comm.barrier()
                return True
            env.comm.barrier()
            return True

        assert all(run_threads(2, prog, cell_size=CELL))

    def test_ibcast_rejects_noncontiguous(self):
        def prog(env):
            a = np.zeros((8, 8))[:, :4]          # non-C-contiguous
            with pytest.raises(ValueError, match="contiguous"):
                env.comm.ibcast(a, root=0)
            env.comm.barrier()
            return True

        assert all(run_threads(2, prog, cell_size=CELL))


# --------------------------------------------------------------------------
# chunked execution + fused hierarchical allreduce (functional)
# --------------------------------------------------------------------------

class TestChunkedCollectives:
    @pytest.mark.parametrize("n,algo", [(2, "rd"), (3, "ring"),
                                        (4, "ring")])
    def test_chunked_allreduce_matches_reference(self, n, algo):
        def prog(env):
            x = np.arange(6000, dtype=np.float64) * (env.rank + 1)
            return env.comm.iallreduce(x, algo=algo,
                                       chunk_bytes=8192).wait(60)

        exp = np.arange(6000, dtype=np.float64) * sum(range(1, n + 1))
        for out in run_threads(n, prog, cell_size=CELL,
                               pool_bytes=64 << 20, timeout=120):
            assert np.allclose(out, exp)

    def test_chunked_auto_derives_from_probe(self):
        """chunk_bytes="auto" floors at 8x the probed crossover (min
        64 KiB), caps pipeline depth at ~8 chunks, and stays
        message-granular for small payloads."""
        from repro.core.collectives import auto_chunk_bytes

        def prog(env):
            c = env.comm
            cb = auto_chunk_bytes(c, 4 << 20)
            assert cb == max(64 * 1024,
                             8 * (c.probed_crossover
                                  or c.eager_threshold),
                             (4 << 20) // 8)
            assert auto_chunk_bytes(c, 64 << 20) == 8 << 20   # depth cap
            assert auto_chunk_bytes(c, 1024) is None
            x = np.arange(3000.0) * (env.rank + 1)
            return c.iallreduce(x, chunk_bytes="auto").wait(60)

        for out in run_threads(2, prog, cell_size=CELL,
                               pool_bytes=32 << 20, timeout=120):
            assert np.allclose(out, np.arange(3000.0) * 3)

    def test_ihier_matches_allreduce_bit_exact(self):
        """Acceptance: ihier_allreduce on a 4-rank 2x2 hier comm agrees
        BIT-EXACTLY with comm.allreduce (which auto-selects the same
        fused schedule at this shape)."""
        def prog(env):
            x = (np.arange(8000, dtype=np.float64) / 3.0
                 + env.rank * 0.1)
            a = env.comm.ihier_allreduce(x, group_size=2).wait(60)
            b = env.comm.allreduce(x)
            xi = np.arange(8000, dtype=np.int64) * (env.rank + 1)
            ai = env.comm.ihier_allreduce(xi, group_size=2).wait(60)
            return a.tobytes() == b.tobytes(), ai

        for same, ai in run_threads(4, prog, cell_size=CELL,
                                    pool_bytes=64 << 20, timeout=120):
            assert same
            assert np.array_equal(ai, np.arange(8000, dtype=np.int64)
                                  * 10)

    def test_ihier_chunked_overlaps_compute(self):
        """The fused hier schedule is nonblocking: compute injected
        between start and wait still reduces correctly."""
        def prog(env):
            x = np.full(16000, float(env.rank + 1))
            req = env.comm.ihier_allreduce(x, chunk_bytes=16384)
            acc = np.zeros(32)
            for i in range(30):
                acc += np.cos(acc + i)
                env.comm.progress()
            return req.wait(60)

        for out in run_threads(4, prog, cell_size=CELL,
                               pool_bytes=64 << 20, timeout=120):
            assert np.allclose(out, 10.0)

    def test_ihier_invalid_group_size_warns_and_falls_back(self):
        def prog(env):
            # 6 = 2 x 3 groups: a group COUNT of 3 is not a power of
            # two, so recursive doubling cannot run the inter phase —
            # the call must still WORK (the pre-fused sub-comm path
            # accepted any divisor), just single-level, with a warning
            x = np.arange(500.0) * (env.rank + 1)
            with pytest.warns(UserWarning, match="group_size 2"):
                out = env.comm.ihier_allreduce(x, group_size=2).wait(60)
            return out

        exp = np.arange(500.0) * sum(range(1, 7))
        for out in run_threads(6, prog, cell_size=CELL,
                               pool_bytes=32 << 20, timeout=120):
            assert np.allclose(out, exp)

    def test_default_timeout_scales_with_subrounds(self):
        """Satellite fix: 30 s/round budgets every chunk sub-round once
        a round is split — a chunked request's default wait budget is
        its sub-round count, not the message-granular round count."""
        def prog(env):
            c = env.comm
            x = np.zeros(1 << 15)        # 256 KiB
            plain = c.iallreduce(x, algo="rd")
            chunked = c.iallreduce(x, algo="rd", chunk_bytes=32768)
            plain.wait(60)
            chunked.wait(60)
            return plain.default_timeout, chunked.default_timeout

        for plain_t, chunked_t in run_threads(2, prog, cell_size=CELL,
                                              pool_bytes=64 << 20,
                                              timeout=120):
            assert plain_t == 30.0
            assert chunked_t == 30.0 * 8     # 256 KiB / 32 KiB chunks


# --------------------------------------------------------------------------
# fault injection: _SchedExec._abort on chunked schedules
# --------------------------------------------------------------------------

class TestChunkedAbort:
    def test_mid_chunk_send_failure_aborts_cleanly(self):
        """Kill one in-flight chunk send of a chunked resident schedule:
        the sibling receives must cancel (matchbox retracted), the
        leased buffer set must be LEAKED (never recycled — a straggler
        chunk may still land in it), and the communicator must stay
        usable for a fresh collective."""
        def prog(env):
            c = env.comm
            if env.rank == 0:
                c.barrier()
                req = c.iallreduce(np.full(40000, 1.0), algo="rd",
                                   chunk_bytes=65536)
                ex = req._ex
                # peer is asleep: resident sends went staged-sync and
                # stay in flight awaiting the drain ack
                for _ in range(50):
                    c.progress()
                    sends = [r for r in ex._inflight.values()
                             if r.kind == "send" and not r.done]
                    if sends:
                        break
                assert sends, "no in-flight chunk send to kill"
                sends[0]._error = RuntimeError("injected chunk failure")
                with pytest.raises(RuntimeError, match="injected"):
                    req.wait(10)
                assert req.error is not None
                # sibling in-flight receives were cancelled and their
                # matchbox postings withdrawn
                assert not c._mb_records
                assert not any(c._mb_overflow.values())
                assert ex not in c._engine.colls
                # the leased slot set is leaked, not recycled
                assert c._rounds._free_sets == []
                c.barrier()              # wake the peer's second phase
            else:
                c.barrier()
                time.sleep(0.3)          # arrive late: rank 0's chunk
                # sends are staged-sync in flight when it injects
                req = c.iallreduce(np.full(40000, 1.0), algo="rd",
                                   chunk_bytes=65536)
                for _ in range(20):      # absorb rank 0's partial chunks
                    c.progress()
                # the peer died mid-collective: abort our side too (the
                # MPI calling convention keeps collective seq numbers
                # aligned for whatever comes next)
                req._ex._abort(RuntimeError("peer aborted"))
                assert not c._mb_records
                assert not any(c._mb_overflow.values())
                c.barrier()
            # the comm is still usable: a fresh small collective works
            # (stale chunk descriptors of the dead collective are
            # drained, acked and parked under their old tag window)
            out = c.allreduce(np.full(64, float(env.rank + 1)),
                              algo="rd")
            return float(out[0])

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=64 << 20,
                          timeout=120)
        assert res == [3.0, 3.0]

    def test_abort_after_normal_completion_recycles(self):
        """Control: a collective that completes normally RETURNS its
        slot set to the round pool (the leak above is abort-only)."""
        def prog(env):
            c = env.comm
            c.iallreduce(np.full(40000, 1.0), algo="rd",
                         chunk_bytes=65536).wait(60)
            c.barrier()
            return len(c._rounds._free_sets)

        assert all(k == 1 for k in run_threads(2, prog, cell_size=CELL,
                                               pool_bytes=64 << 20,
                                               timeout=120))


# --------------------------------------------------------------------------
# persistent bcast / allgather inits
# --------------------------------------------------------------------------

class TestPersistentBcastAllgather:
    @pytest.mark.parametrize("n", [2, 3])
    def test_bcast_init_iterations(self, n):
        def prog(env):
            c = env.comm
            x = np.zeros(3000)
            req = c.bcast_init(x, root=1)
            vals = []
            for i in range(4):
                if c.rank == 1:
                    x[:] = float(i + 5)
                out = req.start().wait(60)
                assert out is x          # in-place live-view contract
                vals.append(float(out[0]))
            c.barrier()
            req.free()
            return vals

        for vals in run_threads(n, prog, cell_size=CELL,
                                pool_bytes=64 << 20,
                                comm_kw={"matchbox_slots": 16},
                                timeout=120):
            assert vals == [5.0, 6.0, 7.0, 8.0]

    def test_allgather_init_ring_deterministic_hits(self):
        """Ring allgather is CYCLIC, so the one-iteration-ahead
        pre-post gives the same 100% posted-hit determinism as
        allreduce_init; the arena footprint stays flat."""
        iters = 5

        def prog(env):
            c = env.comm
            sh = np.zeros(2000)
            req = c.allgather_init(sh, algo="ring")
            h0, r0 = c.posted_sends, c.rndv_sends
            slots = []
            outs = []
            for i in range(iters):
                sh[:] = float(10 * env.rank + i)
                outs.append(req.start().wait(60)
                            .reshape(c.size, -1)[:, 0].tolist())
                c.barrier()
                slots.append(env.arena.stats()["slots_used"])
            hits, rndv = c.posted_sends - h0, c.rndv_sends - r0
            c.barrier()
            req.free()
            return outs, hits, rndv, slots

        n = 3
        res = run_threads(n, prog, cell_size=CELL, pool_bytes=64 << 20,
                          comm_kw={"matchbox_slots": 16}, timeout=120)
        for outs, hits, rndv, slots in res:
            for i, row in enumerate(outs):
                assert row == [float(10 * r + i) for r in range(n)]
            assert hits == rndv and rndv >= iters
            assert len(set(slots)) == 1

    def test_bcast_allgather_init_free_releases_slots(self):
        def prog(env):
            c = env.comm
            before = env.arena.stats()["slots_used"]
            c.barrier()
            pb = c.bcast_init(np.zeros(2000), root=0)
            sh = np.full(500, float(env.rank + 1))
            pg = c.allgather_init(sh, algo="bruck")
            pb.start().wait(60)
            g = pg.start().wait(60)        # bruck -> rank-order reorder
            assert np.allclose(g.reshape(c.size, -1)[:, 0],
                               np.arange(1.0, c.size + 1))
            c.barrier()
            pb.free()
            pg.free()
            c.barrier()
            return env.arena.stats()["slots_used"] - before

        assert all(d == 0 for d in run_threads(2, prog, cell_size=CELL,
                                               pool_bytes=64 << 20,
                                               timeout=120))


class TestAutoChunkAgreement:
    def test_auto_chunk_base_agreed_across_probing_ranks(self):
        """eager_threshold="auto" probes per rank (crossovers may
        differ), but chunk counts become sub-round wire tags — the
        "auto" chunk basis must be the communicator-agreed maximum, and
        a chunked "auto" collective must still reduce correctly."""
        def prog(env):
            c = env.comm
            out = c.iallreduce(np.arange(40000.0) * (env.rank + 1),
                               chunk_bytes="auto").wait(60)
            return c._chunk_base, out

        res = run_threads(2, prog, cell_size=CELL,
                          eager_threshold="auto", pool_bytes=64 << 20,
                          timeout=120)
        bases = [b for b, _ in res]
        assert bases[0] == bases[1] and bases[0] is not None
        exp = np.arange(40000.0) * 3
        for _, out in res:
            assert np.allclose(out, exp)
