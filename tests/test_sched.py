"""Schedule-DAG collectives: the IR + compile cache, the shared
progress engine (nonblocking i* collectives, mixed-request wait
helpers), MPI-4 persistent collectives with round-synchronized
pre-posting, matchbox sizing/capacity-miss accounting, tag-space
isolation of collectives from ANY_TAG traffic, and the real-peer
eager-threshold probe."""
import numpy as np
import pytest

from repro.core import run_threads
from repro.core.sched import (RecvOp, ReduceOp, SendOp, compile_schedule)

CELL = 4096


class _StubComm:
    """compile_schedule needs only (size, rank, _sched_cache)."""

    def __init__(self, n, rank):
        self.size = n
        self.rank = rank
        self._sched_cache = {}


# --------------------------------------------------------------------------
# IR + compiler
# --------------------------------------------------------------------------

class TestScheduleIR:
    @pytest.mark.parametrize("kind,nbytes", [
        ("allreduce_rd", 4096), ("allreduce_ring", 4096),
        ("reduce_scatter_ring", 4096), ("allgather_ring", 512),
        ("allgather_bruck", 512), ("bcast", 4096), ("reduce", 4096),
        ("barrier", 0)])
    def test_compiles_valid_dags_all_ranks(self, kind, nbytes):
        for n in (2, 3, 4, 5, 8):
            if kind == "allreduce_rd" and n & (n - 1):
                continue
            for rank in range(n):
                s = compile_schedule(_StubComm(n, rank), kind, nbytes, 8)
                s.validate()     # deps strictly backward, rounds in span
                # every send and recv names a peer inside the comm
                for nd in s.nodes:
                    if isinstance(nd, (SendOp, RecvOp)):
                        assert 0 <= nd.peer < n and nd.peer != rank

    def test_compile_cached_per_key(self):
        c = _StubComm(4, 1)
        a = compile_schedule(c, "allreduce_ring", 4096, 8)
        b = compile_schedule(c, "allreduce_ring", 4096, 8)
        assert a is b                        # one compile per key
        assert compile_schedule(c, "allreduce_ring", 8192, 8) is not a

    def test_rd_recvs_preposted(self):
        """Every recursive-doubling receive is dependency-free (own
        slot per round), so the engine pre-posts all of them at start —
        the matchbox-priming property persistent collectives rely on."""
        s = compile_schedule(_StubComm(8, 3), "allreduce_rd", 1024, 8)
        recvs = s.recv_nodes()
        assert len(recvs) == 3
        assert all(not nd.deps for nd in recvs)
        assert s.max_recvs_per_peer() == 1   # one round per peer

    def test_ring_ag_recvs_wait_for_rs_sends(self):
        """The fused ring's allgather receives target chunks the RS
        phase sourced — they must carry the anti-hazard dependency."""
        s = compile_schedule(_StubComm(4, 0), "allreduce_ring", 4096, 8)
        rs = [nd for nd in s.nodes if isinstance(nd, RecvOp)
              and nd.round < 3]
        ag = [nd for nd in s.nodes if isinstance(nd, RecvOp)
              and nd.round >= 3]
        assert all(not nd.deps for nd in rs)
        assert all(nd.deps for nd in ag)
        assert s.max_recvs_per_peer() == 6   # all from `left`

    def test_reduce_sum_of_reduceops_covers_children(self):
        s = compile_schedule(_StubComm(7, 0), "reduce", 512, 8, root=0)
        # root of 7 ranks folds in children 1, 2, 4 -> three ReduceOps
        assert sum(isinstance(nd, ReduceOp) for nd in s.nodes) == 3


# --------------------------------------------------------------------------
# nonblocking collectives over the shared progress engine
# --------------------------------------------------------------------------

class TestNonblockingCollectives:
    @pytest.mark.parametrize("n,nelem,algo", [(2, 31, "rd"),
                                              (3, 4000, "ring"),
                                              (4, 4000, "rd")])
    def test_iallreduce_with_injected_compute(self, n, nelem, algo):
        """Compute between start and wait, ticking comm.progress() —
        the overlap usage pattern — still reduces correctly."""
        def prog(env):
            x = (np.arange(nelem, dtype=np.float64) + 1) * (env.rank + 1)
            req = env.comm.iallreduce(x, algo=algo)
            acc = np.zeros(64)
            for i in range(50):              # injected compute
                acc += np.sin(acc + i)
                env.comm.progress()
            out = req.wait(60)
            return out, acc

        exp = (np.arange(nelem, dtype=np.float64) + 1) * sum(
            range(1, n + 1))
        for out, _ in run_threads(n, prog, cell_size=CELL,
                                  pool_bytes=32 << 20, timeout=120):
            assert np.allclose(out, exp)

    def test_ibcast_in_place(self):
        def prog(env):
            buf = (np.arange(5000.0) if env.rank == 1
                   else np.zeros(5000))
            out = env.comm.ibcast(buf, root=1).wait(60)
            assert out is buf                # in-place MPI semantics
            return buf

        for out in run_threads(3, prog, cell_size=CELL,
                               pool_bytes=32 << 20, timeout=120):
            assert np.allclose(out, np.arange(5000.0))

    def test_iallgather_ireduce_scatter_ibarrier(self):
        n = 4

        def prog(env):
            c = env.comm
            g = c.iallgather(np.full(700, float(env.rank))).wait(60)
            rs = c.ireduce_scatter(np.arange(8.0) + env.rank).wait(60)
            c.ibarrier().wait(60)
            return g, rs

        res = run_threads(n, prog, cell_size=CELL, pool_bytes=32 << 20,
                          timeout=120)
        full = sum(np.arange(8.0) + r for r in range(n))
        for r, (g, rs) in enumerate(res):
            assert np.allclose(g.reshape(n, -1)[2], 2.0)
            k = 2 * ((r + 1) % n)
            assert np.allclose(rs, full[k:k + 2])

    def test_concurrent_collectives_disjoint_tags(self):
        """Three collectives in flight at once on one communicator:
        per-launch tag windows keep their rounds apart."""
        def prog(env):
            c = env.comm
            r1 = c.iallreduce(np.full(3000, float(env.rank + 1)))
            r2 = c.iallgather(np.array([env.rank * 7.0]))
            r3 = c.ibarrier()
            c.waitall([r1, r2, r3], timeout=60)
            return r1.result, r2.result

        for a, g in run_threads(3, prog, cell_size=CELL,
                                pool_bytes=32 << 20, timeout=120):
            assert np.allclose(a, 6.0)
            assert np.allclose(g, [0.0, 7.0, 14.0])

    def test_collectives_isolated_from_any_tag_recv(self):
        """An outstanding ANY_TAG user receive must not swallow
        collective rounds: reserved tags are excluded from wildcard
        matching (both queue matching and matchbox wildcard entries)."""
        def prog(env):
            c = env.comm
            peer = 1 - env.rank
            ur = c.irecv(peer, tag=-1)       # ANY_TAG, posted FIRST
            a = c.iallreduce(np.full(4000, float(env.rank + 1)))
            c.ibarrier().wait(60)
            out = a.wait(60)
            c.send(peer, b"user-payload", tag=3)
            data = ur.wait(60)
            return out[0], data

        for s, data in run_threads(2, prog, cell_size=CELL,
                                   pool_bytes=32 << 20, timeout=120):
            assert s == 3.0
            assert data == b"user-payload"

    def test_free_function_shims_match_methods(self):
        """The deprecated free functions route through the SAME
        schedules (heap backend) and agree with the method results."""
        from repro.core import collectives as coll

        def prog(env):
            x = np.arange(600.0) * (env.rank + 1)
            a = coll.allreduce(env.comm, x, algo="ring")
            b = env.comm.allreduce(x, algo="ring")
            return np.allclose(a, b)

        assert all(run_threads(3, prog, cell_size=CELL,
                               pool_bytes=32 << 20, timeout=120))


# --------------------------------------------------------------------------
# persistent collectives: round-synchronized pre-post
# --------------------------------------------------------------------------

class TestPersistentCollectives:
    @pytest.mark.parametrize("n,algo", [(2, "rd"), (3, "ring"),
                                        (4, "rd")])
    def test_allreduce_init_iterations(self, n, algo):
        iters = 5

        def prog(env):
            c = env.comm
            x = np.zeros(3000)
            req = c.allreduce_init(x, algo=algo)
            h0, r0 = c.posted_sends, c.rndv_sends
            vals = []
            slots = []
            for i in range(iters):
                x[:] = float(i * (env.rank + 1))
                vals.append(float(req.start().wait(60)[0]))
                c.barrier()
                slots.append(env.arena.stats()["slots_used"])
            hits, rndv = c.posted_sends - h0, c.rndv_sends - r0
            c.barrier()      # peers may still be reading slot counts
            req.free()
            return vals, hits, rndv, slots

        res = run_threads(n, prog, cell_size=CELL, pool_bytes=64 << 20,
                          comm_kw={"matchbox_slots": 16}, timeout=120)
        exp = [i * sum(range(1, n + 1)) for i in range(iters)]
        for vals, hits, rndv, slots in res:
            assert vals == [float(v) for v in exp]
            # deterministic 100% posted-hit rate (matchbox sized to
            # 2x schedule depth), flat arena footprint across rounds
            assert hits == rndv and rndv >= iters
            assert len(set(slots)) == 1

    def test_heap_persistent_survives_restarts(self):
        """Non-resident pools (incoherent mode) run persistent
        collectives on heap slot sets; release() after an iteration
        must leave the caller-owned double buffers intact for the
        next start()."""
        def prog(env):
            assert not env.comm._resident
            x = np.zeros(2000)
            req = env.comm.allreduce_init(x, algo="rd")
            vals = []
            for i in range(3):
                x[:] = float(i * (env.rank + 1))
                vals.append(float(req.start().wait(60)[0]))
            env.comm.barrier()
            req.free()
            return vals

        res = run_threads(2, prog, coherent=False, cell_size=CELL,
                          pool_bytes=32 << 20, timeout=120)
        assert res[0] == res[1] == [0.0, 3.0, 6.0]

    def test_matchbox_demand_and_free(self):
        def prog(env):
            c = env.comm
            before = env.arena.stats()["slots_used"]
            c.barrier()
            req = c.allreduce_init(np.zeros(2000), algo="rd")
            assert req.matchbox_demand == 2   # rd: 1 recv/peer, 2 parities
            req.start().wait(60)
            req.free()
            c.barrier()
            return env.arena.stats()["slots_used"] - before

        assert all(d == 0 for d in run_threads(2, prog, cell_size=CELL,
                                               pool_bytes=32 << 20,
                                               timeout=120))

    def test_capacity_misses_counted(self):
        """matchbox_slots=1: the second postable receive from one
        source finds the strip full — counted in ProtocolStats so the
        sizing policy has a signal."""
        def prog(env):
            c = env.comm
            if env.rank == 1:
                d1, d2 = c.alloc_buffer(8000), c.alloc_buffer(8000)
                r1 = c.irecv_into(0, d1, tag=1)
                r2 = c.irecv_into(0, d2, tag=2)   # strip already full
                misses = env.arena.view.stats.mb_capacity_misses
                c.send(0, b"", tag=9)
                r1.wait(60)
                r2.wait(60)
                return misses
            c.recv(1, tag=9)
            c.send(1, bytes(8000), tag=1)
            c.send(1, bytes(8000), tag=2)
            return 0

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=32 << 20,
                          comm_kw={"matchbox_slots": 1}, timeout=120)
        assert res[1] >= 1

    def test_matchbox_slots_param_reaches_strips(self):
        def prog(env):
            assert env.comm.mb_slots == 7
            assert env.comm._mb.n_slots == 7
            env.comm.barrier()
            return True

        assert all(run_threads(2, prog, cell_size=CELL,
                               comm_kw={"matchbox_slots": 7}))


# --------------------------------------------------------------------------
# mixed-request wait helpers
# --------------------------------------------------------------------------

class TestWaitHelpers:
    def test_waitall_mixed_kinds(self):
        def prog(env):
            c = env.comm
            peer = 1 - env.rank
            sreq = c.isend(peer, np.full(2000, float(env.rank)), tag=5)
            rbuf = np.zeros(2000)
            rreq = c.irecv_into(peer, rbuf, tag=5)
            coll = c.iallreduce(np.full(100, 1.0))
            ps = c.send_init(peer, np.full(50, 2.0), tag=6).start()
            pr_buf = np.zeros(50)
            pr = c.recv_init(peer, pr_buf, tag=6).start()
            c.waitall([sreq, rreq, coll, ps, pr], timeout=60)
            return float(rbuf[0]), float(coll.result[0]), float(pr_buf[0])

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=32 << 20,
                          timeout=120)
        assert res[0] == (1.0, 2.0, 2.0)
        assert res[1] == (0.0, 2.0, 2.0)

    def test_waitany_returns_first_completed(self):
        def prog(env):
            c = env.comm
            if env.rank == 0:
                late = c.irecv(1, tag=42)    # unsendable until go-ahead
                bar = c.ibarrier()
                i, req = c.waitany([late, bar], timeout=60)
                assert i == 1 and req is bar
                c.send(1, b"go", tag=43)
                return late.wait(60)
            c.ibarrier().wait(60)
            go, _ = c.recv(0, tag=43)
            c.send(0, b"late", tag=42)
            return go

        res = run_threads(2, prog, cell_size=CELL, timeout=120)
        assert res[0] == b"late" and res[1] == b"go"

    def test_testall(self):
        def prog(env):
            c = env.comm
            reqs = [c.ibarrier(), c.iallreduce(np.ones(10))]
            while not c.testall(reqs):
                pass
            return float(reqs[1].result[0])

        assert run_threads(2, prog, cell_size=CELL) == [2.0, 2.0]


# --------------------------------------------------------------------------
# real-peer eager-threshold probe
# --------------------------------------------------------------------------

class TestRealPeerProbe:
    def test_pairs_probe_against_peer(self):
        def prog(env):
            c = env.comm
            assert isinstance(c.eager_threshold, int)
            assert c.eager_threshold >= 64
            # the wire still works after probing
            peer = 1 - env.rank
            c.send(peer, b"y" * (CELL * 2), tag=1)
            data, _ = c.recv(peer, tag=1)
            return c.probe_mode, len(data)

        res = run_threads(2, prog, cell_size=CELL,
                          eager_threshold="auto", pool_bytes=32 << 20,
                          timeout=120)
        assert all(m == "peer" for m, _ in res)
        assert all(ln == CELL * 2 for _, ln in res)

    def test_odd_rank_falls_back_to_local(self):
        def prog(env):
            env.comm.barrier()
            return env.comm.probe_mode

        res = run_threads(3, prog, cell_size=CELL,
                          eager_threshold="auto", pool_bytes=32 << 20,
                          timeout=120)
        assert res[0] == "peer" and res[1] == "peer"
        assert res[2] == "local"


# --------------------------------------------------------------------------
# reserved tag space + cancel semantics (review regressions)
# --------------------------------------------------------------------------

class TestReservedTagFence:
    def test_user_tags_in_reserved_space_rejected(self):
        def prog(env):
            with pytest.raises(ValueError, match="reserved"):
                env.comm.isend(1 - env.rank, b"x", tag=0x7E000001)
            with pytest.raises(ValueError, match="reserved"):
                env.comm.irecv(1 - env.rank, tag=0x7F000010)
            env.comm.barrier()
            return True

        assert all(run_threads(2, prog, cell_size=CELL))

    def test_cancel_is_observable(self):
        def prog(env):
            if env.rank == 0:
                req = env.comm.irecv(1, tag=9)
                req.cancel()
                assert req.done and req.cancelled
                assert req.data is None
                env.comm.barrier()
                return True
            env.comm.barrier()
            return True

        assert all(run_threads(2, prog, cell_size=CELL))

    def test_ibcast_rejects_noncontiguous(self):
        def prog(env):
            a = np.zeros((8, 8))[:, :4]          # non-C-contiguous
            with pytest.raises(ValueError, match="contiguous"):
                env.comm.ibcast(a, root=0)
            env.comm.barrier()
            return True

        assert all(run_threads(2, prog, cell_size=CELL))
