"""Zero-copy message path: eager/rendezvous protocol selection and
threshold boundaries, buffer-protocol sends, recv_into, PoolBuffer
zero-copy sends, copy accounting (ProtocolStats), gather-enqueue /
dequeue_into framing, RMA buffer variants, and host-side coordination."""
import numpy as np
import pytest

from repro.core import run_threads
from repro.core.coherence import CoherentView
from repro.core.pool import IncoherentPool, LocalPool, RankCache, as_u8
from repro.core.ringqueue import SPSCQueue, queue_bytes
from repro.core.rma import Window

CELL = 4096
MSG_HDR = 16


# --------------------------------------------------------------------------
# protocol selection at the threshold boundary
# --------------------------------------------------------------------------

class TestThreshold:
    @pytest.mark.parametrize("size", [CELL - MSG_HDR, CELL])
    def test_at_or_below_threshold_is_eager(self, size):
        def prog(env):
            if env.rank == 0:
                env.comm.send(1, b"\xcd" * size, tag=7)
                return env.comm.eager_sends, env.comm.rndv_sends
            data, _ = env.comm.recv(0, tag=7)
            return data

        res = run_threads(2, prog, cell_size=CELL)
        assert res[0] == (1, 0)                  # eager protocol used
        assert res[1] == b"\xcd" * size

    def test_above_threshold_is_rendezvous(self):
        size = CELL + 1
        payload = np.arange(size, dtype=np.uint8).tobytes()

        def prog(env):
            if env.rank == 0:
                env.comm.send(1, payload, tag=7)
                return env.comm.eager_sends, env.comm.rndv_sends
            data, _ = env.comm.recv(0, tag=7)
            return data

        res = run_threads(2, prog, cell_size=CELL)
        assert res[0] == (0, 1)                  # rendezvous protocol used
        assert res[1] == payload

    def test_custom_threshold_overrides_cell_size(self):
        def prog(env):
            if env.rank == 0:
                env.comm.send(1, b"x" * 100, tag=1)   # > 64 -> rendezvous
                env.comm.send(1, b"y" * 64, tag=2)    # == 64 -> eager
                return env.comm.eager_sends, env.comm.rndv_sends
            a, _ = env.comm.recv(0, tag=1)
            b, _ = env.comm.recv(0, tag=2)
            return a, b

        res = run_threads(2, prog, cell_size=CELL, eager_threshold=64)
        assert res[0] == (1, 1)
        assert res[1] == (b"x" * 100, b"y" * 64)

    def test_rendezvous_tag_mismatch_parks(self):
        """A rendezvous message of the wrong tag is parked (and its
        stager ack'd), not dropped."""
        big = b"\x11" * (CELL * 3)

        def prog(env):
            if env.rank == 0:
                env.comm.send(1, big, tag=1)
                env.comm.send(1, b"small", tag=2)
                return None
            s, _ = env.comm.recv(0, tag=2)        # overtakes the big one
            b, _ = env.comm.recv(0, tag=1)
            return s, b

        res = run_threads(2, prog, cell_size=CELL)
        assert res[1] == (b"small", big)

    def test_stager_reclaimed_after_ack(self):
        """The rendezvous staging object is destroyed once the receiver
        acks, so long-running streams do not leak arena slots."""
        def prog(env):
            if env.rank == 0:
                base = env.arena.stats()["slots_used"]
                for i in range(5):
                    env.comm.send(1, bytes([i]) * (CELL * 2), tag=3)
                env.comm.recv(1, tag=4)           # receiver done
                env.comm._progress()              # reclaim ack'd stagers
                assert not env.comm._stagers
                return base, env.arena.stats()["slots_used"]
            for i in range(5):
                data, _ = env.comm.recv(0, tag=3)
                assert data == bytes([i]) * (CELL * 2)
            env.comm.send(0, b"done", tag=4)
            return None

        res = run_threads(2, prog, cell_size=CELL)
        base, after = res[0]
        assert after == base


# --------------------------------------------------------------------------
# recv_into / buffer-protocol sends
# --------------------------------------------------------------------------

class TestRecvInto:
    @pytest.mark.parametrize("size", [64, CELL, CELL * 4])
    def test_roundtrip_into_bytearray(self, size):
        payload = np.random.default_rng(1).integers(
            0, 256, size, dtype=np.uint8).tobytes()

        def prog(env):
            if env.rank == 0:
                env.comm.send(1, payload, tag=5)
                return None
            buf = bytearray(size + 10)            # oversized is fine
            n, tag = env.comm.recv_into(0, buf, tag=5)
            return n, tag, bytes(buf[:n])

        res = run_threads(2, prog, cell_size=CELL)
        assert res[1] == (size, 5, payload)

    @pytest.mark.parametrize("size", [100, CELL * 4])
    def test_undersized_buffer_raises(self, size):
        """Both protocols reject delivery into a too-small buffer with a
        clean ValueError (truncation: message consumed + discarded), and
        the pair queue stays usable afterwards."""
        def prog(env):
            if env.rank == 0:
                env.comm.send(1, b"z" * size, tag=6, timeout=5)
                env.comm.send(1, b"after", tag=7, timeout=5)
                env.comm.recv(1, tag=9, timeout=5)  # receiver done
                env.comm._progress()
                return not env.comm._stagers        # stager reclaimed
            buf = bytearray(size - 1)
            with pytest.raises(ValueError, match="exceeds"):
                env.comm.recv_into(0, buf, tag=6, timeout=5)
            out = env.comm.recv(0, tag=7, timeout=5)[0]
            env.comm.send(0, b"", tag=9, timeout=5)
            return out

        res = run_threads(2, prog, cell_size=CELL)
        assert res[0] is True
        assert res[1] == b"after"

    def test_poolbuffer_truncated_recv_unblocks_sender(self):
        """An undersized recv_into of a PoolBuffer send must still ack,
        so the synchronous sender completes instead of timing out."""
        def prog(env):
            if env.rank == 0:
                pb = env.comm.alloc_buffer(CELL * 2)
                pb.view()[:] = b"w" * (CELL * 2)
                env.comm.send(1, pb, tag=8, timeout=5)   # needs the ack
                return True
            with pytest.raises(ValueError, match="exceeds"):
                env.comm.recv_into(0, bytearray(8), tag=8, timeout=5)
            return True

        assert run_threads(2, prog, cell_size=CELL) == [True, True]

    def test_poolbuffer_rejects_concurrent_sends(self):
        """One ack slot per PoolBuffer => a second isend while one is in
        flight is refused instead of corrupting completion tracking."""
        def prog(env):
            if env.rank == 0:
                pb = env.comm.alloc_buffer(64)
                pb.view()[:] = b"k" * 64
                req = env.comm.isend(1, pb, tag=1)
                with pytest.raises(ValueError, match="in-flight"):
                    env.comm.isend(1, pb, tag=2)
                env.comm.recv(1, tag=3, timeout=5)
                req.wait(5)
                env.comm.send(1, pb, tag=2, timeout=5)   # fine once done
                return True
            data, _ = env.comm.recv(0, tag=1, timeout=5)
            env.comm.send(0, b"", tag=3, timeout=5)
            data2, _ = env.comm.recv(0, tag=2, timeout=5)
            return data == data2 == b"k" * 64

        assert run_threads(2, prog, cell_size=CELL) == [True, True]

    def test_recv_array_size_mismatch_raises(self):
        """recv_array must not hand back uninitialized tail memory when
        the sender's message is smaller than the requested shape."""
        def prog(env):
            if env.rank == 0:
                env.comm.send(1, np.zeros(10, np.uint8), tag=4, timeout=5)
                return None
            with pytest.raises(ValueError, match="expected 100B"):
                env.comm.recv_array(0, (100,), np.uint8, tag=4)
            return True

        assert run_threads(2, prog, cell_size=CELL)[1] is True

    def test_ndarray_send_recv_views(self):
        """send accepts ndarrays; recv_array lands without frombuffer
        copies; dtype/shape round-trip through recv_into."""
        def prog(env):
            x = np.linspace(0.0, 1.0, 1000) * (env.rank + 1)
            peer = 1 - env.rank
            req = env.comm.isend(peer, x, tag=9)
            got = env.comm.recv_array(peer, (1000,), np.float64, tag=9)
            req.wait()
            return got

        res = run_threads(2, prog, cell_size=CELL)
        assert np.allclose(res[0], np.linspace(0.0, 1.0, 1000) * 2)
        assert np.allclose(res[1], np.linspace(0.0, 1.0, 1000))


# --------------------------------------------------------------------------
# copy accounting: rendezvous must beat eager for large messages
# --------------------------------------------------------------------------

class TestCopyAccounting:
    MB = 1 << 20

    def _stream_copied_bytes(self, eager_threshold, use_poolbuf,
                             n_msgs=3):
        size = self.MB

        def prog(env):
            if env.rank == 0:
                if use_poolbuf:
                    src = env.comm.alloc_buffer(size)
                    src.view()[:] = b"\xee" * size
                else:
                    src = b"\xee" * size
                env.comm.barrier()
                c0 = env.arena.view.stats.copied_bytes
                for _ in range(n_msgs):
                    env.comm.send(1, src, tag=1)
                env.comm.recv(1, tag=2)
                c1 = env.arena.view.stats.copied_bytes
                return c1 - c0
            dst = bytearray(size)
            env.comm.barrier()
            c0 = env.arena.view.stats.copied_bytes
            for _ in range(n_msgs):
                n, _ = env.comm.recv_into(0, dst, tag=1)
                assert n == size and dst[0] == 0xEE
            env.comm.send(0, b"", tag=2)
            c1 = env.arena.view.stats.copied_bytes
            return c1 - c0

        res = run_threads(2, prog, pool_bytes=32 << 20, cell_size=16384,
                          eager_threshold=eager_threshold, timeout=120)
        return (res[0] + res[1]) / n_msgs

    def test_rendezvous_copies_fewer_bytes_than_eager(self):
        eager = self._stream_copied_bytes(1 << 40, use_poolbuf=False)
        staged = self._stream_copied_bytes(0, use_poolbuf=False)
        zerocopy = self._stream_copied_bytes(0, use_poolbuf=True)
        # staged rendezvous: one stage write + one bulk read (~2n) beats
        # eager's per-cell chunking (~2n + headers + first-chunk memcpy)
        assert staged < eager
        # pool-resident source: receiver-side bulk read only (~1n) —
        # the acceptance bar: >= 2x fewer copied bytes than eager
        assert eager >= 2 * zerocopy

    def test_protocol_stats_copy_counters_monotonic(self):
        pool = LocalPool(4096)
        v = CoherentView(pool)
        v.write_release(0, b"abc")
        assert v.stats.copies == 1 and v.stats.copied_bytes == 3
        v.read_acquire(0, 3)
        assert v.stats.copies == 2 and v.stats.copied_bytes == 6
        dst = bytearray(3)
        v.read_acquire_into(0, dst)
        assert bytes(dst) == b"abc"
        assert v.stats.copies == 3 and v.stats.copied_bytes == 9
        v.count_copy(10, k=2)
        assert v.stats.copies == 5 and v.stats.copied_bytes == 29


# --------------------------------------------------------------------------
# PoolBuffer
# --------------------------------------------------------------------------

class TestPoolBuffer:
    def test_zero_copy_send_and_reuse(self):
        size = CELL * 8

        def prog(env):
            if env.rank == 0:
                pb = env.comm.alloc_buffer(size)
                for i in range(3):                # reusable after each send
                    pb.view()[:] = bytes([i]) * size
                    env.comm.send(1, pb, tag=1)
                pb.free()
                return env.comm.rndv_sends
            out = []
            dst = bytearray(size)
            for _ in range(3):
                env.comm.recv_into(0, dst, tag=1)
                out.append(dst[0])
            return out

        res = run_threads(2, prog, pool_bytes=16 << 20, cell_size=CELL)
        assert res[0] == 3                        # PoolBuffer => rendezvous
        assert res[1] == [0, 1, 2]

    def test_write_read_protocol_path(self):
        """PoolBuffer.write/read work on every pool mode (no raw view)."""
        def prog(env):
            if env.rank == 0:
                pb = env.comm.alloc_buffer(128)
                pb.write(b"q" * 128)
                assert pb.read() == b"q" * 128
                env.comm.send(1, pb, tag=1)
                pb.free()
                return None
            return env.comm.recv(0, tag=1)[0]

        res = run_threads(2, prog, coherent=False, cell_size=CELL,
                          eager_threshold=0)
        assert res[1] == b"q" * 128

    def test_incoherent_pool_refuses_raw_view(self):
        backing = LocalPool(1 << 20)
        inc = IncoherentPool(backing, RankCache(backing))
        with pytest.raises(TypeError, match="not memory-mappable"):
            inc.memview(0, 64)


# --------------------------------------------------------------------------
# ringqueue gather-enqueue / dequeue_into
# --------------------------------------------------------------------------

class TestQueueFraming:
    def _pair(self, cell_size=256, n_cells=4):
        backing = LocalPool(queue_bytes(cell_size, n_cells) + 256)
        v = CoherentView(backing)
        p = SPSCQueue(v, 0, cell_size, n_cells, producer=True,
                      initialize=True)
        c = SPSCQueue(v, 0, cell_size, n_cells, producer=False)
        return p, c

    def test_gather_enqueue_no_concat(self):
        p, c = self._pair()
        parts = [b"alpha", memoryview(b"-beta-"), np.frombuffer(
            b"gamma", np.uint8)]
        assert p.try_enqueue_parts(parts, flags=3)
        data, flags = c.dequeue()
        assert data == b"alpha-beta-gamma" and flags == 3

    def test_dequeue_into_exact_and_undersized(self):
        p, c = self._pair()
        p.enqueue(b"0123456789")
        buf = bytearray(10)
        n, _ = c.dequeue_into(buf)
        assert n == 10 and buf == b"0123456789"
        p.enqueue(b"0123456789")
        with pytest.raises(ValueError):
            c.try_dequeue_into(bytearray(4))

    def test_recv_message_into(self):
        p, c = self._pair(cell_size=64, n_cells=8)
        msg = bytes(range(256))
        import threading
        t = threading.Thread(target=p.send_message, args=(msg, 5, 10))
        t.start()
        dst = bytearray(300)
        n, tag = c.recv_message_into(dst, timeout=10)
        t.join(10)
        assert (n, tag) == (256, 5) and dst[:n] == msg

    def test_send_message_accepts_ndarray(self):
        p, c = self._pair(cell_size=64, n_cells=8)
        arr = np.arange(50, dtype=np.int32)
        import threading
        out = {}
        t = threading.Thread(
            target=lambda: out.update(m=c.recv_message(timeout=10)))
        t.start()
        p.send_message(arr, tag=1, timeout=10)
        t.join(10)
        assert np.array_equal(np.frombuffer(out["m"][0], np.int32), arr)


# --------------------------------------------------------------------------
# RMA buffer variants
# --------------------------------------------------------------------------

class TestRMABuffers:
    def test_put_from_get_into(self):
        def prog(env):
            win = env.comm.win_allocate("zc", 8192)
            x = np.arange(512, dtype=np.float32)
            win.fence()
            if env.rank == 0:
                win.put_from(1, 0, x)             # ndarray view, one copy
            win.fence()
            if env.rank == 1:
                dst = np.empty(512, np.float32)
                n = win.get_into(1, 0, dst)
                assert n == 2048
                return dst
            return None

        res = run_threads(2, prog, pool_bytes=16 << 20)
        assert np.array_equal(res[1], np.arange(512, dtype=np.float32))

    def test_accumulate_still_atomic(self):
        def prog(env):
            win = env.comm.win_allocate("acc", 1024)
            if env.rank == 0:
                win.put_array(0, 0, np.zeros(8, np.int64))
            win.fence()
            win.accumulate(0, 0, np.full(8, env.rank + 1, np.int64))
            win.fence()
            return win.get_array(0, 0, (8,), np.int64)

        res = run_threads(3, prog, pool_bytes=16 << 20)
        assert np.array_equal(res[0], np.full(8, 6, np.int64))   # 1+2+3


# --------------------------------------------------------------------------
# host-side coordination (distributed/ callers of the collectives)
# --------------------------------------------------------------------------

class TestHostCoord:
    def test_metrics_manifest_epoch(self):
        from repro.distributed.host_coord import (agree_max_step,
                                                  allreduce_metrics,
                                                  bcast_manifest,
                                                  sync_epoch)

        def prog(env):
            m = allreduce_metrics(env.comm, {"loss": float(env.rank + 1),
                                             "toks": 10.0})
            mmax = allreduce_metrics(env.comm, {"s": float(env.rank)},
                                     op=np.maximum)
            assert mmax == {"s": 2.0}
            manifest = {"step": 42, "shards": [0, 1, 2]} \
                if env.rank == 1 else None
            mf = bcast_manifest(env.comm, manifest, root=1)
            ep = sync_epoch(env.comm, 7 if env.rank == 0 else -1)
            mx = agree_max_step(env.comm, env.rank * 10)
            return m, mf, ep, mx

        for m, mf, ep, mx in run_threads(3, prog, cell_size=CELL):
            assert m == {"loss": 6.0, "toks": 30.0}
            assert mf == {"step": 42, "shards": [0, 1, 2]}
            assert ep == 7
            assert mx == 20


def test_as_u8_rejects_noncontiguous():
    arr = np.arange(16).reshape(4, 4)[:, ::2]
    with pytest.raises((TypeError, ValueError)):
        as_u8(arr)
