"""Serving data plane: router/worker tier over the comm core.

Covers the serve tier end to end — admission over persistent-request
pools, continuous-batching decode, rank-sharded page moves with the
zero-receiver-drain contract, the raccumulate'd shared token counter —
plus the fault path: a worker rank dying mid-decode, the router
retracting its matchbox postings and re-routing its sessions, and the
communicator staying usable for every surviving rank (the PR-5
``TestChunkedAbort`` discipline lifted to the serve tier)."""
import numpy as np

from repro.core import run_threads
from repro.serve import ServeConfig, run_serve, serve_rank
from repro.serve.pages import PageDirectory, PageStore
from repro.serve import wire


def _cfg(**over) -> ServeConfig:
    base = dict(sessions=16, rate=400.0, seed=11, slots_per_worker=32,
                deadline_s=45.0)
    base.update(over)
    return ServeConfig(**base)


class TestServeSmoke:
    def test_all_sessions_complete_and_verify(self):
        cfg = _cfg()
        reports = run_serve(cfg, ranks=3)
        router, workers = reports[0], reports[1:]
        assert router["sessions"] == cfg.sessions
        assert router["bad_checksums"] == 0
        assert sum(w["served"] for w in workers) == cfg.sessions
        assert all(w["verify_failures"] == 0 for w in workers)
        assert router["p99_us"] >= router["p50_us"] > 0

    def test_raccumulated_token_total_matches_done_frames(self):
        """Satellite-1 in anger: the workers' request-based accumulates
        into ONE shared stats word must add up to exactly the token
        total the DONE frames report (a lost update would show here)."""
        reports = run_serve(_cfg(sessions=20, stats_interval=2), ranks=3)
        router = reports[0]
        assert router["stats_tokens"] == router["tokens"] > 0

    def test_deterministic_session_content(self):
        """Same seed => same arrival schedule, shapes and checksums on
        a different run (content is a pure function of (sid, seed))."""
        a = run_serve(_cfg(), ranks=3)[0]
        b = run_serve(_cfg(), ranks=3)[0]
        assert a["tokens"] == b["tokens"]
        assert a["sessions"] == b["sessions"]

    def test_continuous_batching_overlaps_sessions(self):
        """One worker, batch width 4, a burst of arrivals: sessions
        must decode INTERLEAVED (steps well under the sum of serial
        lengths), joining and leaving between steps."""
        cfg = _cfg(sessions=8, rate=10_000.0, max_batch=4,
                   prompt_min=16, prompt_max=16, gen_min=16, gen_max=16)
        reports = run_serve(cfg, ranks=2)
        w = reports[1]
        assert w["served"] == 8
        # 8 sessions x 16 decode steps serially = 128 batch-steps; with
        # width-4 batching the worker needs ~2 waves of 16 plus slack
        # (idle spins are excluded: busy_steps only counts live-batch
        # decode steps)
        assert w["busy_steps"] < 100


class TestZeroReceiverDrain:
    def test_page_moves_land_only_in_rma_buckets(self):
        """The data-plane contract, exact to the byte: every worker's
        rma_put/rma_get equals its page traffic plus 8 B per
        raccumulate; nothing is staged; the router never touches page
        payloads."""
        reports = run_serve(_cfg(sessions=12), ranks=3)
        router, workers = reports[0], reports[1:]
        rd = router["stats_delta"]["path_copied_bytes"]
        for path in ("rma_put", "rma_get", "rndv_staged", "rndv_posted"):
            assert rd.get(path, 0) == 0, (path, rd)
        for w in workers:
            d = w["stats_delta"]["path_copied_bytes"]
            racc = 8 * w["racc_calls"]
            assert d.get("rma_put", 0) == w["rput_bytes"] + racc
            assert d.get("rma_get", 0) == w["rget_bytes"] + racc
            assert d.get("rndv_staged", 0) == 0

    def test_passive_page_home_copies_nothing(self):
        """A rank that merely HOMES pages while a peer fills and drains
        them does not execute a single counted copy — the one-sided
        page move has zero receiver-side drain.  (Synchronization runs
        over window notify words, which are uncounted by design.)"""
        def prog(env):
            comm = env.comm
            win = comm.win_create_dynamic("pp", attach_slots=8)
            store = PageStore(comm, win, 4, 4096)
            directory = PageDirectory(comm, store)
            if env.rank == 2:
                before = comm.arena.view.stats.snapshot()
                win.wait_notify(1, timeout=30.0)    # peer's traffic done
                d = comm.arena.view.stats.delta(before)
                out = (d["copies"], d["copied_bytes"])
            elif env.rank == 1:
                src = np.arange(4096, dtype=np.uint8)
                dst = np.zeros(4096, np.uint8)
                for slot in range(4):
                    addr = directory.addr(2, slot)
                    win.rput(2, addr, src).wait()
                    win.rget(2, addr, dst).wait()
                    assert np.array_equal(dst, src)
                win.notify(2)
                out = None
            else:
                out = None
            comm.barrier()
            store.free()
            win.free()
            return out

        res = run_threads(3, prog, pool_bytes=16 << 20, timeout=60)
        assert res[2] == (0, 0)


class TestWorkerDeath:
    def test_worker_dies_mid_decode_sessions_reroute(self):
        """The satellite-4 fault drill: one worker fail-stops
        mid-decode.  The router must retire it (cancelling its posted
        DONE receives — matchbox retracted), re-route its sessions
        under a bumped epoch, finish the full population with correct
        checksums, and leave the communicator usable for a fresh
        collective on EVERY rank afterwards."""
        cfg = _cfg(sessions=16, worker_timeout=0.8, fail_rank=1,
                   fail_after_steps=25, decode_us=300.0,
                   deadline_s=45.0)

        def prog(env):
            report = serve_rank(env, cfg)
            # no stale matchbox postings anywhere after teardown —
            # cancelled receives really retracted their entries
            assert not env.comm._mb_records
            assert not any(env.comm._mb_overflow.values())
            # the comm survives for ALL ranks, dead one included
            out = env.comm.allreduce(np.full(8, float(env.rank + 1)))
            assert np.allclose(out, 1.0 + 2.0 + 3.0 + 4.0)
            return report

        reports = run_threads(
            4, lambda env: prog(env),
            pool_bytes=cfg.pool_bytes_needed(4), timeout=90)
        router, workers = reports[0], reports[1:]
        assert router["retired"] == [1]
        assert router["reroutes"] > 0
        assert reports[1]["aborted"]
        assert router["sessions"] == cfg.sessions
        assert router["bad_checksums"] == 0
        assert all(w["verify_failures"] == 0 for w in workers)
        # survivors did real work after the death
        assert sum(w["served"] for w in workers[1:]) > 0
        # epoch fencing: a dead placement cannot double-count, so the
        # raccumulate total counts every completed session exactly once
        # EXCEPT completions the dead worker never got to report
        assert router["stats_tokens"] <= router["tokens"]

    def test_pages_homed_on_dead_rank_stay_readable(self):
        """Pool memory outlives the rank: after a home worker
        fail-stops, peers still rget the pages it hosted (the CXL
        shared-pool property the serve tier leans on)."""
        def prog(env):
            comm = env.comm
            win = comm.win_create_dynamic("dd", attach_slots=4)
            store = PageStore(comm, win, 2, 1024)
            directory = PageDirectory(comm, store)
            if env.rank == 1:
                store.write_local(0, np.full(1024, 7, np.uint8))
                win.notify(2)          # "filled" — then fail-stop:
                # rank 1 serves nothing further, but does NOT free
            if env.rank == 2:
                win.wait_notify(1, timeout=30.0)
                dst = np.zeros(1024, np.uint8)
                win.rget(1, directory.addr(1, 0), dst).wait()
                ok = bool((dst == 7).all())
            else:
                ok = True
            comm.barrier()             # teardown fence
            store.free()
            win.free()
            return ok

        assert all(run_threads(3, prog, pool_bytes=16 << 20,
                               timeout=60))


class TestWire:
    def test_admit_roundtrip(self):
        buf = np.zeros(wire.admit_words(4), np.int64)
        pages = [wire.pack_page(2, 7), wire.pack_page(1, 31)]
        wire.encode_admit(buf, sid=9, epoch=2, prompt=16, gen=24,
                          pages=pages)
        msg = wire.decode_admit(buf)
        assert msg == dict(sid=9, epoch=2, prompt=16, gen=24,
                           pages=[(2, 7), (1, 31)])

    def test_session_checksum_matches_worker_fold(self):
        """The router-side recompute is exactly the worker's fold
        order: tokens in KV order, then page checksums."""
        sid, prompt, gen, pt, pb, seed = 3, 10, 14, 16, 256, 5
        acc = 0
        for t in range(gen):
            acc = wire.fold(acc, wire.token(sid, prompt + t, seed))
        for p in range(wire.pages_for(prompt, gen, pt)):
            acc = wire.fold(acc, wire.page_checksum(
                wire.page_fill(sid, p, seed, pb)))
        assert acc == wire.session_checksum(sid, prompt, gen, pt, pb,
                                            seed)

    def test_content_is_deterministic(self):
        assert wire.token(1, 2, 3) == wire.token(1, 2, 3)
        a = wire.page_fill(4, 5, 6, 512)
        b = wire.page_fill(4, 5, 6, 512)
        assert np.array_equal(a, b)
        assert not np.array_equal(a, wire.page_fill(4, 6, 6, 512))
