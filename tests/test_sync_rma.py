"""Synchronization (§3.4) and one-sided RMA (§3.2): barrier, bakery lock
mutual exclusion, PSCW epochs, put/get/accumulate, fence."""
import threading
import time

import numpy as np
import pytest

from repro.core import run_threads
from repro.core.coherence import CoherentView
from repro.core.pool import LocalPool
from repro.core.sync import BakeryLock, SeqBarrier


class TestSeqBarrier:
    def test_rendezvous(self):
        pool = LocalPool(4096)
        view = CoherentView(pool, "coherent")
        n = 4
        bars = [SeqBarrier(view, 0, n, r, initialize=(r == 0))
                for r in range(n)]
        arrived = []
        lock = threading.Lock()

        def worker(r):
            time.sleep(0.01 * r)
            with lock:
                arrived.append(r)
            bars[r].wait()
            # after the barrier, everyone must have arrived
            with lock:
                assert len(arrived) == n

        ts = [threading.Thread(target=worker, args=(r,)) for r in range(n)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(10)

    def test_reusable(self):
        pool = LocalPool(4096)
        view = CoherentView(pool, "coherent")
        bars = [SeqBarrier(view, 0, 2, r, initialize=(r == 0))
                for r in range(2)]

        def worker(r):
            for _ in range(50):
                bars[r].wait()

        ts = [threading.Thread(target=worker, args=(r,)) for r in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(10)
            assert not t.is_alive()


class TestBakery:
    def test_mutual_exclusion(self):
        pool = LocalPool(4096)
        n = 4
        locks = [BakeryLock(CoherentView(pool, "coherent"), 0, n, r,
                            initialize=(r == 0)) for r in range(n)]
        counter = {"v": 0}

        def worker(r):
            for _ in range(200):
                locks[r].acquire()
                v = counter["v"]          # racy read-modify-write unless
                time.sleep(0)             # the lock really excludes
                counter["v"] = v + 1
                locks[r].release()

        ts = [threading.Thread(target=worker, args=(r,)) for r in range(n)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(30)
        assert counter["v"] == n * 200


class TestRMA:
    def test_put_get_fence(self):
        def prog(env):
            r, n = env.rank, env.size
            win = env.comm.win_allocate("w", 64)
            win.fence()
            win.put((r + 1) % n, 0, f"from{r}".encode())
            win.fence()
            return bytes(win.get(r, 0, 5))

        res = run_threads(3, prog, pool_bytes=8 << 20)
        for r, got in enumerate(res):
            assert got == f"from{(r - 1) % 3}".encode()

    def test_put_array_roundtrip(self):
        def prog(env):
            win = env.comm.win_allocate("w", 1024)
            arr = np.arange(32, dtype=np.float64) * (env.rank + 1)
            win.fence()
            win.put_array(env.rank, 0, arr)
            win.fence()
            peer = (env.rank + 1) % env.size
            return win.get_array(peer, 0, (32,), np.float64)

        res = run_threads(2, prog, pool_bytes=8 << 20)
        assert np.allclose(res[0], np.arange(32) * 2)
        assert np.allclose(res[1], np.arange(32) * 1)

    def test_accumulate_atomic_under_lock(self):
        def prog(env):
            win = env.comm.win_allocate("w", 64)
            win.fence()
            for _ in range(25):
                win.accumulate(0, 0, np.array([1.0]))
            win.fence()
            return np.frombuffer(win.get(0, 0, 8))[0]

        res = run_threads(4, prog, pool_bytes=8 << 20, timeout=120)
        assert res[0] == 100.0

    def test_pscw_epoch(self):
        def prog(env):
            r = env.rank
            win = env.comm.win_allocate("w", 64)
            if r == 0:                        # origin
                win.start([1])
                win.put(1, 0, b"epoch-data")
                win.complete([1])
                return b""
            win.post([0])                     # target
            win.wait([0])
            return bytes(win.get(1, 0, 10))

        res = run_threads(2, prog, pool_bytes=8 << 20)
        assert res[1] == b"epoch-data"

    def test_lock_unlock(self):
        def prog(env):
            win = env.comm.win_allocate("w", 64)
            win.fence()
            for _ in range(10):
                win.lock()
                cur = np.frombuffer(win.get(0, 0, 8))[0]
                win.put(0, 0, np.float64(cur + 1).tobytes())
                win.unlock()
            win.fence()
            return np.frombuffer(win.get(0, 0, 8))[0]

        res = run_threads(3, prog, pool_bytes=8 << 20, timeout=120)
        assert res[0] == 30.0

    def test_window_bounds(self):
        def prog(env):
            win = env.comm.win_allocate("w", 16)
            with pytest.raises(IndexError):
                win.put(0, 12, b"too-long")
            return True

        assert all(run_threads(2, prog, pool_bytes=8 << 20))


class TestGetIntoRegistration:
    def test_get_into_registration_destination(self):
        """get_into accepts a pinned Registration: the window load
        drains straight into the user's buffer (one rma_get-counted
        copy, the shadow stays untouched) — the same destination kinds
        the pt2pt posting path takes."""
        size = 2048

        def prog(env):
            win = env.comm.win_allocate("w", 4096)
            st = env.arena.view.stats
            win.fence()
            win.put(env.rank, 0, bytes([env.rank + 1]) * size)
            win.fence()
            peer = (env.rank + 1) % env.size
            dst = np.zeros(size, np.uint8)
            reg = env.comm.register(dst)
            g0 = st.path_copied_bytes["rma_get"]
            got = win.get_into(peer, 0, reg)
            dg = st.path_copied_bytes["rma_get"] - g0
            env.comm.unregister(reg)
            win.fence()
            return got, dg, bool(np.all(dst == peer + 1))

        for got, dg, ok in run_threads(2, prog, pool_bytes=16 << 20):
            assert got == size and dg == size and ok


class TestAccumulateUnderSharedLock:
    def test_accumulate_excluded_by_shared_holders(self):
        """MPI_Accumulate takes the window lock EXCLUSIVELY; concurrent
        lock(shared=True) holders must see the two accumulated cells
        move in lockstep — a reader holding the shared lock can never
        observe a half-applied accumulate (get+op+put torn in the
        middle), and the final totals are exact."""
        iters = 20

        def prog(env):
            win = env.comm.win_allocate("wacc", 64)
            win.fence()
            if env.rank == 0:
                win.put(0, 0, np.zeros(2).tobytes())
            win.fence()
            if env.rank in (0, 1):           # accumulators
                for _ in range(iters):
                    win.accumulate(0, 0, np.array([1.0, 1.0]))
                win.fence()
                return None
            tears = 0                        # concurrent shared readers
            for _ in range(iters * 3):
                win.lock(shared=True)
                pair = np.frombuffer(win.get(0, 0, 16))
                win.unlock(shared=True)
                if pair[0] != pair[1]:
                    tears += 1
            win.fence()
            final = np.frombuffer(win.get(0, 0, 16))
            return tears, final.copy()

        res = run_threads(4, prog, pool_bytes=8 << 20, timeout=120)
        for out in res[2:]:
            tears, final = out
            assert tears == 0                # no torn accumulate seen
            assert np.allclose(final, [2.0 * iters, 2.0 * iters])

    def test_accumulate_custom_op_with_shared_readers(self):
        """accumulate(op=np.maximum) interleaved with shared-lock
        readers: the exclusive lock serializes read-op-write against
        them, and the final cell is the true max across ranks."""
        def prog(env):
            win = env.comm.win_allocate("wmax", 64)
            win.fence()
            if env.rank == 0:
                win.put(0, 0, np.zeros(1).tobytes())
            win.fence()
            for i in range(10):
                win.accumulate(0, 0, np.array([float(env.rank * 10 + i)]),
                               op=np.maximum)
                win.lock(shared=True)
                seen = np.frombuffer(win.get(0, 0, 8))[0]
                win.unlock(shared=True)
                assert seen >= float(env.rank * 10 + i)
            win.fence()
            return np.frombuffer(win.get(0, 0, 8))[0]

        res = run_threads(3, prog, pool_bytes=8 << 20, timeout=120)
        assert all(v == 29.0 for v in res)
