"""Protocol-discipline linter: the shipped core must lint clean, and
each rule must fire on a minimal synthetic violation (and stay quiet
on the sanctioned counterpart)."""
from pathlib import Path

import repro.core
from repro.analysis.lint_protocol import lint_paths, lint_sources

CORE = Path(repro.core.__file__).resolve().parent


def codes(findings):
    return {f.rule for f in findings}


class TestShippedCore:
    def test_core_lints_clean(self):
        findings = lint_paths([CORE])
        assert findings == [], "\n".join(str(f) for f in findings)


class TestRawAccess:
    def test_raw_write_outside_coherence_layer_flagged(self):
        fs = lint_sources({"x/foo.py":
                           "def f(v):\n    v.raw_write(0, b'x')\n"})
        assert codes(fs) == {"LP001"}
        assert fs[0].line == 2

    def test_pool_and_backing_chains_flagged(self):
        src = ("def f(self, data):\n"
               "    self.pool.write(0, data)\n"
               "    return self.backing.read(0, 8)\n")
        fs = lint_sources({"x/foo.py": src})
        assert [f.line for f in fs] == [2, 3]
        assert codes(fs) == {"LP001"}

    def test_waiver_comment_suppresses(self):
        src = ("def f(v):\n"
               "    v.raw_write(0, b'x')  # lint: raw-ok (init)\n")
        assert lint_sources({"x/foo.py": src}) == []

    def test_coherence_layer_itself_exempt(self):
        src = "def f(self, o, d):\n    self.pool.write(o, d)\n"
        assert lint_sources({"x/coherence.py": src}) == []
        assert lint_sources({"x/pool.py": src}) == []


class TestReservedTags:
    def test_unvalidated_surface_flagged(self):
        src = ("def isend(self, dest, data, tag=0):\n"
               "    return self.q.push(data, tag)\n")
        fs = lint_sources({"x/a.py": src})
        assert codes(fs) == {"LP002"}
        assert "isend" in fs[0].message

    def test_direct_validation_passes(self):
        src = ("TAG_RESERVED_BASE = 1 << 30\n"
               "def isend(self, dest, data, tag=0):\n"
               "    if tag >= TAG_RESERVED_BASE:\n"
               "        raise ValueError(tag)\n")
        assert lint_sources({"x/a.py": src}) == []

    def test_delegation_reaches_validation(self):
        # recv -> irecv -> _impl references the constant: all clean
        src = ("TAG_RESERVED_BASE = 1 << 30\n"
               "def _impl(self, src, tag):\n"
               "    assert tag < TAG_RESERVED_BASE\n"
               "def irecv(self, src, tag=0):\n"
               "    return self._impl(src, tag)\n"
               "def recv(self, src, tag=0):\n"
               "    return self.irecv(src, tag).wait()\n")
        assert lint_sources({"x/a.py": src}) == []

    def test_class_instantiation_counts_as_delegation(self):
        # send_init returns a request object whose start() validates —
        # the comm.py persistent-request shape
        src = ("TAG_RESERVED_BASE = 1 << 30\n"
               "class PersistentRequest:\n"
               "    def start(self):\n"
               "        if self.tag >= TAG_RESERVED_BASE:\n"
               "            raise ValueError\n"
               "def send_init(self, dest, buf, tag=0):\n"
               "    return PersistentRequest(self, dest, buf, tag)\n")
        assert lint_sources({"x/a.py": src}) == []

    def test_private_and_tagless_surfaces_ignored(self):
        src = ("def _isend(self, dest, data, tag=0):\n"
               "    return 1\n"
               "def send_queue(self, dest):\n"
               "    return 2\n")
        assert lint_sources({"x/a.py": src}) == []


class TestTickSleeps:
    def test_nonzero_sleep_in_progress_flagged(self):
        src = "import time\n\ndef tick():\n    time.sleep(0.001)\n"
        fs = lint_sources({"x/progress.py": src})
        assert codes(fs) == {"LP003"}

    def test_non_literal_sleep_flagged(self):
        src = "import time\n\ndef tick(d):\n    time.sleep(d)\n"
        assert codes(lint_sources({"x/progress.py": src})) == {"LP003"}

    def test_yield_sleep_zero_allowed(self):
        src = "import time\n\ndef tick():\n    time.sleep(0)\n"
        assert lint_sources({"x/progress.py": src}) == []

    def test_other_files_not_tick_paths(self):
        src = "import time\n\ndef poll():\n    time.sleep(0.5)\n"
        assert lint_sources({"x/pt2pt.py": src}) == []


class TestMatchboxSingleWriter:
    def test_unannotated_store_flagged(self):
        src = ("_MB_CLAIM = 32\n"
               "def claim(v, off, pid):\n"
               "    v.nt_store_u64(off + _MB_CLAIM, pid)\n")
        fs = lint_sources({"x/mb.py": src})
        assert codes(fs) == {"LP004"}
        assert "unannotated" in fs[0].message

    def test_wrong_side_flagged(self):
        src = ("_MB_CLAIM = 32\n"
               "# mb-writer: receiver\n"
               "def retract(v, off):\n"
               "    v.nt_store_u64(off + _MB_CLAIM, 0)\n")
        fs = lint_sources({"x/mb.py": src})
        assert codes(fs) == {"LP004"}
        assert "single-writer" in fs[0].message

    def test_correct_annotations_pass(self):
        src = ("_MB_CLAIM = 32\n"
               "_MB_TAG = 8\n"
               "# mb-writer: sender\n"
               "def claim(v, off, pid):\n"
               "    v.nt_store_u64(off + _MB_CLAIM, pid)\n"
               "# mb-writer: receiver\n"
               "def post(mb, v, slot, tag):\n"
               "    off = mb.entry_off(0, 1, slot)\n"
               "    v.nt_store_u64(off + _MB_TAG, tag)\n"
               "    v.nt_store_u64(off, 7)\n")
        assert lint_sources({"x/mb.py": src}) == []

    def test_bare_postid_publish_needs_annotation(self):
        src = ("def publish(mb, v, slot):\n"
               "    off = mb.entry_off(0, 1, slot)\n"
               "    v.nt_store_u64(off, 7)\n")
        assert codes(lint_sources({"x/mb.py": src})) == {"LP004"}

    def test_non_matchbox_stores_ignored(self):
        src = ("def ack(v, ack_off):\n"
               "    v.nt_store_u8(ack_off + 4, 1)\n")
        assert lint_sources({"x/mb.py": src}) == []


class TestTraceGuards:
    def test_unguarded_emit_flagged(self):
        src = ("def tick(self):\n"
               "    self.tracer.emit(1, 0, 0, 0)\n")
        fs = lint_sources({"x/progress.py": src})
        assert codes(fs) == {"LP005"}
        assert fs[0].line == 2

    def test_guarded_plain_int_emit_passes(self):
        src = ("def tick(self):\n"
               "    tr = self.tracer\n"
               "    if tr.enabled:\n"
               "        tr.emit(1, self.rank, 0, 0)\n")
        assert lint_sources({"x/progress.py": src}) == []

    def test_fstring_arg_flagged_even_when_guarded(self):
        src = ("def f(self, dest):\n"
               "    tr = self.tracer\n"
               "    if tr.enabled:\n"
               "        tr.emit(1, f'dest={dest}', 0, 0)\n")
        fs = lint_sources({"x/pt2pt.py": src})
        assert codes(fs) == {"LP005"}
        assert "eager" in fs[0].message.lower() \
            or "f-string" in fs[0].message.lower() \
            or "build" in fs[0].message.lower()

    def test_dict_arg_flagged(self):
        src = ("def f(self, n):\n"
               "    tr = self.tracer\n"
               "    if tr.enabled:\n"
               "        tr.emit(1, {'n': n}, 0, 0)\n")
        assert codes(lint_sources({"x/pt2pt.py": src})) == {"LP005"}

    def test_else_branch_not_considered_guarded(self):
        src = ("def f(self):\n"
               "    tr = self.tracer\n"
               "    if tr.enabled:\n"
               "        tr.emit(1, 0, 0, 0)\n"
               "    else:\n"
               "        tr.emit(2, 0, 0, 0)\n")
        fs = lint_sources({"x/progress.py": src})
        assert codes(fs) == {"LP005"}
        assert [f.line for f in fs] == [6]

    def test_only_hot_path_files_in_scope(self):
        src = ("def f(self):\n"
               "    self.tracer.emit(1, 0, 0, 0)\n")
        assert lint_sources({"x/comm.py": src}) == []
        assert lint_sources({"x/rma.py": src}) == []


class TestCli:
    def test_cli_clean_on_core(self, capsys):
        from repro.analysis.lint_protocol import main
        assert main([str(CORE)]) == 0
        assert "0 finding(s)" in capsys.readouterr().out

    def test_cli_nonzero_on_violation(self, tmp_path, capsys):
        from repro.analysis.lint_protocol import main
        bad = tmp_path / "bad.py"
        bad.write_text("def f(v):\n    v.raw_write(0, b'x')\n")
        assert main([str(bad)]) == 1
        assert "LP001" in capsys.readouterr().out
