"""CXL SHM Arena: lifecycle, multi-level hashing, allocation invariants."""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import Arena, ArenaFullError, LocalPool
from repro.core.arena import NAME_MAX, level_capacities, _hash_name


def fresh_arena(pool_bytes=4 << 20, **kw):
    return Arena(LocalPool(pool_bytes), 0, initialize=True, **kw)


class TestLevels:
    def test_paper_configuration(self):
        """§3.7: 10 levels under 200,000 -> 199,999..199,873; 1,999,260
        slots total."""
        caps = level_capacities(200_000, 10)
        assert caps[0] == 199_999
        assert caps[-1] == 199_873
        assert sum(caps) == 1_999_260
        assert len(set(caps)) == 10          # distinct primes

    def test_descending_primes(self):
        caps = level_capacities(251, 10)
        assert caps == sorted(caps, reverse=True)

    def test_hash_level_salted(self):
        h = [_hash_name(b"object", lvl) for lvl in range(10)]
        assert len(set(h)) == 10


class TestLifecycle:
    def test_create_open_destroy_close(self):
        a = fresh_arena()
        h = a.create("x", 100)
        assert a.open("x").offset == h.offset
        a.close(h)
        assert h.closed
        h2 = a.open("x")
        a.destroy(h2)
        with pytest.raises(FileNotFoundError):
            a.open("x")

    def test_create_duplicate_raises(self):
        a = fresh_arena()
        a.create("x", 10)
        with pytest.raises(FileExistsError):
            a.create("x", 10)

    def test_data_roundtrip(self):
        a = fresh_arena()
        h = a.create("d", 1000)
        payload = bytes(range(256)) * 3
        a.write(h, 10, payload)
        assert a.read(h, 10, len(payload)) == payload

    def test_bounds_checked(self):
        a = fresh_arena()
        h = a.create("d", 64)
        with pytest.raises(IndexError):
            a.write(h, 60, b"123456")
        with pytest.raises(IndexError):
            a.read(h, -1, 4)

    def test_name_limits(self):
        a = fresh_arena()
        a.create("n" * NAME_MAX, 64)
        with pytest.raises(ValueError):
            a.create("n" * (NAME_MAX + 1), 64)
        with pytest.raises(ValueError):
            a.create("", 64)

    def test_second_mapping_sees_objects(self):
        pool = LocalPool(4 << 20)
        a0 = Arena(pool, 0, initialize=True)
        a0.create("shared", 128)
        a1 = Arena(pool, 1, initialize=False)
        assert a1.open("shared").size == 128

    def test_heap_exhaustion(self):
        a = fresh_arena(1 << 20, base_slots=53, n_levels=3)
        with pytest.raises(ArenaFullError):
            a.create("big", 4 << 20)

    def test_free_reuse(self):
        a = fresh_arena()
        h1 = a.create("a", 1024)
        off1 = h1.offset
        a.destroy(h1)
        h2 = a.create("b", 512)   # first-fit reuse of the freed block
        assert h2.offset == off1

    def test_stats(self):
        a = fresh_arena()
        a.create("s1", 64)
        a.create("s2", 64)
        st = a.stats()
        assert st["slots_used"] == 2
        assert st["heap_used"] >= 128


class TestCollisions:
    def test_multilevel_absorbs_collisions(self):
        """With tiny level capacities, many keys still fit (one slot per
        level per key => up to n_levels colliding keys per bucket chain)."""
        a = fresh_arena(8 << 20, base_slots=13, n_levels=6)
        created = []
        try:
            for i in range(40):
                created.append(a.create(f"k{i}", 64))
        except ArenaFullError:
            pass
        assert len(created) >= 14     # beyond a single level's 13 slots
        for i, h in enumerate(created):
            assert a.open(f"k{i}").offset == h.offset

    def test_full_table_raises(self):
        a = fresh_arena(8 << 20, base_slots=3, n_levels=2)
        with pytest.raises(ArenaFullError):
            for i in range(100):
                a.create(f"k{i}", 64)


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(
    st.tuples(st.text(alphabet="abcdefgh", min_size=1, max_size=8),
              st.integers(min_value=1, max_value=2048)),
    min_size=1, max_size=40))
def test_property_no_overlap_and_findable(ops):
    """Invariant: live objects never overlap and open() finds exactly the
    offset create() returned; destroy removes only its own object."""
    a = fresh_arena(8 << 20)
    live: dict[str, tuple[int, int]] = {}
    for name, size in ops:
        if name in live:
            h = a.open(name)
            a.destroy(h)
            del live[name]
        else:
            try:
                h = a.create(name, size)
            except ArenaFullError:
                continue
            live[name] = (h.offset, size)
    # verify
    spans = sorted(live.values())
    for (o1, s1), (o2, _s2) in zip(spans, spans[1:]):
        assert o1 + s1 <= o2, "live objects overlap"
    for name, (off, _) in live.items():
        assert a.open(name).offset == off
