"""RMA v2: schedule-compiled one-sided communication.

Request-based rput/rget over the shared progress engine (local
completion, chunked, composable with pt2pt requests in waitall),
notified access with its deterministic zero-receiver-copy guarantee,
the get-based allgather and put-based bcast window collectives,
passive-target lock_all/flush epochs, and ProtocolStats attribution of
every RMA byte to an ``rma_*`` path bucket."""
import numpy as np
import pytest

from repro.core import run_threads


class TestRequestBasedRMA:
    def test_rput_rget_roundtrip(self):
        def prog(env):
            r, n = env.rank, env.size
            win = env.comm.win_allocate("w", 1 << 16)
            src = (np.arange(4096, dtype=np.uint8) + r).astype(np.uint8)
            win.fence()
            win.rput(r, 0, src).wait()
            win.fence()
            peer = (r + 1) % n
            dst = np.zeros(4096, np.uint8)
            res = win.rget(peer, 0, dst).wait()
            assert res is dst             # wait() returns the dest
            win.free()
            return np.array_equal(
                dst, (np.arange(4096) + peer).astype(np.uint8))

        assert all(run_threads(3, prog, pool_bytes=16 << 20))

    def test_rput_chunked_counts_rma_put(self):
        """A chunked rput moves one chunk per engine tick and lands
        every byte in path_copied_bytes['rma_put'] (the §6 accounting
        fix: no RMA byte escapes the path buckets)."""
        size = 64 * 1024

        def prog(env):
            win = env.comm.win_allocate("w", size)
            st = env.arena.view.stats
            c0 = st.path_copied_bytes["rma_put"]
            src = np.full(size, env.rank, np.uint8)
            win.fence()
            req = win.rput(env.rank, 0, src, chunk_bytes=8 * 1024)
            req.wait()
            win.fence()
            put_bytes = st.path_copied_bytes["rma_put"] - c0
            got = win.get_array((env.rank + 1) % env.size, 0,
                                (size,), np.uint8)
            win.free()
            return put_bytes, bool(np.all(got == (env.rank + 1) % env.size))

        res = run_threads(2, prog, pool_bytes=16 << 20)
        for put_bytes, ok in res:
            assert put_bytes == size
            assert ok

    def test_blocking_put_get_count_paths(self):
        """Blocking put/get/accumulate all attribute their payloads
        (put->rma_put, get->rma_get, accumulate->one of each)."""
        def prog(env):
            win = env.comm.win_allocate("w", 256)
            st = env.arena.view.stats
            win.fence()
            p0, g0 = (st.path_copied_bytes["rma_put"],
                      st.path_copied_bytes["rma_get"])
            win.put(env.rank, 0, b"x" * 100)
            _ = win.get(env.rank, 0, 100)
            win.accumulate(env.rank, 128, np.arange(4.0))
            win.fence()
            dp = st.path_copied_bytes["rma_put"] - p0
            dg = st.path_copied_bytes["rma_get"] - g0
            win.free()
            return dp, dg

        for dp, dg in run_threads(2, prog, pool_bytes=16 << 20):
            assert dp == 100 + 32        # put + accumulate write-back
            assert dg == 100 + 32        # get + accumulate read

    def test_mixed_waitall_pt2pt_and_rma(self):
        """comm.waitall drains a mixed bag: a pt2pt isend/irecv pair
        plus rput and rget requests, in one call."""
        def prog(env):
            r, n = env.rank, env.size
            comm = env.comm
            win = comm.win_allocate("w", 1 << 16)
            win.fence()
            peer = (r + 1) % n
            src_rank = (r - 1) % n
            sreq = comm.isend(peer, np.full(512, r, np.uint8), tag=5)
            rreq = comm.irecv(src_rank, tag=5)
            preq = win.rput(r, 0, np.full(2048, r, np.uint8),
                            chunk_bytes=512)
            comm.waitall([sreq, rreq, preq])
            win.fence()
            dst = np.zeros(2048, np.uint8)
            greq = win.rget(peer, 0, dst, chunk_bytes=512)
            comm.waitall([greq])
            msg = rreq.data
            win.free()
            return (bool(np.all(np.frombuffer(msg, np.uint8) == src_rank)),
                    bool(np.all(dst == peer)))

        for pt_ok, rma_ok in run_threads(3, prog, pool_bytes=16 << 20):
            assert pt_ok and rma_ok


class TestNotifiedAccess:
    def test_put_notify_zero_receiver_copy(self):
        """The notified-put fast path: payload counted once at the
        ORIGIN under rma_notify; the consumer's copied-byte counters do
        not move at all — deterministically zero receiver-side copies
        (it spins on one non-temporal word and reads in place)."""
        payload = b"sensor-frame-0042"

        def prog(env):
            win = env.comm.win_allocate("w", 4096)
            st = env.arena.view.stats
            win.fence()
            if env.rank == 0:
                n0 = st.path_copied_bytes["rma_notify"]
                win.put_notify(1, 64, payload)
                out = ("origin", st.path_copied_bytes["rma_notify"] - n0)
            else:
                c0 = st.copied_bytes
                assert win.wait_notify(0) == 1
                got = bytes(win.local_view(64, len(payload)))
                out = ("consumer", st.copied_bytes - c0, got)
            win.fence()
            win.free()
            return out

        origin, consumer = run_threads(2, prog, pool_bytes=16 << 20)
        assert origin == ("origin", len(payload))
        assert consumer == ("consumer", 0, payload)

    def test_notify_counts_and_test_notify(self):
        """Back-to-back notifies queue on the monotonic counter;
        test_notify peeks without consuming; wait_notify(count=k)
        consumes exactly k."""
        def prog(env):
            win = env.comm.win_allocate("w", 4096)
            win.fence()
            if env.rank == 0:
                for i in range(3):
                    win.put_notify(1, 128 * i, bytes([i]) * 8)
                win.fence()
                win.free()
                return None
            win.wait_notify(0, count=3)
            assert win.test_notify(0) == 0
            vals = [win.local_view(128 * i, 8)[0] for i in range(3)]
            win.fence()
            win.free()
            return vals

        res = run_threads(2, prog, pool_bytes=16 << 20)
        assert res[1] == [0, 1, 2]

    def test_wait_notify_timeout(self):
        def prog(env):
            win = env.comm.win_allocate("w", 256)
            win.fence()
            if env.rank == 1:
                with pytest.raises(TimeoutError):
                    win.wait_notify(0, timeout=0.2)
            win.fence()
            win.free()
            return True

        assert all(run_threads(2, prog, pool_bytes=16 << 20))


class TestWindowCollectives:
    def test_allgather_get(self):
        def prog(env):
            win = env.comm.win_allocate("w", 1 << 16)
            shard = np.full(64, float(env.rank) + 0.5)
            out = win.allgather(shard)
            win.free()
            return out

        n = 4
        res = run_threads(n, prog, pool_bytes=32 << 20)
        exp = np.repeat(np.arange(n) + 0.5, 64)
        for out in res:
            assert np.array_equal(out, exp)

    def test_allgather_counts_rma_coll_no_wire_payload(self):
        """The get-based allgather's payloads move only through the
        window (rma_coll bucket); the wire carries zero-byte tokens
        only, so the eager/rndv payload buckets stay flat."""
        def prog(env):
            win = env.comm.win_allocate("w", 1 << 16)
            st = env.arena.view.stats
            before = dict(st.path_copied_bytes)
            out = win.allgather(np.arange(128.0) * (env.rank + 1))
            coll = st.path_copied_bytes["rma_coll"] - before["rma_coll"]
            wire = sum(st.path_copied_bytes[k] - before[k]
                       for k in ("eager", "rndv_staged", "rndv_posted"))
            win.free()
            return out.size, coll, wire

        for size, coll, wire in run_threads(3, prog, pool_bytes=32 << 20):
            assert size == 3 * 128
            assert coll > 0
            assert wire == 0

    def test_bcast_put_roots_and_chunks(self):
        def prog(env):
            win = env.comm.win_allocate("w", 1 << 17)
            outs = []
            for root in (0, env.size - 1):
                arr = (np.arange(8192, dtype=np.float64)
                       if env.rank == root
                       else np.zeros(8192))
                win.ibcast(arr, root=root, chunk_bytes=16 * 1024).wait()
                outs.append(bool(np.array_equal(arr,
                                                np.arange(8192.0))))
                win.fence()          # bcast completion is local
            win.free()
            return outs

        for outs in run_threads(4, prog, pool_bytes=64 << 20):
            assert outs == [True, True]

    def test_interleaves_with_comm_collectives(self):
        """Window collectives share the communicator's tag sequence:
        alternating comm.allreduce and win.allgather in the same order
        on every rank must not cross-match."""
        def prog(env):
            win = env.comm.win_allocate("w", 4096)
            a = env.comm.allreduce(np.full(16, 1.0))
            g = win.allgather(np.full(16, float(env.rank)))
            b = env.comm.allreduce(np.full(16, 2.0))
            win.free()
            return float(a[0]), g.copy(), float(b[0])

        n = 3
        res = run_threads(n, prog, pool_bytes=32 << 20)
        for a0, g, b0 in res:
            assert a0 == n and b0 == 2 * n
            assert np.array_equal(g, np.repeat(np.arange(n,
                                                         dtype=float), 16))

    def test_size_1_and_bounds(self):
        def prog(env):
            win = env.comm.win_allocate("w", 128)
            g = win.allgather(np.arange(4.0))
            with pytest.raises(ValueError):
                win.allgather(np.zeros(1024))    # shard > win_size
            with pytest.raises(ValueError):
                win.ibcast(np.zeros(1024), root=0)
            win.free()
            return g

        res = run_threads(1, prog, pool_bytes=8 << 20)
        assert np.array_equal(res[0], np.arange(4.0))


class TestPassiveTargetEpochs:
    def test_lock_all_flush(self):
        """lock_all epochs on every rank concurrently; flush(target)
        completes the rput mid-epoch; after the closing fence each
        rank's segment holds its left neighbour's payload."""
        def prog(env):
            r, n = env.rank, env.size
            win = env.comm.win_allocate("w", 4096)
            win.fence()
            win.lock_all()
            req = win.rput((r + 1) % n, 0, np.full(1024, r, np.uint8),
                           chunk_bytes=256)
            win.flush((r + 1) % n)
            win.unlock_all()
            win.fence()
            assert req.done
            got = win.get_array(r, 0, (1024,), np.uint8)
            win.free()
            return bool(np.all(got == (r - 1) % n))

        assert all(run_threads(4, prog, pool_bytes=16 << 20))

    def test_flush_local_and_unlock_complete_requests(self):
        def prog(env):
            win = env.comm.win_allocate("w", 8192)
            win.fence()
            win.lock(shared=True)
            req = win.rput(env.rank, 0, np.full(4096, 7, np.uint8),
                           chunk_bytes=1024)
            win.unlock(shared=True)     # unlock flushes
            assert req.done
            win.fence()
            win.flush_local()           # no outstanding: no-op
            got = win.get_array(env.rank, 0, (4096,), np.uint8)
            win.free()
            return bool(np.all(got == 7))

        assert all(run_threads(2, prog, pool_bytes=16 << 20))


class TestWindowLifecycle:
    def test_free_idempotent_mid_epoch(self):
        """free() is collective but safe mid-epoch: rank 1 holds a
        shared lock and rank 0 has an un-flushed rput when free() is
        called; the internal flush + fence settles both, and repeated
        free() calls are no-ops."""
        def prog(env):
            win = env.comm.win_allocate("w", 4096)
            win.fence()
            if env.rank == 0:
                win.rput(1, 0, np.full(512, 9, np.uint8),
                         chunk_bytes=128)        # left outstanding
            else:
                win.lock_all()                   # left open
            win.free()
            win.free()                           # idempotent
            win.free()
            return True

        assert all(run_threads(2, prog, pool_bytes=16 << 20))

    def test_detached_window_rejects_requests(self):
        """A Window built without a communicator still does blocking
        put/get but refuses the engine-backed surface."""
        from repro.core.arena import Arena
        from repro.core.pool import LocalPool
        from repro.core.rma import Window

        arena = Arena(LocalPool(1 << 20), 0, initialize=True)
        win = Window(arena, "solo", 1, 0, 1024, create=True)
        win.put(0, 0, b"abc")
        assert win.get(0, 0, 3) == b"abc"
        with pytest.raises(RuntimeError):
            win.rput(0, 0, np.zeros(8, np.uint8))
        with pytest.raises(RuntimeError):
            win.allgather(np.zeros(4))


class TestRaccumulate:
    def test_blocking_accumulate_still_works(self):
        """``accumulate`` is now a thin wrapper over ``raccumulate`` on
        comm-attached windows — same result as the old synchronous
        path."""
        def prog(env):
            win = env.comm.win_allocate("acc", 1 << 12)
            if env.rank == 0:
                win.put_array(0, 0, np.zeros(16))
            win.fence()
            win.accumulate(0, 0, np.full(16, float(env.rank + 1)))
            win.fence()
            out = win.get_array(0, 0, (16,), np.float64)
            win.free()
            return float(out[0])

        res = run_threads(3, prog, pool_bytes=16 << 20)
        assert res == [6.0, 6.0, 6.0]      # 1 + 2 + 3

    def test_raccumulate_atomic_under_contention(self):
        """Every rank fires many request-based accumulates at ONE
        target word: the exclusive-lock read-modify-write chain must
        never lose an update."""
        iters = 20

        def prog(env):
            win = env.comm.win_allocate("racc", 1 << 12)
            if env.rank == 0:
                win.put_array(0, 0, np.zeros(1))
            win.fence()
            for _ in range(iters):
                win.raccumulate(0, 0, np.ones(1)).wait()
            win.fence()
            out = float(win.get_array(0, 0, (1,), np.float64)[0])
            win.free()
            return out

        res = run_threads(4, prog, pool_bytes=16 << 20, timeout=120)
        assert res[0] == 4 * iters

    def test_raccumulate_is_nonblocking_and_releases_lock(self):
        """The request returns before completion (engine-pumped), the
        source operand is applied with the window lock held, and the
        lock is free again afterwards (a fresh lock() succeeds)."""
        def prog(env):
            win = env.comm.win_allocate("rnb", 1 << 16)
            if env.rank == 0:
                win.put_array(1, 0, np.zeros(2048))
            win.fence()
            if env.rank == 0:
                req = win.raccumulate(1, 0, np.ones(2048),
                                      chunk_bytes=4096)
                req.wait()
                win.lock()        # released on completion, or deadlock
                win.unlock()
            win.fence()
            out = float(win.get_array(1, 0, (2048,), np.float64).sum())
            win.free()
            return out

        assert run_threads(2, prog, pool_bytes=16 << 20) == [2048.0,
                                                             2048.0]

    def test_raccumulate_path_buckets_split_get_put(self):
        """The read-modify-write chain attributes its Get chunks to
        ``rma_get`` and its Put chunks to ``rma_put`` on the ORIGIN —
        exactly nbytes each — while the passive target counts
        nothing."""
        nbytes = 4096

        def prog(env):
            win = env.comm.win_allocate("rpb", 1 << 13)
            win.fence()
            before = env.comm.arena.view.stats.snapshot()
            if env.rank == 0:
                win.raccumulate(
                    1, 0, np.zeros(nbytes, np.uint8)).wait()
            win.fence()
            d = env.comm.arena.view.stats.delta(before)
            win.free()
            return d["path_copied_bytes"]

        origin, target = run_threads(2, prog, pool_bytes=16 << 20)
        assert origin.get("rma_get", 0) == nbytes
        assert origin.get("rma_put", 0) == nbytes
        assert target.get("rma_get", 0) == 0
        assert target.get("rma_put", 0) == 0

    def test_raccumulate_custom_op(self):
        def prog(env):
            win = env.comm.win_allocate("rop", 1 << 12)
            if env.rank == 0:
                win.put_array(0, 0, np.full(8, 3.0))
            win.fence()
            if env.rank == 1:
                win.raccumulate(0, 0, np.full(8, 5.0),
                                op=np.maximum).wait()
            win.fence()
            out = float(win.get_array(0, 0, (8,), np.float64)[0])
            win.free()
            return out

        assert run_threads(2, prog, pool_bytes=16 << 20) == [5.0, 5.0]


class TestDynamicWindow:
    def test_attach_detach_copies_nothing(self):
        """The satellite-2 regression: serving a pool-resident buffer
        through the window must not copy it into any arena — attach
        and detach leave ``copied_bytes`` EXACTLY untouched."""
        def prog(env):
            win = env.comm.win_create_dynamic("dw0")
            buf = env.comm.alloc_buffer(4096)
            before = env.comm.arena.view.stats.snapshot()
            addr = win.attach(buf)
            win.detach(addr)
            d = env.comm.arena.view.stats.delta(before)
            env.comm.barrier()
            buf.free()
            win.free()
            return d["copied_bytes"], d["copies"]

        assert run_threads(2, prog, pool_bytes=16 << 20) == [(0, 0),
                                                             (0, 0)]

    def test_rget_of_attached_pool_buffer(self):
        """A KV-page-style read: rget a peer's attached PoolBuffer by
        its absolute pool address, no staging anywhere."""
        def prog(env):
            r = env.rank
            win = env.comm.win_create_dynamic("dw1")
            buf = env.comm.alloc_buffer(4096)
            buf.write(np.full(4096, r + 1, np.uint8))
            addr = win.attach(buf)
            addrs = env.comm.allgather(np.asarray([addr], np.int64))
            peer = (r + 1) % env.size
            dst = np.zeros(4096, np.uint8)
            win.rget(peer, int(addrs[peer]), dst).wait()
            env.comm.barrier()
            win.detach(addr)
            buf.free()
            win.free()
            return int(dst[0]), int(dst[-1])

        res = run_threads(3, prog, pool_bytes=16 << 20)
        assert res == [(2, 2), (3, 3), (1, 1)]

    def test_unattached_address_rejected(self):
        """Real bounds checking: a dynamic window only accepts
        displacements inside a LIVE attached region of the target."""
        def prog(env):
            win = env.comm.win_create_dynamic("dw2")
            buf = env.comm.alloc_buffer(4096)
            addr = win.attach(buf)
            env.comm.barrier()
            err_unattached = err_straddle = err_detached = False
            if env.rank == 1:
                try:
                    win.rget(0, 12345678, np.zeros(16, np.uint8))
                except IndexError:
                    err_unattached = True
            env.comm.barrier()
            if env.rank == 0:
                # a range starting inside but running past the region
                try:
                    win.rput(0, addr + 4000,
                             np.zeros(200, np.uint8))
                except IndexError:
                    err_straddle = True
                win.detach(addr)
            env.comm.barrier()
            if env.rank == 1:
                try:                  # tombstoned after detach
                    win.rget(0, addr if env.rank else 0,
                             np.zeros(16, np.uint8))
                except IndexError:
                    err_detached = True
            env.comm.barrier()
            buf.free()
            win.free()
            return err_unattached, err_straddle, err_detached

        r0, r1 = run_threads(2, prog, pool_bytes=16 << 20)
        assert r1 == (True, False, True)
        assert r0 == (False, True, False)

    def test_attach_table_exhaustion(self):
        def prog(env):
            win = env.comm.win_create_dynamic("dw3", attach_slots=2)
            bufs = [env.comm.alloc_buffer(64) for _ in range(3)]
            win.attach(bufs[0])
            a1 = win.attach(bufs[1])
            try:
                win.attach(bufs[2])
                full = False
            except RuntimeError:
                full = True
            win.detach(a1)
            win.attach(bufs[2])       # tombstoned slot is reusable
            env.comm.barrier()
            win.free()
            return full

        assert all(run_threads(2, prog, pool_bytes=16 << 20))

    def test_window_collectives_rejected(self):
        """A dynamic window has no symmetric per-rank segment, so the
        segment-addressed window collectives must refuse."""
        def prog(env):
            win = env.comm.win_create_dynamic("dw4")
            try:
                win.allgather(np.zeros(4))
                ok = False
            except (ValueError, IndexError):
                ok = True
            win.free()
            return ok

        assert all(run_threads(2, prog, pool_bytes=16 << 20))
