"""RMA v2: schedule-compiled one-sided communication.

Request-based rput/rget over the shared progress engine (local
completion, chunked, composable with pt2pt requests in waitall),
notified access with its deterministic zero-receiver-copy guarantee,
the get-based allgather and put-based bcast window collectives,
passive-target lock_all/flush epochs, and ProtocolStats attribution of
every RMA byte to an ``rma_*`` path bucket."""
import numpy as np
import pytest

from repro.core import run_threads


class TestRequestBasedRMA:
    def test_rput_rget_roundtrip(self):
        def prog(env):
            r, n = env.rank, env.size
            win = env.comm.win_allocate("w", 1 << 16)
            src = (np.arange(4096, dtype=np.uint8) + r).astype(np.uint8)
            win.fence()
            win.rput(r, 0, src).wait()
            win.fence()
            peer = (r + 1) % n
            dst = np.zeros(4096, np.uint8)
            res = win.rget(peer, 0, dst).wait()
            assert res is dst             # wait() returns the dest
            win.free()
            return np.array_equal(
                dst, (np.arange(4096) + peer).astype(np.uint8))

        assert all(run_threads(3, prog, pool_bytes=16 << 20))

    def test_rput_chunked_counts_rma_put(self):
        """A chunked rput moves one chunk per engine tick and lands
        every byte in path_copied_bytes['rma_put'] (the §6 accounting
        fix: no RMA byte escapes the path buckets)."""
        size = 64 * 1024

        def prog(env):
            win = env.comm.win_allocate("w", size)
            st = env.arena.view.stats
            c0 = st.path_copied_bytes["rma_put"]
            src = np.full(size, env.rank, np.uint8)
            win.fence()
            req = win.rput(env.rank, 0, src, chunk_bytes=8 * 1024)
            req.wait()
            win.fence()
            put_bytes = st.path_copied_bytes["rma_put"] - c0
            got = win.get_array((env.rank + 1) % env.size, 0,
                                (size,), np.uint8)
            win.free()
            return put_bytes, bool(np.all(got == (env.rank + 1) % env.size))

        res = run_threads(2, prog, pool_bytes=16 << 20)
        for put_bytes, ok in res:
            assert put_bytes == size
            assert ok

    def test_blocking_put_get_count_paths(self):
        """Blocking put/get/accumulate all attribute their payloads
        (put->rma_put, get->rma_get, accumulate->one of each)."""
        def prog(env):
            win = env.comm.win_allocate("w", 256)
            st = env.arena.view.stats
            win.fence()
            p0, g0 = (st.path_copied_bytes["rma_put"],
                      st.path_copied_bytes["rma_get"])
            win.put(env.rank, 0, b"x" * 100)
            _ = win.get(env.rank, 0, 100)
            win.accumulate(env.rank, 128, np.arange(4.0))
            win.fence()
            dp = st.path_copied_bytes["rma_put"] - p0
            dg = st.path_copied_bytes["rma_get"] - g0
            win.free()
            return dp, dg

        for dp, dg in run_threads(2, prog, pool_bytes=16 << 20):
            assert dp == 100 + 32        # put + accumulate write-back
            assert dg == 100 + 32        # get + accumulate read

    def test_mixed_waitall_pt2pt_and_rma(self):
        """comm.waitall drains a mixed bag: a pt2pt isend/irecv pair
        plus rput and rget requests, in one call."""
        def prog(env):
            r, n = env.rank, env.size
            comm = env.comm
            win = comm.win_allocate("w", 1 << 16)
            win.fence()
            peer = (r + 1) % n
            src_rank = (r - 1) % n
            sreq = comm.isend(peer, np.full(512, r, np.uint8), tag=5)
            rreq = comm.irecv(src_rank, tag=5)
            preq = win.rput(r, 0, np.full(2048, r, np.uint8),
                            chunk_bytes=512)
            comm.waitall([sreq, rreq, preq])
            win.fence()
            dst = np.zeros(2048, np.uint8)
            greq = win.rget(peer, 0, dst, chunk_bytes=512)
            comm.waitall([greq])
            msg = rreq.data
            win.free()
            return (bool(np.all(np.frombuffer(msg, np.uint8) == src_rank)),
                    bool(np.all(dst == peer)))

        for pt_ok, rma_ok in run_threads(3, prog, pool_bytes=16 << 20):
            assert pt_ok and rma_ok


class TestNotifiedAccess:
    def test_put_notify_zero_receiver_copy(self):
        """The notified-put fast path: payload counted once at the
        ORIGIN under rma_notify; the consumer's copied-byte counters do
        not move at all — deterministically zero receiver-side copies
        (it spins on one non-temporal word and reads in place)."""
        payload = b"sensor-frame-0042"

        def prog(env):
            win = env.comm.win_allocate("w", 4096)
            st = env.arena.view.stats
            win.fence()
            if env.rank == 0:
                n0 = st.path_copied_bytes["rma_notify"]
                win.put_notify(1, 64, payload)
                out = ("origin", st.path_copied_bytes["rma_notify"] - n0)
            else:
                c0 = st.copied_bytes
                assert win.wait_notify(0) == 1
                got = bytes(win.local_view(64, len(payload)))
                out = ("consumer", st.copied_bytes - c0, got)
            win.fence()
            win.free()
            return out

        origin, consumer = run_threads(2, prog, pool_bytes=16 << 20)
        assert origin == ("origin", len(payload))
        assert consumer == ("consumer", 0, payload)

    def test_notify_counts_and_test_notify(self):
        """Back-to-back notifies queue on the monotonic counter;
        test_notify peeks without consuming; wait_notify(count=k)
        consumes exactly k."""
        def prog(env):
            win = env.comm.win_allocate("w", 4096)
            win.fence()
            if env.rank == 0:
                for i in range(3):
                    win.put_notify(1, 128 * i, bytes([i]) * 8)
                win.fence()
                win.free()
                return None
            win.wait_notify(0, count=3)
            assert win.test_notify(0) == 0
            vals = [win.local_view(128 * i, 8)[0] for i in range(3)]
            win.fence()
            win.free()
            return vals

        res = run_threads(2, prog, pool_bytes=16 << 20)
        assert res[1] == [0, 1, 2]

    def test_wait_notify_timeout(self):
        def prog(env):
            win = env.comm.win_allocate("w", 256)
            win.fence()
            if env.rank == 1:
                with pytest.raises(TimeoutError):
                    win.wait_notify(0, timeout=0.2)
            win.fence()
            win.free()
            return True

        assert all(run_threads(2, prog, pool_bytes=16 << 20))


class TestWindowCollectives:
    def test_allgather_get(self):
        def prog(env):
            win = env.comm.win_allocate("w", 1 << 16)
            shard = np.full(64, float(env.rank) + 0.5)
            out = win.allgather(shard)
            win.free()
            return out

        n = 4
        res = run_threads(n, prog, pool_bytes=32 << 20)
        exp = np.repeat(np.arange(n) + 0.5, 64)
        for out in res:
            assert np.array_equal(out, exp)

    def test_allgather_counts_rma_coll_no_wire_payload(self):
        """The get-based allgather's payloads move only through the
        window (rma_coll bucket); the wire carries zero-byte tokens
        only, so the eager/rndv payload buckets stay flat."""
        def prog(env):
            win = env.comm.win_allocate("w", 1 << 16)
            st = env.arena.view.stats
            before = dict(st.path_copied_bytes)
            out = win.allgather(np.arange(128.0) * (env.rank + 1))
            coll = st.path_copied_bytes["rma_coll"] - before["rma_coll"]
            wire = sum(st.path_copied_bytes[k] - before[k]
                       for k in ("eager", "rndv_staged", "rndv_posted"))
            win.free()
            return out.size, coll, wire

        for size, coll, wire in run_threads(3, prog, pool_bytes=32 << 20):
            assert size == 3 * 128
            assert coll > 0
            assert wire == 0

    def test_bcast_put_roots_and_chunks(self):
        def prog(env):
            win = env.comm.win_allocate("w", 1 << 17)
            outs = []
            for root in (0, env.size - 1):
                arr = (np.arange(8192, dtype=np.float64)
                       if env.rank == root
                       else np.zeros(8192))
                win.ibcast(arr, root=root, chunk_bytes=16 * 1024).wait()
                outs.append(bool(np.array_equal(arr,
                                                np.arange(8192.0))))
                win.fence()          # bcast completion is local
            win.free()
            return outs

        for outs in run_threads(4, prog, pool_bytes=64 << 20):
            assert outs == [True, True]

    def test_interleaves_with_comm_collectives(self):
        """Window collectives share the communicator's tag sequence:
        alternating comm.allreduce and win.allgather in the same order
        on every rank must not cross-match."""
        def prog(env):
            win = env.comm.win_allocate("w", 4096)
            a = env.comm.allreduce(np.full(16, 1.0))
            g = win.allgather(np.full(16, float(env.rank)))
            b = env.comm.allreduce(np.full(16, 2.0))
            win.free()
            return float(a[0]), g.copy(), float(b[0])

        n = 3
        res = run_threads(n, prog, pool_bytes=32 << 20)
        for a0, g, b0 in res:
            assert a0 == n and b0 == 2 * n
            assert np.array_equal(g, np.repeat(np.arange(n,
                                                         dtype=float), 16))

    def test_size_1_and_bounds(self):
        def prog(env):
            win = env.comm.win_allocate("w", 128)
            g = win.allgather(np.arange(4.0))
            with pytest.raises(ValueError):
                win.allgather(np.zeros(1024))    # shard > win_size
            with pytest.raises(ValueError):
                win.ibcast(np.zeros(1024), root=0)
            win.free()
            return g

        res = run_threads(1, prog, pool_bytes=8 << 20)
        assert np.array_equal(res[0], np.arange(4.0))


class TestPassiveTargetEpochs:
    def test_lock_all_flush(self):
        """lock_all epochs on every rank concurrently; flush(target)
        completes the rput mid-epoch; after the closing fence each
        rank's segment holds its left neighbour's payload."""
        def prog(env):
            r, n = env.rank, env.size
            win = env.comm.win_allocate("w", 4096)
            win.fence()
            win.lock_all()
            req = win.rput((r + 1) % n, 0, np.full(1024, r, np.uint8),
                           chunk_bytes=256)
            win.flush((r + 1) % n)
            win.unlock_all()
            win.fence()
            assert req.done
            got = win.get_array(r, 0, (1024,), np.uint8)
            win.free()
            return bool(np.all(got == (r - 1) % n))

        assert all(run_threads(4, prog, pool_bytes=16 << 20))

    def test_flush_local_and_unlock_complete_requests(self):
        def prog(env):
            win = env.comm.win_allocate("w", 8192)
            win.fence()
            win.lock(shared=True)
            req = win.rput(env.rank, 0, np.full(4096, 7, np.uint8),
                           chunk_bytes=1024)
            win.unlock(shared=True)     # unlock flushes
            assert req.done
            win.fence()
            win.flush_local()           # no outstanding: no-op
            got = win.get_array(env.rank, 0, (4096,), np.uint8)
            win.free()
            return bool(np.all(got == 7))

        assert all(run_threads(2, prog, pool_bytes=16 << 20))


class TestWindowLifecycle:
    def test_free_idempotent_mid_epoch(self):
        """free() is collective but safe mid-epoch: rank 1 holds a
        shared lock and rank 0 has an un-flushed rput when free() is
        called; the internal flush + fence settles both, and repeated
        free() calls are no-ops."""
        def prog(env):
            win = env.comm.win_allocate("w", 4096)
            win.fence()
            if env.rank == 0:
                win.rput(1, 0, np.full(512, 9, np.uint8),
                         chunk_bytes=128)        # left outstanding
            else:
                win.lock_all()                   # left open
            win.free()
            win.free()                           # idempotent
            win.free()
            return True

        assert all(run_threads(2, prog, pool_bytes=16 << 20))

    def test_detached_window_rejects_requests(self):
        """A Window built without a communicator still does blocking
        put/get but refuses the engine-backed surface."""
        from repro.core.arena import Arena
        from repro.core.pool import LocalPool
        from repro.core.rma import Window

        arena = Arena(LocalPool(1 << 20), 0, initialize=True)
        win = Window(arena, "solo", 1, 0, 1024, create=True)
        win.put(0, 0, b"abc")
        assert win.get(0, 0, 3) == b"abc"
        with pytest.raises(RuntimeError):
            win.rput(0, 0, np.zeros(8, np.uint8))
        with pytest.raises(RuntimeError):
            win.allgather(np.zeros(4))
