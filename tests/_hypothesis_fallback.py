"""Dependency-free stand-in for the subset of the hypothesis API this
suite uses, so tier-1 collects and runs when hypothesis is not installed
(install requirements-dev.txt for the real thing).

``@given`` runs each property test over a fixed number of examples drawn
from a deterministically seeded PRNG — weaker than real hypothesis (no
shrinking, no coverage-guided generation) but it keeps the property
tests executing rather than skipped.
"""
from __future__ import annotations

import random


class HealthCheck:
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


class UnsatisfiedAssumption(Exception):
    """Raised by ``assume(False)``; the runner skips the example."""


def assume(condition) -> bool:
    """Real hypothesis steers generation away from failed assumptions;
    the fallback simply skips the example."""
    if not condition:
        raise UnsatisfiedAssumption()
    return True


def seed(_value):
    """The fallback PRNG is already fixed-seeded; accept and ignore the
    explicit seed decorator so suites can pin real hypothesis."""
    def deco(fn):
        return fn
    return deco


def note(_message):
    """Diagnostics attached to failing examples; no-op here."""


def event(_message):
    """Statistics bucket marker; no-op here."""


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value=None, max_value=None):
    lo = 0 if min_value is None else min_value
    hi = 2 ** 31 if max_value is None else max_value
    return _Strategy(lambda r: r.randint(lo, hi))


def binary(min_size=0, max_size=16):
    return _Strategy(lambda r: bytes(
        r.randrange(256) for _ in range(r.randint(min_size, max_size))))


def text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=0, max_size=16):
    chars = list(alphabet)
    return _Strategy(lambda r: "".join(
        r.choice(chars) for _ in range(r.randint(min_size, max_size))))


def lists(elements, min_size=0, max_size=16, **_kw):
    return _Strategy(lambda r: [
        elements.draw(r) for _ in range(r.randint(min_size, max_size))])


def tuples(*elems):
    return _Strategy(lambda r: tuple(e.draw(r) for e in elems))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: r.choice(seq))


def booleans():
    return _Strategy(lambda r: bool(r.getrandbits(1)))


def just(value):
    return _Strategy(lambda r: value)


def permutations(seq):
    seq = list(seq)

    def draw(r):
        out = list(seq)
        r.shuffle(out)
        return out
    return _Strategy(draw)


class _StrategiesNamespace:
    integers = staticmethod(integers)
    binary = staticmethod(binary)
    text = staticmethod(text)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    just = staticmethod(just)
    permutations = staticmethod(permutations)


strategies = _StrategiesNamespace()

_DEFAULT_EXAMPLES = 20


def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


# profile management: the fallback is always deterministic, so profiles
# are accepted and ignored (conftest registers a "ci" profile against
# real hypothesis)
settings.register_profile = lambda *_a, **_kw: None
settings.load_profile = lambda *_a, **_kw: None


def given(*strats):
    def deco(fn):
        # plain zero-arg wrapper (NOT functools.wraps): pytest must not
        # see the strategy parameters and treat them as fixtures
        def runner():
            n = getattr(runner, "_fallback_max_examples",
                        _DEFAULT_EXAMPLES)
            rng = random.Random(0xC3A1)
            done = 0
            attempts = 0
            while done < n and attempts < 20 * n:
                attempts += 1
                try:
                    fn(*[s.draw(rng) for s in strats])
                except UnsatisfiedAssumption:
                    continue
                done += 1
            if done == 0:
                # mirror real hypothesis's Unsatisfied error: a test
                # whose assumptions filtered out EVERY example must not
                # pass vacuously
                raise AssertionError(
                    f"{fn.__name__}: assume() rejected all {attempts} "
                    f"generated examples — no property was ever checked")
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
