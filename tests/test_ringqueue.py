"""SPSC ring queues + queue matrix (§3.3): FIFO, wraparound, chunking,
fullness, concurrency, and correctness on the incoherent pool."""
import threading

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.coherence import CoherentView
from repro.core.pool import IncoherentPool, LocalPool, RankCache
from repro.core.ringqueue import QueueMatrix, SPSCQueue, queue_bytes


def make_pair(cell_size=256, n_cells=4, incoherent=False):
    backing = LocalPool(queue_bytes(cell_size, n_cells) + 256)
    if incoherent:
        vp = CoherentView(IncoherentPool(backing, RankCache(backing)),
                          "incoherent")
        vc = CoherentView(IncoherentPool(backing, RankCache(backing)),
                          "incoherent")
    else:
        vp = vc = CoherentView(backing, "coherent")
    prod = SPSCQueue(vp, 0, cell_size, n_cells, producer=True,
                     initialize=True)
    cons = SPSCQueue(vc, 0, cell_size, n_cells, producer=False)
    return prod, cons


class TestSPSC:
    @pytest.mark.parametrize("incoherent", [False, True])
    def test_fifo(self, incoherent):
        p, c = make_pair(incoherent=incoherent)
        for i in range(3):
            p.enqueue(f"m{i}".encode())
        for i in range(3):
            data, _ = c.dequeue()
            assert data == f"m{i}".encode()

    def test_empty_and_full(self):
        p, c = make_pair(n_cells=2)
        assert c.try_dequeue() is None
        assert p.try_enqueue(b"1")
        assert p.try_enqueue(b"2")
        assert not p.try_enqueue(b"3")          # full
        c.dequeue()
        assert p.try_enqueue(b"3")              # space reclaimed

    def test_wraparound(self):
        p, c = make_pair(n_cells=2)
        for i in range(20):
            p.enqueue(str(i).encode())
            data, _ = c.dequeue()
            assert data == str(i).encode()

    def test_chunked_message(self):
        p, c = make_pair(cell_size=64, n_cells=4)
        msg = bytes(range(256)) * 4             # 1024 B >> 64 B cells

        results = {}

        def consumer():
            results["msg"], results["tag"] = c.recv_message(timeout=10)

        t = threading.Thread(target=consumer)
        t.start()
        p.send_message(msg, tag=42, timeout=10)
        t.join(10)
        assert results["msg"] == msg
        assert results["tag"] == 42

    def test_concurrent_stream(self):
        p, c = make_pair(cell_size=128, n_cells=4)
        n = 500
        got = []

        def consumer():
            for _ in range(n):
                got.append(c.dequeue(timeout=20)[0])

        t = threading.Thread(target=consumer)
        t.start()
        for i in range(n):
            p.enqueue(f"payload-{i}".encode(), timeout=20)
        t.join(20)
        assert got == [f"payload-{i}".encode() for i in range(n)]


class TestMatrix:
    def test_pairwise_isolation(self):
        n = 3
        backing = LocalPool(QueueMatrix.region_bytes(n, 128, 4) + 256)
        view = CoherentView(backing, "coherent")
        mats = [QueueMatrix(view, 0, n, r, 128, 4, initialize=(r == 0))
                for r in range(n)]
        # every ordered pair gets a distinct queue
        for s in range(n):
            for d in range(n):
                if s == d:
                    continue
                mats[s].send_queue(d).send_message(
                    f"{s}->{d}".encode(), tag=s * 10 + d)
        for d in range(n):
            for s in range(n):
                if s == d:
                    continue
                msg, tag = mats[d].recv_queue(s).recv_message()
                assert msg == f"{s}->{d}".encode()
                assert tag == s * 10 + d


@settings(max_examples=30, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=700), min_size=1,
                max_size=25),
       st.sampled_from([64, 128, 256]))
def test_property_stream_integrity(messages, cell_size):
    """Any message sequence (any sizes incl. > cell) arrives intact and
    in order through the chunking framing."""
    p, c = make_pair(cell_size=cell_size, n_cells=8)
    out = []

    def consumer():
        for _ in messages:
            out.append(c.recv_message(timeout=30)[0])

    t = threading.Thread(target=consumer)
    t.start()
    for m in messages:
        p.send_message(m, timeout=30)
    t.join(30)
    assert out == list(messages)
