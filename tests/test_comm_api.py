"""Comm API v2: method collectives over pool-resident round buffers,
split/dup sub-communicators, hierarchical allreduce, persistent requests,
the auto-tuned eager threshold, deprecation shims, and the progress /
window-free regression fixes."""
import warnings

import numpy as np
import pytest

from repro.core import run_threads
from repro.core.comm import _derived_name, _hier_group

CELL = 4096


# --------------------------------------------------------------------------
# split / dup
# --------------------------------------------------------------------------

class TestSplitDup:
    def test_split_remaps_ranks_and_groups(self):
        def prog(env):
            sub = env.comm.split(env.rank % 2, key=env.rank)
            s = sub.allreduce(np.array([float(env.rank)]))
            return sub.rank, sub.size, sub.parent_ranks, float(s[0])

        res = run_threads(4, prog, cell_size=CELL)
        assert res[0] == (0, 2, (0, 2), 2.0)    # evens: 0 + 2
        assert res[2] == (1, 2, (0, 2), 2.0)
        assert res[1] == (0, 2, (1, 3), 4.0)    # odds: 1 + 3
        assert res[3] == (1, 2, (1, 3), 4.0)

    def test_split_key_reorders(self):
        def prog(env):
            sub = env.comm.split(0, key=-env.rank)    # reversed order
            return sub.rank, sub.parent_ranks

        res = run_threads(3, prog, cell_size=CELL)
        assert [r[0] for r in res] == [2, 1, 0]
        assert all(r[1] == (2, 1, 0) for r in res)

    def test_split_color_none_excluded(self):
        def prog(env):
            sub = env.comm.split(0 if env.rank < 2 else None,
                                 key=env.rank)
            if sub is None:
                return None
            return sub.size, float(sub.allreduce(
                np.array([1.0]))[0])

        res = run_threads(3, prog, cell_size=CELL)
        assert res[0] == (2, 2.0) and res[1] == (2, 2.0)
        assert res[2] is None

    def test_disjoint_tag_spaces(self):
        """The SAME tag on parent, split and dup never cross-matches:
        each derived comm owns its own queue matrix."""
        def prog(env):
            peer = 1 - env.rank
            sub = env.comm.split(0, key=env.rank)
            d = env.comm.dup()
            env.comm.send(peer, b"parent", tag=5)
            sub.send(peer, b"split", tag=5)
            d.send(peer, b"dup", tag=5)
            a, _ = d.recv(peer, tag=5)
            b, _ = env.comm.recv(peer, tag=5)
            c, _ = sub.recv(peer, tag=5)
            return a, b, c

        for got in run_threads(2, prog, cell_size=CELL):
            assert got == (b"dup", b"parent", b"split")

    def test_nested_split(self):
        def prog(env):
            half = env.comm.split(env.rank // 2, key=env.rank)
            solo = half.split(half.rank, key=0)       # size-1 comms
            v = solo.allreduce(np.array([float(env.rank)]))
            again = half.split(0, key=-half.rank)     # re-split, reversed
            return solo.size, float(v[0]), again.rank, half.rank

        res = run_threads(4, prog, cell_size=CELL)
        for r, (ssz, v, arank, hrank) in enumerate(res):
            assert ssz == 1 and v == float(r)
            assert arank == 1 - hrank

    def test_dup_congruent(self):
        def prog(env):
            d = env.comm.dup()
            assert (d.rank, d.size) == (env.rank, env.size)
            out = d.allreduce(np.full(5, float(env.rank + 1)))
            return out[0]

        assert all(v == 6.0 for v in run_threads(3, prog, cell_size=CELL))

    def test_derived_name_stays_short(self):
        name = "world"
        for i in range(8):
            name = _derived_name(name, f"s{i}.{i}")
        assert len(name) <= 24


# --------------------------------------------------------------------------
# method collectives (pool-resident and fallback paths)
# --------------------------------------------------------------------------

class TestMethodCollectives:
    @pytest.mark.parametrize("coherent,nelem", [(True, 23), (True, 20000),
                                                (False, 23), (False, 20000)])
    def test_allreduce_matches_free(self, coherent, nelem):
        """Method allreduce == free-function result on both the resident
        path (large, coherent) and every fallback."""
        def prog(env):
            x = (np.arange(nelem, dtype=np.float64) + 1) * (env.rank + 1)
            return env.comm.allreduce(x, algo="ring")

        n = 3
        exp = (np.arange(nelem, dtype=np.float64) + 1) * sum(
            range(1, n + 1))
        for out in run_threads(n, prog, coherent=coherent, cell_size=CELL,
                               pool_bytes=32 << 20):
            assert np.allclose(out, exp)

    @pytest.mark.parametrize("n", [2, 4])
    def test_allreduce_rd_resident(self, n):
        def prog(env):
            return env.comm.allreduce(
                np.full(9000, float(env.rank + 1)), algo="rd")

        for out in run_threads(n, prog, cell_size=CELL,
                               pool_bytes=32 << 20):
            assert np.allclose(out, sum(range(1, n + 1)))

    @pytest.mark.parametrize("n,g", [(4, None), (4, 2), (6, None), (6, 3)])
    def test_allreduce_hier(self, n, g):
        def prog(env):
            x = np.arange(10000.0) * (env.rank + 1)
            return env.comm.allreduce(x, algo="hier", group_size=g)

        exp = np.arange(10000.0) * sum(range(1, n + 1))
        for out in run_threads(n, prog, cell_size=CELL,
                               pool_bytes=64 << 20, timeout=120):
            assert np.allclose(out, exp)

    def test_hier_fused_schedule_cached_no_subcomms(self):
        """The hier path is ONE fused schedule on the parent comm now:
        compiled once, cached, and no sub-communicators are created."""
        def prog(env):
            c = env.comm
            before = env.arena.stats()["slots_used"]
            c.allreduce(np.arange(8000.0), algo="hier")
            n1 = sum(k[0] == "allreduce_hier" for k in c._sched_cache)
            c.allreduce(np.arange(8000.0), algo="hier")
            n2 = sum(k[0] == "allreduce_hier" for k in c._sched_cache)
            seq = c._derived_seq              # split()/dup() counter
            c.barrier()
            return n1, n2, seq, env.arena.stats()["slots_used"] - before

        for n1, n2, seq, _ in run_threads(4, prog, cell_size=CELL,
                                          pool_bytes=64 << 20):
            assert n1 == n2 == 1        # compiled once, then reused
            assert seq == 0             # no split(): no derived comms

    @pytest.mark.parametrize("algo", ["ring", "bruck"])
    def test_allgather_resident(self, algo):
        n = 5

        def prog(env):
            shard = np.full(3000, float(env.rank))
            return env.comm.allgather(shard, algo=algo)

        exp = np.concatenate([np.full(3000, float(i)) for i in range(n)])
        for out in run_threads(n, prog, cell_size=CELL,
                               pool_bytes=64 << 20, timeout=120):
            assert np.array_equal(out, exp)

    def test_bcast_reduce_scatter_alltoall_methods(self):
        n = 4

        def prog(env):
            c = env.comm
            b = c.bcast(np.arange(12000.0) if env.rank == 2 else None,
                        root=2)
            rs = c.reduce_scatter(np.arange(8.0) + env.rank)
            a2a = c.alltoall([np.full(4000, env.rank * 10 + d, np.int64)
                              for d in range(n)])
            red = c.reduce(np.full(6000, float(env.rank)), root=1)
            c.barrier()
            return b, rs, [int(x[0]) for x in a2a], red

        res = run_threads(n, prog, cell_size=CELL, pool_bytes=64 << 20,
                          timeout=120)
        full = sum(np.arange(8.0) + r for r in range(n))
        for r, (b, rs, a2a, red) in enumerate(res):
            assert np.allclose(b, np.arange(12000.0))
            k = 2 * ((r + 1) % n)
            assert np.allclose(rs, full[k:k + 2])
            assert a2a == [s * 10 + r for s in range(n)]
            if r == 1:
                assert np.allclose(red, sum(range(n)))
            else:
                assert red is None

    def test_resident_copies_fewer_bytes(self):
        """The acceptance bar at test scale: comm.allreduce moves fewer
        protocol-counted bytes per call than the free-function path."""
        from repro.core import collectives as coll
        nelem = 32768                    # 256 KB float64

        def prog(env):
            x = np.full(nelem, float(env.rank + 1))
            coll.allreduce(env.comm, x, algo="ring")   # warm
            env.comm.allreduce(x, algo="ring")
            st = env.arena.view.stats
            c0 = st.copied_bytes
            a = coll.allreduce(env.comm, x, algo="ring")
            c1 = st.copied_bytes
            b = env.comm.allreduce(x, algo="ring")
            c2 = st.copied_bytes
            assert np.allclose(a, b)
            return c1 - c0, c2 - c1

        res = run_threads(2, prog, cell_size=16384, pool_bytes=64 << 20,
                          timeout=120)
        free_b = sum(r[0] for r in res)
        meth_b = sum(r[1] for r in res)
        assert meth_b < free_b
        # per-round staging is gone: expect ~2x, allow protocol headroom
        assert free_b > 1.5 * meth_b

    def test_round_buffers_persist(self):
        """Repeated method collectives reuse the round-buffer pool:
        arena slot count is flat across iterations."""
        def prog(env):
            x = np.arange(20000.0)
            env.comm.allreduce(x, algo="ring")
            env.comm.barrier()
            s0 = env.arena.stats()["slots_used"]
            for _ in range(4):
                env.comm.allreduce(x, algo="ring")
                env.comm.barrier()
            return s0, env.arena.stats()["slots_used"]

        for s0, s1 in run_threads(2, prog, cell_size=CELL,
                                  pool_bytes=32 << 20):
            assert s0 == s1

    def test_hier_group_policy(self):
        assert _hier_group(4) == 2       # 2 groups of 2
        assert _hier_group(6) == 3       # group COUNT must be pow2
        assert _hier_group(12) == 3      # nearest sqrt(12) with 4 groups
        assert _hier_group(16) == 4
        assert _hier_group(7) is None    # prime: no hierarchy
        assert _hier_group(9) is None    # no pow2 cofactor
        assert _hier_group(6, 2) is None  # 3 groups: not a pow2 count
        assert _hier_group(2, 2) is None  # g must be < n


# --------------------------------------------------------------------------
# persistent requests
# --------------------------------------------------------------------------

class TestPersistentRequests:
    @pytest.mark.parametrize("nelem", [16, 30000])   # eager and staged
    def test_reuse_n_iterations(self, nelem):
        iters = 6

        def prog(env):
            peer = 1 - env.rank
            sbuf = np.zeros(nelem, np.float64)
            rbuf = np.zeros(nelem, np.float64)
            ps = env.comm.send_init(peer, sbuf, tag=7)
            pr = env.comm.recv_init(peer, rbuf, tag=7)
            got = []
            slots = []
            for i in range(iters):
                sbuf[:] = i * (env.rank + 1)
                ps.start()
                pr.start()
                n = pr.wait()
                ps.wait()
                assert n == sbuf.nbytes
                got.append(float(rbuf[0]))
                env.comm.barrier()       # align slot counts across ranks
                slots.append(env.arena.stats()["slots_used"])
            return got, slots

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=32 << 20,
                          timeout=120)
        assert res[0][0] == [i * 2.0 for i in range(iters)]
        assert res[1][0] == [i * 1.0 for i in range(iters)]
        # the staged plan allocates its stager ONCE: no per-iteration
        # arena create/destroy churn
        for _, slots in res:
            assert len(set(slots)) == 1

    def test_start_while_active_raises(self):
        def prog(env):
            if env.rank == 0:
                buf = bytearray(8)
                pr = env.comm.recv_init(1, buf, tag=1)
                pr.start()
                with pytest.raises(RuntimeError, match="active"):
                    pr.start()
                env.comm.send(1, b"", tag=2)     # unblock the sender
                pr.wait()
                return bytes(buf)
            env.comm.recv(0, tag=2)
            env.comm.send(0, b"deadbeef", tag=1)
            return None

        assert run_threads(2, prog, cell_size=CELL)[0] == b"deadbeef"

    def test_poolbuffer_persistent_send(self):
        def prog(env):
            if env.rank == 0:
                pb = env.comm.alloc_buffer(CELL * 2)
                ps = env.comm.send_init(1, pb, tag=3)
                for i in range(3):
                    pb.view()[:] = bytes([i]) * (CELL * 2)
                    ps.start()
                    ps.wait()
                assert ps._mode == "pool"
                return None
            out = []
            dst = bytearray(CELL * 2)
            for _ in range(3):
                env.comm.recv_into(0, dst, tag=3)
                out.append(dst[0])
            return out

        assert run_threads(2, prog, cell_size=CELL)[1] == [0, 1, 2]

    def test_free_releases_stager(self):
        def prog(env):
            if env.rank == 0:
                ps = env.comm.send_init(1, bytearray(CELL * 4), tag=1)
                before = env.arena.stats()["slots_used"]
                ps.start()
                env.comm.recv(1, tag=2)
                ps.wait()
                ps.free()
                return before - 1 == env.arena.stats()["slots_used"]
            env.comm.recv(0, tag=1)
            env.comm.send(0, b"", tag=2)
            return True

        assert all(run_threads(2, prog, cell_size=CELL))


# --------------------------------------------------------------------------
# auto-tuned eager threshold
# --------------------------------------------------------------------------

class TestAutoThreshold:
    def test_probe_records_crossover(self):
        def prog(env):
            assert isinstance(env.comm.eager_threshold, int)
            assert env.comm.eager_threshold >= 64
            peer = 1 - env.rank
            env.comm.send(peer, b"x" * (CELL * 3), tag=1)
            data, _ = env.comm.recv(peer, tag=1)
            return len(data), env.comm.eager_threshold

        res = run_threads(2, prog, cell_size=CELL,
                          eager_threshold="auto", pool_bytes=32 << 20)
        assert all(r[0] == CELL * 3 for r in res)

    def test_subcomms_inherit_resolved_threshold(self):
        def prog(env):
            sub = env.comm.split(0, key=env.rank)
            return env.comm.eager_threshold == sub.eager_threshold \
                and isinstance(sub.eager_threshold, int)

        assert all(run_threads(2, prog, cell_size=CELL,
                               eager_threshold="auto",
                               pool_bytes=32 << 20))


# --------------------------------------------------------------------------
# regressions: recv progress pump, collective window free
# --------------------------------------------------------------------------

class TestRegressions:
    def test_irecv_wait_pumps_send_progress(self):
        """Head-to-head isend + bare irecv().wait(): before the fix the
        recv path never advanced the sender's FIFO, deadlocking once the
        pair queue filled."""
        big = bytes(CELL * 16)

        def prog(env):
            peer = 1 - env.rank
            sreq = env.comm.isend(peer, big, tag=1)
            rreq = env.comm.irecv(peer, tag=1)
            data = rreq.wait(60)
            sreq.wait(60)
            return len(data)

        res = run_threads(2, prog, cell_size=CELL, n_cells=4,
                          eager_threshold=1 << 30, timeout=120)
        assert res == [len(big), len(big)]

    def test_posted_recv_matched_while_waiting_send(self):
        """A synchronous (pool-resident) send waited BEFORE a posted
        receive: the progress engine must match the posted receive
        passively (MPI posted-receive semantics), or a ring of
        start(send); start(recv); wait(send) deadlocks."""
        def prog(env):
            c = env.comm
            peer = (c.rank + 1) % c.size
            src = (c.rank - 1) % c.size
            sbuf = np.full(4000, float(c.rank))      # > threshold: staged
            rbuf = np.zeros(4000)
            ps = c.send_init(peer, sbuf, tag=11)
            pr = c.recv_init(src, rbuf, tag=11)
            for _ in range(3):
                ps.start()
                pr.start()
                ps.wait(60)              # sync send first — needs the
                pr.wait(60)              # engine to match pr passively
            return float(rbuf[0])

        res = run_threads(3, prog, cell_size=CELL, timeout=120)
        assert res == [2.0, 0.0, 1.0]

    def test_posted_recvs_match_in_order_per_source(self):
        """Two posted receives from one source complete in post order
        even when the user waits them out of order."""
        def prog(env):
            if env.rank == 0:
                env.comm.send(1, b"first", tag=1)
                env.comm.send(1, b"second", tag=2)
                return None
            r1 = env.comm.irecv(0, tag=1)
            r2 = env.comm.irecv(0, tag=2)
            b = r2.wait(30)              # out-of-order wait
            a = r1.wait(30)
            return a, b

        res = run_threads(2, prog, cell_size=CELL)
        assert res[1] == (b"first", b"second")

    def test_nonhead_recv_completes_from_park(self):
        """Receives of different tags complete independently: a later
        posted irecv whose message was parked by the head must finish
        even while the head is still unmatched."""
        def prog(env):
            if env.rank == 0:
                env.comm.send(1, b"tag2-first", tag=2)
                env.comm.recv(1, tag=9, timeout=30)   # rb delivered?
                env.comm.send(1, b"tag1-later", tag=1)
                return None
            ra = env.comm.irecv(0, tag=1)
            rb = env.comm.irecv(0, tag=2)
            b = rb.wait(30)              # must not starve behind ra
            env.comm.send(0, b"", tag=9)
            a = ra.wait(30)
            return a, b

        res = run_threads(2, prog, cell_size=CELL, timeout=60)
        assert res[1] == (b"tag1-later", b"tag2-first")

    def test_mixed_eager_thresholds_interoperate(self):
        """Collectives stay wire-compatible when ranks disagree on the
        eager threshold (the auto-probe is per-rank): resident and
        fallback stages must exchange the same rounds."""
        def prog(env):
            # force maximal disagreement: rank 0 rendezvous-everything,
            # rank 1 eager-everything
            env.comm.eager_threshold = 0 if env.rank == 0 else 1 << 30
            x = (np.arange(16384, dtype=np.float64) + 1) * (env.rank + 1)
            a = env.comm.allreduce(x, algo="ring")
            g = env.comm.allgather(np.full(2000, float(env.rank)))
            b = env.comm.bcast(np.arange(9000.0) if env.rank == 0
                               else None)
            return a, g, b

        res = run_threads(2, prog, cell_size=CELL, pool_bytes=64 << 20,
                          timeout=120)
        for a, g, b in res:
            assert np.allclose(a, (np.arange(16384.0) + 1) * 3)
            assert np.allclose(g.reshape(2, -1)[1], 1.0)
            assert np.allclose(b, np.arange(9000.0))

    def test_window_free_collective_idempotent(self):
        """Every rank calls free(); non-root ranks may still be inside
        an epoch — free fences first, and double-free is a no-op."""
        def prog(env):
            win = env.comm.win_allocate("wf", 256)
            win.fence()
            win.put(0, 8 * env.rank, np.float64(env.rank + 1))
            win.free()
            win.free()                   # idempotent
            return True

        assert all(run_threads(3, prog, pool_bytes=8 << 20))

    def test_window_free_releases_objects(self):
        def prog(env):
            env.comm.barrier()           # no rank has created yet
            before = env.arena.stats()["slots_used"]
            win = env.comm.win_allocate("wf2", 128)
            win.fence()
            win.free()
            env.comm.barrier()
            return env.arena.stats()["slots_used"] - before

        res = run_threads(2, prog, pool_bytes=8 << 20)
        assert res[0] == 0 and res[1] == 0


# --------------------------------------------------------------------------
# deprecation shims
# --------------------------------------------------------------------------

class TestDeprecationShims:
    @pytest.mark.parametrize("name", ["Communicator", "bcast", "reduce",
                                      "allreduce", "allgather_ring",
                                      "allgather_bruck", "alltoall",
                                      "barrier_dissemination",
                                      "reduce_scatter_ring"])
    def test_old_names_warn_and_resolve(self, name):
        import repro.core as core
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            obj = getattr(core, name)
        assert obj is not None
        assert any(issubclass(w.category, DeprecationWarning)
                   for w in caught)

    def test_old_free_function_path_still_works(self):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from repro.core import Communicator, bcast

        def prog(env):
            assert isinstance(env.comm, Communicator)   # Comm subclasses
            return bcast(env.comm,
                         np.arange(6.0) if env.rank == 0 else None)

        for out in run_threads(2, prog, cell_size=CELL):
            assert np.allclose(out, np.arange(6.0))

    def test_unknown_attr_still_raises(self):
        import repro.core as core
        with pytest.raises(AttributeError):
            core.definitely_not_an_api
