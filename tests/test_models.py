"""Per-architecture smoke tests (assignment requirement): every arch's
REDUCED config runs one forward/train step and one decode step on CPU,
asserting shapes and finiteness. Plus semantic checks: prefill-vs-decode
equivalence, MoE dispatch vs dense oracle, decode-state mechanics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.models import blocks as B
from repro.models import lm


def tiny_batch(cfg, bsz=2, seq=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(bsz, seq + 1))
    batch = {"labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    if cfg.frontend == "frames":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(bsz, seq, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(toks[:, :-1], jnp.int32)
    if cfg.n_ctx_tokens:
        batch["ctx"] = jnp.asarray(
            rng.normal(size=(bsz, cfg.n_ctx_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train(arch):
    cfg = get_config(arch).reduced()
    params = lm.init(cfg, jax.random.key(0))
    batch = tiny_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert jnp.isfinite(loss), arch
    assert loss.shape == ()
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    params = lm.init(cfg, jax.random.key(0))
    bsz, cache = 2, 8
    state = lm.decode_state_init(cfg, bsz, cache)
    batch = ({"frames": jnp.ones((bsz, 1, cfg.d_model), jnp.float32)}
             if cfg.frontend == "frames" else
             {"tokens": jnp.zeros((bsz, 1), jnp.int32)})
    logits, ns = lm.decode_step(params, cfg, state, batch,
                                jnp.zeros((bsz,), jnp.int32))
    assert logits.shape == (bsz, cfg.vocab_size), arch
    assert bool(jnp.isfinite(logits).all()), arch
    # state must actually change (cache write happened)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(ns)))
    assert changed, arch


@pytest.mark.parametrize("arch", ["llama3-8b", "rwkv6-3b", "musicgen-large",
                                  "jamba-1.5-large-398b"])
def test_prefill_decode_equivalence(arch):
    """Teacher-forcing the same tokens through decode steps must match the
    parallel forward's final logits (KV-cache correctness)."""
    # f32 compute: the test checks ALGORITHMIC equivalence, not bf16 drift
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              compute_dtype="float32")
    if cfg.moe is not None:
        # ample capacity: token dropping differs between prefill grouping
        # (per sequence) and decode grouping (across batch) by design
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = lm.init(cfg, jax.random.key(1))
    bsz, seq = 2, 8
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, size=(bsz, seq), dtype=np.int32)

    if cfg.frontend == "frames":
        emb = np.asarray(params["embed"], np.float32)
        full = {"frames": jnp.asarray(emb[toks])}
        stepb = lambda i: {"frames": jnp.asarray(emb[toks[:, i:i + 1]])}  # noqa: E731
    else:
        full = {"tokens": jnp.asarray(toks)}
        stepb = lambda i: {"tokens": jnp.asarray(toks[:, i:i + 1])}  # noqa: E731
    logits_par = lm.prefill(params, cfg, full)

    state = lm.decode_state_init(cfg, bsz, seq)
    logits_seq = None
    for i in range(seq):
        logits_seq, state = lm.decode_step(params, cfg, state, stepb(i),
                                           jnp.full((bsz,), i, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_par),
                               np.asarray(logits_seq), rtol=1e-4,
                               atol=1e-4)


def test_kv_update_dus_matches_onehot():
    cfg = get_config("glm4-9b").reduced()
    cfg2 = dataclasses.replace(cfg, kv_update="dus")
    params = lm.init(cfg, jax.random.key(0))
    b = 2
    t = np.random.default_rng(0).integers(0, cfg.vocab_size, (b, 4),
                                          dtype=np.int32)

    def roll(c):
        st = lm.decode_state_init(c, b, 8)
        outs = []
        for i in range(4):
            lg, st = lm.decode_step(params, c, st,
                                    {"tokens": jnp.asarray(t[:, i:i + 1])},
                                    jnp.full((b,), i, jnp.int32))
            outs.append(lg)
        return np.asarray(jnp.stack(outs))

    np.testing.assert_allclose(roll(cfg), roll(cfg2), atol=1e-5)


def test_chunked_attention_matches_plain():
    # f32 compute so the only difference is the summation algorithm
    cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                              compute_dtype="float32")
    params = lm.init(cfg, jax.random.key(0))
    batch = tiny_batch(cfg, bsz=2, seq=32)
    plain = lm.forward(params, cfg, batch)[0]
    cfgc = dataclasses.replace(cfg, attn_chunk=8)
    chunked = lm.forward(params, cfgc, batch)[0]
    np.testing.assert_allclose(np.asarray(plain, np.float32),
                               np.asarray(chunked, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_moe_matches_dense_oracle_when_capacity_ample():
    """With capacity_factor high enough that nothing drops, capacity
    dispatch == dense weighted mixture of expert outputs."""
    cfg = dataclasses.replace(
        get_config("granite-moe-1b-a400m").reduced(),
        moe=dataclasses.replace(
            get_config("granite-moe-1b-a400m").reduced().moe,
            capacity_factor=8.0),
    )
    params = B.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32).astype(jnp.dtype(cfg.compute_dtype))
    got, aux = B.moe_apply(params, cfg, x)

    # dense oracle: run every expert on every token, mix by top-k weights
    cdt = x.dtype
    logits = (x @ params["router"].astype(cdt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    h_g = jnp.einsum("bsd,edf->ebsf", x, params["w_gate"].astype(cdt))
    h_u = jnp.einsum("bsd,edf->ebsf", x, params["w_up"].astype(cdt))
    h = jax.nn.silu(h_g) * h_u
    eo = jnp.einsum("ebsf,efd->ebsd", h, params["w_down"].astype(cdt))
    oh = jax.nn.one_hot(top_e, cfg.moe.n_experts, dtype=jnp.float32)
    w = jnp.einsum("bske,bsk->ebs", oh, top_p)
    want = jnp.einsum("ebs,ebsd->bsd", w, eo.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)
    assert float(aux) > 0


def test_param_counts_sane():
    """Analytic counts land within 25% of actual leaf-count totals."""
    for arch in ("llama3-8b", "dbrx-132b", "rwkv6-3b"):
        cfg = get_config(arch)
        specs = lm.param_specs(cfg)
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs))
        analytic = cfg.param_counts()["total"]
        assert abs(actual - analytic) / actual < 0.25, \
            (arch, actual, analytic)


def test_long500k_applicability():
    ok, _ = shape_applicable(get_config("rwkv6-3b"), SHAPES["long_500k"])
    assert ok
    ok, why = shape_applicable(get_config("llama3-8b"), SHAPES["long_500k"])
    assert not ok and "full-attention" in why
    ok, _ = shape_applicable(get_config("jamba-1.5-large-398b"),
                             SHAPES["long_500k"])
    assert ok
